"""Pallas TPU kernels for the perf-critical substrate hot-spot.

flash_attention: online-softmax attention whose backward *recomputes* the
probability blocks instead of caching the O(S^2) score matrix — the paper's
recompute-don't-cache trade at the tile level (DESIGN.md §3.5).
Validated in interpret mode against kernels.ref (pure jnp oracle).
"""

from .ops import flash_attention

__all__ = ["flash_attention"]
