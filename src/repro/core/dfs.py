"""§4.1 — naive exhaustive search over lower-set sequences.

Exponential; used as the correctness oracle for the DP in tests (the DP's
optimum must match the exhaustive optimum on small graphs) and to expose the
triplet-state ``(L, t, m)`` observation that motivates the DP.

With ``strategies=`` (an extended ``StrategyConfig``) the search also
enumerates, per transition, every legal per-node storage-strategy
assignment of the newly cached set — the brute-force ground truth the
joint memory-strategy DP is property-tested against.  All folds (device
bytes, taxes) run in ascending node id, matching the DP's incremental
Minkowski sums float-for-float, and the budget check reads the same
memoized ``transition_excess`` value — so optimum equality is exact, not
approximate.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence

from .dp import DPResult, INF, peak_memory_live, to_mask
from .graph import EMPTY, Graph, NodeSet
from .liveness import transition_excess
from .lower_sets import all_lower_sets
from .strategies import StrategyConfig


def exhaustive_search(
    g: Graph,
    budget: float,
    objective: str = "time_centric",
    family: Optional[Sequence[NodeSet]] = None,
    strategies: Optional[StrategyConfig] = None,
) -> DPResult:
    """DFS over all increasing sequences {L₁ ≺ … ≺ L_k = V} within budget.

    Tracks the triplet (L, t, m) exactly as §4.1 describes:
      t = overhead so far, m = M(U_i) of the cache so far.

    With an extended ``strategies`` config every transition additionally
    branches over the product of its newly cached nodes' legal storage
    options; ``t`` then accumulates the strategy taxes for the
    time-centric objective (memory-centric maximizes pure recomputation
    overhead, so taxes stay out of its objective) and ``m`` accumulates
    the chosen device bytes.
    """
    ext = strategies is not None and strategies.extended
    tc = objective == "time_centric"
    fam = list(family) if family is not None else all_lower_sets(g)
    fam = [L for L in fam if L]  # drop ∅ as a sequence element
    full = frozenset(range(g.n))
    fam_sorted = sorted(fam, key=len)

    best_t = INF if tc else -INF
    best_seq: List[NodeSet] = []
    best_assign: Optional[Dict[int, str]] = None
    states = 0

    # Precompute per-L terms.
    info = {}
    for L in fam_sorted:
        b = g.boundary(L)
        info[L] = (b, to_mask(L), to_mask(b))

    def better(t: float) -> bool:
        return t < best_t if tc else t > best_t

    def rec(L: NodeSet, t: float, m: float, seq: List[NodeSet],
            assign: Dict[int, str]) -> None:
        nonlocal best_t, best_seq, best_assign, states
        states += 1
        if L == full:
            if better(t):
                best_t = t
                best_seq = list(seq)
                best_assign = dict(assign) if ext else None
            return
        mask_L = to_mask(L)
        for Lp in fam_sorted:
            if len(Lp) <= len(L) or not (L < Lp):
                continue
            b, mask_Lp, bd_mask = info[Lp]
            Vp = Lp - L
            # 𝓜⁽ⁱ⁾ with M(U_{i-1}) = m, same functional (and same memoized
            # floats) as the DP it oracles
            Mi = m + transition_excess(g, mask_L, mask_Lp, bd_mask)
            if Mi > budget:
                continue
            base_t = g.T(Vp - b)
            if not ext:
                seq.append(Lp)
                rec(Lp, t + base_t, m + g.M(b - L), seq, assign)
                seq.pop()
                continue
            new_nodes = sorted(b - L)
            per_node = [strategies.node_options(g, v) for v in new_nodes]
            for combo in itertools.product(*per_node):
                # ascending-id left folds, then one add onto the running
                # totals — the DP's exact float shape
                m_add = 0.0
                tax = 0.0
                for _code, bb, tx in combo:
                    m_add += bb
                    tax += tx
                t2 = t + (base_t + tax) if tc else t + base_t
                m2 = m + m_add
                seq.append(Lp)
                for v, (code, _bb, _tx) in zip(new_nodes, combo):
                    assign[v] = code
                rec(Lp, t2, m2, seq, assign)
                for v in new_nodes:
                    del assign[v]
                seq.pop()

    rec(EMPTY, 0.0, 0.0, [], {})

    if not best_seq:
        return DPResult([], INF, INF, feasible=False, states_visited=states)
    return DPResult(
        sequence=best_seq,
        overhead=best_t,
        peak_memory=peak_memory_live(g, best_seq, best_assign),
        feasible=True,
        states_visited=states,
        assignment=best_assign,
    )
