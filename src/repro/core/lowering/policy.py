"""Checkpoint-policy backends: the plan as a single jit/pjit citizen.

Both backends lower the canonical strategy the same way XLA wants it: tag
every node's output with ``jax.ad_checkpoint.checkpoint_name`` and run the
whole forward under one ``jax.checkpoint`` whose policy is
``save_only_these_names(U_k)`` — XLA then materializes exactly the paper's
cache set ∂(L₁) ∪ … ∪ ∂(L_k) and rematerializes everything else during the
backward pass.

* ``"policy"``  — block granularity over a ``BlockGraph``
  (``apply_with_policy``, the old ``core.remat`` entry point);
* ``"jaxpr"``   — equation granularity over **any traced JAX function**:
  the jaxpr is re-evaluated with each equation's outputs tagged by its
  graph-node name, so the plan's cache set lowers to
  ``save_only_these_names`` with no model cooperation at all.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.ad_checkpoint import checkpoint_policies as _cp

from ..schedule import ExecutionPlan
from .base import (
    Lowering,
    blockgraph_value_and_grad,
    register_lowering,
    reject_donate,
    reject_track_live,
)
from .carriers import BlockGraphCarrier, TracedCarrier, is_drop_var as _is_drop


def plan_policy(plan: ExecutionPlan, names: Sequence[str]):
    """``save_only_these_names`` over the plan's cache set U_k.

    ``names[v]`` is the checkpoint-name of node v (block name or jaxpr
    equation name).  Strategy plans lower their ``offload`` nodes through
    ``save_and_offload_only_these_names`` — XLA saves those residuals in
    host memory (``pinned_host``) and streams them back for the backward
    pass.  Quantized nodes stay in the *saved* list: their name tags the
    int8 payload + scales (see :func:`quantized_checkpoint`), not the full
    tensor, so the device keeps only the compressed bytes.
    """
    from ..strategies import OFFLOAD

    strategy = plan.strategy or {}
    offloaded = tuple(sorted(
        names[v] for v in plan.cached if strategy.get(v) == OFFLOAD
    ))
    keep = tuple(sorted(
        names[v] for v in plan.cached if strategy.get(v) != OFFLOAD
    ))
    if offloaded:
        return _cp.save_and_offload_only_these_names(
            names_which_can_be_saved=list(keep),
            names_which_can_be_offloaded=list(offloaded),
            offload_src="device",
            offload_dst="pinned_host",
        )
    return _cp.save_only_these_names(*keep)


# ---------------------------------------------------------------------------
# Block granularity (BlockGraph)
# ---------------------------------------------------------------------------


def apply_with_policy(bg, params: Dict[str, Any], inputs: Dict[str, Any],
                      plan: ExecutionPlan, mesh=None) -> Any:
    """Run a BlockGraph forward with the plan lowered to a checkpoint policy.

    Differentiating this function recomputes exactly the non-cached nodes —
    the canonical strategy as a single first-class jit citizen.  With
    ``mesh``, annotated block outputs keep their shardings (see
    ``segment.constrain_block_output``).
    """
    from .segment import constrain_block_output

    names = [b.name for b in bg.blocks]
    policy = plan_policy(plan, names)

    def fwd(p: Dict[str, Any], x: Dict[str, Any]):
        values: Dict[str, Any] = dict(x)
        for b in bg.blocks:
            out = constrain_block_output(
                b.apply(p[b.name], *[values[i] for i in b.inputs]), b, mesh
            )
            values[b.name] = checkpoint_name(out, b.name)
        outs = tuple(values[o] for o in bg.outputs)
        return outs[0] if len(outs) == 1 else outs

    return jax.checkpoint(fwd, policy=policy)(params, inputs)


# ---------------------------------------------------------------------------
# Equation granularity (traced JAX functions)
# ---------------------------------------------------------------------------


def _taggable(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)


def quantized_checkpoint(o, name: str):
    """Checkpoint ``o`` as int8 payload + per-block scales under ``name``.

    The *compressed representation* carries the checkpoint name, so a
    ``save_only_these_names`` policy materializes q (int8) and scales (f32)
    — ~0.25+1/256 of the full bytes — and the backward remat rebuilds the
    dequantized value from them.  The returned value is the round-trip with
    a straight-through gradient (``optim.compression``), so downstream
    consumers see exactly what a replay-from-storage would.
    """
    from repro.optim.compression import Compressed, compress, decompress

    c = compress(jax.lax.stop_gradient(o))
    q = checkpoint_name(c.q, name)
    s = checkpoint_name(c.scale, name)
    rt = decompress(Compressed(q, s, c.shape)).astype(o.dtype)
    return o + jax.lax.stop_gradient(rt - o)


def tagged_eval(closed, names: Sequence[str], *flat_args, quantized=frozenset()):
    """Evaluate a ClosedJaxpr with each equation's outputs named.

    ``names[idx]`` tags equation ``idx``'s (inexact) outputs via
    ``checkpoint_name`` — the hook ``save_only_these_names`` keys on.
    ``quantized`` equations route through :func:`quantized_checkpoint`
    instead: the name tags their int8+scale form.
    """
    from jax.extend import core as jcore

    jaxpr = closed.jaxpr
    env: Dict[Any, Any] = {}

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    for v, c in zip(jaxpr.constvars, closed.consts):
        env[v] = c
    for v, a in zip(jaxpr.invars, flat_args):
        env[v] = a
    for idx, eqn in enumerate(jaxpr.eqns):
        subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
        ans = eqn.primitive.bind(
            *subfuns, *[read(iv) for iv in eqn.invars], **bind_params
        )
        outs = list(ans) if eqn.primitive.multiple_results else [ans]
        outs = [
            (
                quantized_checkpoint(o, names[idx])
                if idx in quantized
                else checkpoint_name(o, names[idx])
            )
            if _taggable(o)
            else o
            for o in outs
        ]
        for ov, o in zip(eqn.outvars, outs):
            if not _is_drop(ov):
                env[ov] = o
    return read(jaxpr.outvars[0])


def traced_value_and_grad(carrier: TracedCarrier, plan: ExecutionPlan):
    """``jax.value_and_grad`` twin of the traced fn under the plan.

    The result composes with ``jax.jit``/``pjit`` like any JAX function;
    gradients are w.r.t. ``carrier.argnums``.  A sharding-aware carrier
    (traced with ``mesh=``) pins its arguments to the caller's shardings
    (``with_sharding_constraint``) before evaluation — the planned twin
    partitions exactly like the vanilla pjit'd function, and the constraint
    transposes to itself so gradients come back in the input layout.
    """
    from ..strategies import OFFLOAD, QUANTIZE

    names = carrier.node_names()
    policy = plan_policy(plan, names)
    closed = carrier.closed
    strategy = plan.strategy or {}
    quantized = frozenset(
        v for v, code in strategy.items() if code == QUANTIZE
    )

    ckpt_flat = jax.checkpoint(
        lambda *flat: tagged_eval(closed, names, *flat, quantized=quantized),
        policy=policy,
    )

    def scalar_fn(*args):
        return ckpt_flat(*carrier.constrain(carrier.flatten_args(args)))

    vag = jax.value_and_grad(scalar_fn, argnums=carrier.argnums)
    if any(code == OFFLOAD for code in strategy.values()):
        # the offload policy's host device_puts (TransferToMemoryKind) are
        # only legal under jit — eager twins with offloaded residuals would
        # raise at the first call
        vag = jax.jit(vag)
    return vag


# ---------------------------------------------------------------------------
# Registry glue
# ---------------------------------------------------------------------------


class PolicyLowering(Lowering):
    """BlockGraph production path: one checkpoint over named block outputs."""

    name = "policy"

    def supports(self, carrier) -> bool:
        return isinstance(carrier, BlockGraphCarrier)

    def lower(self, carrier, plan: ExecutionPlan, track_live: bool = False,
              donate: bool = False):
        if track_live:
            reject_track_live(self.name)
        if donate:
            reject_donate(self.name)
        if plan.strategy:
            raise NotImplementedError(
                "the block-granularity 'policy' backend does not realize "
                "storage strategies (block outputs are pytrees under one "
                "checkpoint name); lower strategy plans with "
                "backend='segment' (BlockGraphs) or backend='jaxpr' "
                "(traced functions)"
            )
        return blockgraph_value_and_grad(
            lambda p, x, _bg=carrier.bg, _plan=plan, _m=carrier.mesh:
                apply_with_policy(_bg, p, x, _plan, mesh=_m),
            carrier.loss_fn,
        )


class JaxprLowering(Lowering):
    """Traced-function production path: named equations + one checkpoint."""

    name = "jaxpr"

    def supports(self, carrier) -> bool:
        return isinstance(carrier, TracedCarrier)

    def lower(self, carrier, plan: ExecutionPlan, track_live: bool = False,
              donate: bool = False):
        if track_live:
            reject_track_live(self.name)
        fn = traced_value_and_grad(carrier, plan)
        if donate:
            from .donation import donate_lowered

            fn = donate_lowered(fn, carrier, carrier.to_graph(), plan)
        return fn


register_lowering(PolicyLowering())
register_lowering(JaxprLowering())
