"""Serving example: continuous batching over a trained (or fresh) model.

Submits a mixed workload (short/long prompts, greedy + sampled) to the
slot-based engine and prints per-request outputs + throughput.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import Engine


def main():
    cfg = reduced(get_config("qwen2.5-14b"), n_layers=4, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, max_slots=4, max_seq=128, rng_seed=0)

    rng = np.random.default_rng(0)
    specs = [
        ([1, 2, 3], 12, 0.0),
        (list(rng.integers(1, 200, size=24)), 8, 0.0),
        ([7] * 5, 16, 0.8),
        (list(rng.integers(1, 200, size=10)), 8, 0.0),
        ([42], 20, 1.0),
        (list(rng.integers(1, 200, size=40)), 6, 0.0),
    ]
    for prompt, n, temp in specs:
        eng.submit(prompt, max_new_tokens=n, temperature=temp)

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt[{len(r.prompt)} toks] → {r.output}")
    toks = sum(len(r.output) for r in done)
    print(f"\n{len(done)} requests, {toks} new tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
