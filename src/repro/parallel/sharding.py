"""Logical-axis sharding rules (DP/TP/EP/SP over ("pod", "data", "model")).

Models annotate activations with *logical* axis names; a rules table maps
them to mesh axes.  Changing the table re-shards the whole model — this is
the knob the §Perf hillclimb turns.

Default mapping:

  batch    → ("pod", "data")   data parallelism (hierarchical across pods)
  seq      → None              (sequence kept local for training shapes)
  seq_sp   → "data"            sequence parallelism for long-context decode
  model    → "model"           d_model kept replicated by default; the TP
                               split lives on heads / ffn / vocab instead
  heads    → "model"           tensor parallelism over attention heads
  kv_heads → "model"           (GQA: kv heads ≤ TP size is handled by rules)
  ffn      → "model"           MLP hidden dim
  experts  → "model"           expert parallelism
  vocab    → "model"           embedding / logits split
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import get_abstract_mesh


Rules = Dict[str, Any]  # logical name -> mesh axis (str | tuple | None)

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "data",
    "seq_act": "model",  # Megatron-style sequence parallelism: the residual
    #                      stream between layer groups lives S/tp per device
    "model": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_cap": "model",  # fallback: shard expert capacity rows when the
    #                         expert count doesn't divide the model axis
    "vocab": "model",
    "state": None,
}

# §Perf hillclimb alternative: NO tensor parallelism — the "model" mesh axis
# joins data parallelism and params are fully sharded (ZeRO-3).  For models
# whose per-chip matmul shards would be tiny under tp=16 (≤ ~4B params at 256
# chips), this removes every activation-cotangent all-reduce and replaces it
# with per-layer weight all-gathers an order of magnitude smaller.
DP_ONLY_RULES: Rules = {
    **DEFAULT_RULES,
    "batch": ("pod", "data", "model"),
    "seq_act": None,
    "heads": None,
    "kv_heads": None,
    "ffn": None,
    "experts": None,
    "expert_cap": None,
    "vocab": None,
}

# MoE hybrid: attention/dense parts ZeRO-sharded over data (no TP — their
# per-chip shards are tiny next to the experts), experts stay EP over the
# model axis with the all-to-all schedule.
DP_ATTN_RULES: Rules = {
    **DEFAULT_RULES,
    "seq_act": None,
    "heads": None,
    "kv_heads": None,
    "ffn": None,
    # vocab stays TP over "model": un-sharding it makes every chip hold the
    # full (B_loc, S, V) logits — 40 GB/chip at this cell's shape.
}

# Active rules — module-level so layer code stays signature-light; the
# launcher swaps them per run (hillclimb knob).
_ACTIVE_RULES: Rules = dict(DEFAULT_RULES)


def set_rules(rules: Rules) -> None:
    global _ACTIVE_RULES
    _ACTIVE_RULES = dict(rules)


def get_rules() -> Rules:
    return dict(_ACTIVE_RULES)


def _mesh_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is not None:
        return tuple(mesh.axis_names)
    env = get_abstract_mesh()
    try:
        return tuple(env.axis_names) if env is not None else ()
    except Exception:
        return ()


def resolve(
    logical: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Logical names → PartitionSpec under the active rules + mesh axes.

    With ``shape``, divisibility is checked inline so an axis rejected on one
    dim (e.g. "model" on 40 experts) stays available for a later dim (e.g.
    the expert-capacity fallback) instead of being consumed and dropped.
    """
    axes = set(_mesh_axes(mesh))
    sizes = _axis_sizes(mesh if mesh is not None else get_abstract_mesh())
    used: set = set()
    spec = []
    for i, name in enumerate(logical):
        if name is None:
            spec.append(None)
            continue
        target = _ACTIVE_RULES.get(name)
        if target is None:
            spec.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        eff = []
        dim = shape[i] if shape is not None and i < len(shape) else None
        prod = 1
        for a in target:
            if a not in axes or a in used:
                continue
            if dim is not None and dim % (prod * sizes.get(a, 1)) != 0:
                continue  # this axis would not divide — leave it available
            eff.append(a)
            prod *= sizes.get(a, 1)
        used.update(eff)
        eff = tuple(eff)
        spec.append(eff if len(eff) > 1 else (eff[0] if eff else None))
    return P(*spec)


def _axis_sizes(mesh) -> Dict[str, int]:
    try:
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        try:
            return dict(mesh.shape)
        except Exception:
            return {}


def drop_indivisible(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int]) -> P:
    """Replicate any dim the mesh axes don't divide evenly (e.g. kv_heads=8
    on a 16-way model axis, or an odd vocab).  GSPMD *would* pad, but padded
    shards waste memory/compute — replication is the perf-correct fallback."""
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= axis_sizes.get(a, 1)
        out.append(entry if total > 0 and dim % total == 0 else None)
    return P(*out)


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op without a mesh)."""
    try:
        mesh = get_abstract_mesh()
        if mesh is None or not mesh.axis_names or mesh.empty:
            return x
    except Exception:
        return x
    spec = resolve(logical, shape=tuple(x.shape))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Parameter sharding: map a param-tree path to a PartitionSpec.
# ---------------------------------------------------------------------------


def param_spec(path: str, shape: Tuple[int, ...]) -> P:
    """Sharding rule for one parameter, keyed on its tree path.

    Conventions (matching repro.models param names):
      embed / unembed   : (vocab, d_model)          → vocab over "model"
      wq/wk/wv          : (d_model, heads·dh)       → out dim over "model"
      wo                : (heads·dh, d_model)       → in dim over "model"
      w_gate/w_up       : (d_model, d_ff)           → d_ff over "model"
      w_down            : (d_ff, d_model)           → d_ff over "model"
      experts.*         : (E, …)                    → E over "model"
      norms / biases / scalars                      → replicated
    """
    rules = _ACTIVE_RULES

    def ax(name):
        t = rules.get(name)
        return t if t is not None else None

    if len(shape) == 0 or min(shape) == 0:
        return P()
    last = path.split("/")[-1]
    if "expert" in path:
        # stacked experts: leading E axis
        spec = [ax("experts")] + [None] * (len(shape) - 1)
        if last in ("w_gate", "w_up") and len(shape) == 3:
            spec[2] = None  # E already takes "model"
        return P(*spec)
    if last in ("embed", "unembed", "lm_head"):
        return P(ax("vocab"), None) if len(shape) == 2 else P()
    if last in ("wq", "wk", "wv", "wqkv"):
        return P(None, ax("heads")) if len(shape) >= 2 else P(ax("heads"))
    if last == "wo":
        return P(ax("heads"), None)
    if last in ("w_gate", "w_up", "w13"):
        return P(None, ax("ffn"))
    if last in ("w_down", "w2"):
        return P(ax("ffn"), None)
    if last in ("in_proj", "x_proj", "dt_proj"):
        return P(None, ax("ffn")) if len(shape) == 2 else P()
    if last == "out_proj":
        return P(ax("ffn"), None) if len(shape) == 2 else P()
    return P(*([None] * len(shape)))


def stacked_param_spec(path: str, shape: Tuple[int, ...]) -> P:
    """Same, for layer-stacked params with a leading [n_layers] axis."""
    inner = param_spec(path, shape[1:])
    return P(None, *inner)


def tree_param_specs(params, stacked_prefixes: Sequence[str] = ("layers",)):
    """PartitionSpec pytree matching a parameter pytree."""

    def visit(path_tuple, leaf):
        keys = []
        for p in path_tuple:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        path = "/".join(keys)
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        if any(path.startswith(pref) for pref in stacked_prefixes) and len(shape) >= 1:
            return stacked_param_spec(path, shape)
        return param_spec(path, shape)

    return jax.tree_util.tree_map_with_path(visit, params)


def fsdp_extend(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int],
                fsdp_axis: str = "data", min_elems: int = 1 << 16) -> P:
    """ZeRO-3/FSDP: additionally shard the largest still-replicated dim of a
    big tensor over the data axis.  Keeps small tensors (norms, biases)
    replicated."""
    n = 1
    for d in shape:
        n *= d
    if n < min_elems or fsdp_axis not in axis_sizes:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    # never reuse an axis that already shards some dim
    for e in entries:
        taken = e if isinstance(e, tuple) else (e,)
        if fsdp_axis in taken:
            return spec
    size = axis_sizes[fsdp_axis]
    # largest unsharded, divisible dim
    best, best_dim = -1, -1
    for i, (d, e) in enumerate(zip(shape, entries)):
        if e is None and d % size == 0 and d > best_dim:
            best, best_dim = i, d
    if best < 0:
        return spec
    entries[best] = fsdp_axis
    return P(*entries)


def named_sharding_tree(params, mesh: Mesh, fsdp: bool = False,
                        fsdp_axes: Tuple[str, ...] = ("data",), **kw):
    specs = tree_param_specs(params, **kw)
    sizes = _axis_sizes(mesh)

    def to_sharding(spec, leaf):
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        p = drop_indivisible(spec, shape, sizes)
        if fsdp:
            for ax in fsdp_axes:
                p = fsdp_extend(p, shape, sizes, fsdp_axis=ax)
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map(to_sharding, specs, params)
