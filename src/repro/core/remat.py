"""Lower a recomputation plan into ``jax.checkpoint`` machinery.

Two production lowerings of the canonical strategy (§3):

* ``apply_with_policy`` — tag every block output with
  ``jax.ad_checkpoint.checkpoint_name`` and run the whole forward under one
  ``jax.checkpoint`` whose policy is ``save_only_these_names(U_k)``: XLA then
  materializes exactly the paper's cache set ∂(L₁) ∪ … ∪ ∂(L_k) and
  rematerializes everything else during the backward pass.  This is the
  jit/pjit-composable twin of ``core.executor.planned_value_and_grad``.

* ``segment_groups`` — map a plan for a *layer-chain* model onto grouped
  scan remat: layers are partitioned into the plan's V_i groups; each group
  becomes one ``jax.checkpoint``-wrapped inner scan step.  For chains the
  lower-set lattice is exactly the set of layer prefixes, so the DP plan is
  optimal, not heuristic (used by models.transformer for the production
  models).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.ad_checkpoint import checkpoint_policies as _cp

from .graph import Graph
from .schedule import ExecutionPlan


def plan_policy(plan: ExecutionPlan, names: Sequence[str]):
    """``save_only_these_names`` over the plan's cache set U_k.

    ``names[v]`` is the checkpoint-name of node v (block name).
    """
    keep = tuple(sorted(names[v] for v in plan.cached))
    return _cp.save_only_these_names(*keep)


def apply_with_policy(bg, params: Dict[str, Any], inputs: Dict[str, Any], plan: ExecutionPlan) -> Any:
    """Run a BlockGraph forward with the plan lowered to a checkpoint policy.

    Differentiating this function recomputes exactly the non-cached nodes —
    the canonical strategy as a single first-class jit citizen.
    """
    names = [b.name for b in bg.blocks]
    policy = plan_policy(plan, names)

    def fwd(p: Dict[str, Any], x: Dict[str, Any]):
        values: Dict[str, Any] = dict(x)
        for b in bg.blocks:
            out = b.apply(p[b.name], *[values[i] for i in b.inputs])
            values[b.name] = checkpoint_name(out, b.name)
        outs = tuple(values[o] for o in bg.outputs)
        return outs[0] if len(outs) == 1 else outs

    return jax.checkpoint(fwd, policy=policy)(params, inputs)


def segment_groups(plan: ExecutionPlan, num_layers: int, nodes_per_layer: int = 1) -> List[int]:
    """Layer-group sizes [g₁, …, g_k] induced by the plan on a layer chain.

    For the scan-over-layers production models the graph is a chain of
    ``num_layers`` macro-nodes; the plan's segments V_i are contiguous layer
    runs.  Returns the run lengths, which models.transformer uses to build a
    per-group ``jax.checkpoint`` inner scan (segment remat ≙ canonical
    strategy on the chain graph).
    """
    sizes = []
    for seg in plan.segments:
        n_nodes = len(seg.nodes)
        if n_nodes % nodes_per_layer:
            raise ValueError(
                f"segment {seg.index} has {n_nodes} nodes, not a multiple of "
                f"{nodes_per_layer} per layer — plan does not align to layers"
            )
        sizes.append(n_nodes // nodes_per_layer)
    if sum(sizes) != num_layers:
        raise ValueError(f"plan covers {sum(sizes)} layers, model has {num_layers}")
    return sizes


def even_groups(num_layers: int, num_segments: int) -> List[int]:
    """Chen-style √n fallback grouping (equal-size contiguous segments)."""
    base, extra = divmod(num_layers, num_segments)
    return [base + (1 if i < extra else 0) for i in range(num_segments)]
