"""§5.1 planner-runtime comparison, plus the budget-sweep engine (PR 2).

Paper: "The exact DP algorithm required more than 80 secs to complete for
GoogLeNet and PSPNet, while the approximate DP completed within 1 sec for
all networks."  Our pure-Python implementation shifts the absolute scale but
must reproduce the ordering and the #𝓛-driven blow-up.

Beyond the paper, this also benchmarks the budget-sweep engine
(``core.dp.sweep``) against the per-budget DP it subsumes:

* an 8-point budget grid from ONE capped sweep vs 8 independent solves —
  plans must be bit-identical, and the sweep must cost no more than the
  loop (it is then cached under the budget-free ``sweep`` entry kind, so
  every later grid/budget/process is a lookup);
* the exact one-pass ``min_feasible_budget`` (``dp.min_feasible_budget_exact``)
  vs the retired §5.1 binary search — must agree within the search's
  tolerance (the exact value is ≤ the search's, and itself feasible).

Since ISSUE 8 it also gates the vectorized-DP fleet targets: EVERY
benchmark net (densenet161 included) must plan cold in < 5 s (fresh
process: family + exact min budget + solve) and warm in < 10 ms (aux +
decoded-LRU hits) through the Planner front door — ~10× over the ~50 s
scalar-era cold solve.  The cold number is a min-of-2 and warm a
min-of-3, so the gates measure the solver, not machine noise.

``--smoke`` runs a trimmed network set for the sweep/paper sections (the
cold/warm gates always cover all nets) and *asserts* the regression
guards (exit code 1 on violation) — wired into CI so DP-speed or
bit-identity regressions fail the build instead of landing silently.
Every run also writes ``BENCH_dp_runtime.json`` (sweep-vs-loop state
counts, per-net cold/warm planning walls, plan-cache hit timings) — CI
uploads it per commit so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict

from repro.core import PlanCache, Planner, approx_dp, exact_dp, min_feasible_budget
from repro.core import dp as dp_mod
from repro.core.planner import _min_feasible_budget_uncached
from repro.core.lower_sets import all_lower_sets, count_lower_sets, pruned_lower_sets

from .networks import NETWORKS

EXACT_BUDGET_S = 120.0  # per-network cap on the exact solve
GRID_POINTS = 8
GRID_SPAN = 3.0  # grid covers [B_min, (1 + GRID_SPAN) · B_min]
MAX_SWEEP_STATES = 20_000_000  # ≈ Planner's fallback threshold
SMOKE_NETS = ("vgg19", "unet")
# ISSUE-8 fleet gates: every net plans cold under this (vectorized DP), and
# a warm repeat is a decoded-LRU lookup.  Cold is min-of-2 fresh runs and
# warm min-of-3 repeats, so one scheduler hiccup can't fail CI.
COLD_PLAN_BUDGET_S = 5.0
WARM_PLAN_BUDGET_S = 0.010


def plan_rows(nets) -> Dict[str, Dict]:
    """Per-net cold/warm planning wall clock through the Planner front door.

    Cold = fresh graph + fresh Planner + empty PlanCache: family
    enumeration, exact min-feasible budget, and the budget solve — the
    full price a first-ever process pays.  Warm = the same two queries
    repeated on the live planner: aux + decoded-LRU hits.  min-of-2 /
    min-of-3 respectively, so the gates measure the code, not the
    machine's noise floor.
    """
    print("\n== Planner cold/warm wall clock (ISSUE-8 fleet gates) ==")
    print(f"{'network':12s} {'cold_s':>8s} {'warm_ms':>9s} {'identical':>9s}")
    out: Dict[str, Dict] = {}
    for name in nets:
        cold = None
        for _ in range(2):
            g = NETWORKS[name]()  # fresh object: no memoized digest/liveness
            planner = Planner(cache=PlanCache())  # empty tiers
            t0 = time.perf_counter()
            B = planner.min_feasible_budget(g, "approx_dp")
            res = planner.solve(g, B, "approx_dp")
            dt = time.perf_counter() - t0
            cold = dt if cold is None else min(cold, dt)
        warm = None
        for _ in range(3):
            t0 = time.perf_counter()
            B2 = planner.min_feasible_budget(g, "approx_dp")
            res2 = planner.solve(g, B2, "approx_dp")
            dt = time.perf_counter() - t0
            warm = dt if warm is None else min(warm, dt)
        identical = (
            B2 == B
            and res2.sequence == res.sequence
            and res2.overhead == res.overhead
            and res2.peak_memory == res.peak_memory
        )
        out[name] = {
            "cold_s": cold,
            "warm_s": warm,
            "feasible": bool(res.feasible),
            "identical": identical,
        }
        print(f"{name:12s} {cold:8.2f} {warm * 1e3:9.3f} {str(identical):>9s}")
    return out


def check_plan_rows(rows: Dict[str, Dict]) -> list:
    """The cold < 5 s / warm < 10 ms fleet gates, per net."""
    failures = []
    for name, r in rows.items():
        if not r["feasible"]:
            failures.append(f"{name}: min-feasible-budget plan infeasible")
        if not r["identical"]:
            failures.append(f"{name}: warm plan not identical to cold plan")
        if r["cold_s"] >= COLD_PLAN_BUDGET_S:
            failures.append(
                f"{name}: cold plan {r['cold_s']:.2f}s >= "
                f"{COLD_PLAN_BUDGET_S:.0f}s budget"
            )
        if r["warm_s"] >= WARM_PLAN_BUDGET_S:
            failures.append(
                f"{name}: warm plan {r['warm_s'] * 1e3:.2f}ms >= "
                f"{WARM_PLAN_BUDGET_S * 1e3:.0f}ms budget"
            )
    return failures


def sweep_rows(nets) -> Dict[str, Dict]:
    """Budget-sweep engine vs the per-budget DP (grid + min budget)."""
    print("\n== Budget sweep: one pass vs per-budget DP ==")
    print(f"{'network':12s} {'solve_s':>8s} {'loop8_s':>8s} {'sweep_s':>8s} "
          f"{'work_ratio':>10s} {'identical':>9s} {'mfb_s':>7s} {'bsearch_s':>9s}")
    out: Dict[str, Dict] = {}
    for name in nets:
        g = NETWORKS[name]()
        fam = pruned_lower_sets(g)
        t0 = time.perf_counter()
        mfb = dp_mod.min_feasible_budget_exact(g, fam)
        t_mfb = time.perf_counter() - t0
        t0 = time.perf_counter()
        bs = _min_feasible_budget_uncached(g, family=fam, tol=1e-3)
        t_bs = time.perf_counter() - t0
        budgets = [mfb * (1.0 + GRID_SPAN * i / (GRID_POINTS - 1))
                   for i in range(GRID_POINTS)]
        t0 = time.perf_counter()
        loop = [dp_mod.solve(g, B, fam) for B in budgets]
        t_loop = time.perf_counter() - t0
        loop_states = sum(r.states_visited for r in loop)
        t0 = time.perf_counter()
        try:
            sw = dp_mod.sweep(g, fam, cap=max(budgets),
                              max_states=MAX_SWEEP_STATES)
        except dp_mod.SweepOverflow:
            # surface too wide at this budget range — the planner would fall
            # back to per-budget solves for this graph; recorded so smoke
            # mode FAILS rather than silently skipping the guard (a state
            # explosion is exactly the regression this benchmark polices)
            print(f"{name:12s} {t_loop / GRID_POINTS:8.3f} {t_loop:8.2f} "
                  f"{'overflow':>8s} {'-':>10s} {'-':>9s} {t_mfb:7.3f} "
                  f"{t_bs:9.3f}")
            out[name] = {"overflow": True}
            continue
        grid = [sw.solve(g, B) for B in budgets]
        t_sweep = time.perf_counter() - t0
        identical = all(
            a.feasible == b.feasible and a.sequence == b.sequence
            and a.overhead == b.overhead
            for a, b in zip(loop, grid)
        )
        row = {
            "solve_s": t_loop / GRID_POINTS,
            "loop_s": t_loop,
            "sweep_s": t_sweep,
            "loop_states": loop_states,
            "sweep_states": sw.states_visited,
            "identical": identical,
            "min_budget_exact": mfb,
            "min_budget_search": bs,
            "min_budget_exact_s": t_mfb,
            "min_budget_search_s": t_bs,
            "exact_feasible": dp_mod.solve(g, mfb, fam).feasible,
        }
        out[name] = row
        print(f"{name:12s} {row['solve_s']:8.3f} {t_loop:8.2f} {t_sweep:8.2f} "
              f"{sw.states_visited / loop_states:10.2f} {str(identical):>9s} "
              f"{t_mfb:7.3f} {t_bs:9.3f}")
    return out


def check_sweep(rows: Dict[str, Dict]) -> list:
    """The smoke-mode regression guards (returned as a list of failures)."""
    failures = []
    for name, r in rows.items():
        if r.get("overflow"):
            failures.append(
                f"{name}: sweep overflowed {MAX_SWEEP_STATES} states — "
                f"state explosion in the sweep engine"
            )
            continue
        if not r["identical"]:
            failures.append(f"{name}: sweep grid not bit-identical to per-budget solves")
        # DP-work gate, deterministic (immune to CI load): one capped sweep
        # visits 0.2–1.3x the transition states of the 8-solve loop; 2x
        # fails on any real complexity regression in the sweep engine
        if r["sweep_states"] > 2.0 * r["loop_states"]:
            failures.append(
                f"{name}: sweep visited {r['sweep_states']} states > 2x the "
                f"per-budget loop's {r['loop_states']}"
            )
        # loose wall-clock safety net for constant-factor regressions
        if r["sweep_s"] > 6.0 * r["loop_s"]:
            failures.append(
                f"{name}: sweep {r['sweep_s']:.2f}s > 6x the per-budget "
                f"loop {r['loop_s']:.2f}s"
            )
        if not r["exact_feasible"]:
            failures.append(f"{name}: exact min budget not feasible")
        if not (r["min_budget_exact"] <= r["min_budget_search"] + 1e-9):
            failures.append(
                f"{name}: exact min budget {r['min_budget_exact']:.3e} above "
                f"binary-search result {r['min_budget_search']:.3e}"
            )
        if r["min_budget_search"] > r["min_budget_exact"] * 1.01 + 1e-9:
            failures.append(
                f"{name}: binary search strayed >1% above the exact minimum"
            )
    return failures


def check_plan_function():
    """Front-door regression guard → (failures, machine-readable record).

    ``repro.plan_function`` must (a) produce gradients bit-identical to
    vanilla ``jax.value_and_grad`` under a halved byte budget, and (b)
    cache-hit on the second call — a fresh planned function over the same
    fn/shapes re-solves nothing.  The record carries the cold-vs-warm
    planning wall times (the plan-cache hit timing tracked across PRs).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax import lax

    from repro.core import PlanCache, Planner
    from repro.core.jaxpr_graph import trace as jtrace
    from repro.core.liveness import vanilla_peak
    from repro.core.lowering import plan_function

    dn = (((1,), (0,)), ((), ()))

    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, dn))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
              for i in range(8)]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    budget = vanilla_peak(jtrace(fn, params, x).graph, liveness=False) / 2

    failures = []
    planner = Planner(cache=PlanCache())
    pf1 = plan_function(fn, budget, planner=planner)
    t0 = time.perf_counter()
    lowered1 = pf1.lowered_for(params, x)
    t_plan_cold = time.perf_counter() - t0
    out1 = lowered1.run(params, x)
    misses_cold = planner.cache.stats()["misses"]
    pf2 = plan_function(fn, budget, planner=planner)
    t0 = time.perf_counter()
    lowered2 = pf2.lowered_for(params, x)
    t_plan_warm = time.perf_counter() - t0
    out2 = lowered2.run(params, x)
    stats = planner.cache.stats()
    if stats["hits"] < 1:
        failures.append("plan_function: second call did not hit the plan cache")
    if stats["misses"] > misses_cold:
        failures.append(
            f"plan_function: second call re-solved "
            f"({stats['misses']} misses > cold {misses_cold})"
        )
    ref = jax.value_and_grad(fn)(params, x)
    for got in (out1, out2):
        ok = np.array_equal(np.asarray(got[0]), np.asarray(ref[0])) and all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(got[1]),
                            jax.tree_util.tree_leaves(ref[1]))
        )
        if not ok:
            failures.append(
                "plan_function: loss/gradients not bit-identical to vanilla"
            )
            break
    print(f"\n== plan_function front door ==\n"
          f"cache: {stats['hits']} hits / {stats['misses']} misses after "
          f"two planned calls; plan {t_plan_cold*1e3:.1f} ms cold / "
          f"{t_plan_warm*1e3:.1f} ms warm; gradients bit-identical: "
          f"{not any('bit-identical' in f for f in failures)}")
    record = {
        "plan_cold_s": t_plan_cold,
        "plan_warm_s": t_plan_warm,
        "cache_hits": stats["hits"],
        "cache_misses": stats["misses"],
    }
    return failures, record


def paper_rows(nets) -> Dict[str, Dict]:
    """The paper's §5.1 exact-vs-approximate wall-time table."""
    print("\n== DP runtime: exact vs approximate (§5.1) ==")
    print(f"{'network':12s} {'#V':>5s} {'#L_G':>8s} {'approx_s':>9s} "
          f"{'exact_s':>9s} {'approx_oh':>10s} {'exact_oh':>9s}")
    out = {}
    for name in nets:
        g = NETWORKS[name]()
        fam_p = pruned_lower_sets(g)
        B = min_feasible_budget(g, family=fam_p) * 1.05
        t0 = time.perf_counter()
        ap = approx_dp(g, B)
        t_ap = time.perf_counter() - t0
        try:
            nL = count_lower_sets(g, limit=200_000)
        except RuntimeError:
            nL = -1
        # exact solve with a wall-clock budget (the paper also reports
        # exact-DP blow-ups rather than waiting them out)
        t_ex = None
        ex_oh = None
        if 0 < nL <= 2_000:
            fam_e = all_lower_sets(g)
            t0 = time.perf_counter()
            ex = exact_dp(g, B)
            t_ex = time.perf_counter() - t0
            ex_oh = ex.overhead if ex.feasible else float("nan")
        row = {
            "n": g.n, "num_lower_sets": nL, "approx_s": t_ap, "exact_s": t_ex,
            "approx_overhead": ap.overhead if ap.feasible else None,
            "exact_overhead": ex_oh,
        }
        out[name] = row
        print(f"{name:12s} {g.n:>5d} {nL:>8d} {t_ap:>9.2f} "
              f"{t_ex if t_ex is not None else float('nan'):>9.2f} "
              f"{row['approx_overhead'] or float('nan'):>10.0f} "
              f"{ex_oh if ex_oh is not None else float('nan'):>9.0f}")
    # paper's qualitative claim: approx ≈ exact in quality where both ran
    both = [(r["approx_overhead"], r["exact_overhead"]) for r in out.values()
            if r["exact_overhead"] is not None and r["approx_overhead"] is not None]
    if both:
        ratios = [a / e for a, e in both if e]
        print(f"  approx/exact overhead ratio: "
              f"min {min(ratios):.2f} max {max(ratios):.2f} "
              f"(paper: 'did not differ much')")
    return out


def main(smoke: bool = False,
         out_json: str = "BENCH_dp_runtime.json") -> Dict[str, Dict]:
    nets = SMOKE_NETS if smoke else tuple(NETWORKS)
    # the grid loop runs 8 full per-budget DPs per network; keep the sweep
    # comparison to the small/medium nets by default (the big three already
    # dominate the §5.1 table above)
    sweep_nets = SMOKE_NETS if smoke else (
        "vgg19", "unet", "resnet50", "googlenet")
    out = {"paper": paper_rows(nets), "sweep": sweep_rows(sweep_nets)}
    failures = check_sweep(out["sweep"])
    # the ISSUE-8 cold/warm fleet gates cover ALL nets, smoke included —
    # densenet161's ~50 s scalar-era cold solve is exactly the regression
    # this guard exists to catch
    out["plan"] = plan_rows(tuple(NETWORKS))
    failures += check_plan_rows(out["plan"])
    pf_failures, pf_record = check_plan_function()
    failures += pf_failures
    out["plan_function"] = pf_record
    if out_json:
        # machine-readable perf trajectory (sweep-vs-loop state counts,
        # plan-cache hit timings) — CI uploads this per commit
        import json

        payload = {
            "smoke": smoke,
            "failures": failures,
            "paper": out["paper"],
            "sweep": out["sweep"],
            "plan": out["plan"],
            "plan_function": pf_record,
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"\nwrote {out_json}")
    if failures:
        print("\nREGRESSIONS:")
        for f in failures:
            print(f"  - {f}")
        if smoke:
            sys.exit(1)
    elif smoke:
        print("\nsmoke OK: sweep grids bit-identical, within 2x of the "
              "per-budget loop's DP work; exact min budget feasible and "
              "<= search; every net plans cold < "
              f"{COLD_PLAN_BUDGET_S:.0f}s and warm < "
              f"{WARM_PLAN_BUDGET_S * 1e3:.0f}ms; plan_function cache-hits "
              "and matches vanilla gradients bit-for-bit")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small network set + hard assertions (CI mode)")
    ap.add_argument("--out-json", default="BENCH_dp_runtime.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    main(smoke=args.smoke, out_json=args.out_json)
