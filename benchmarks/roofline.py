"""Roofline analysis from the dry-run artifacts (results/dryrun/*.json).

Per (arch × shape) on the single-pod mesh:

  compute term    = FLOPs_per_chip / 197e12      (v5e bf16 peak)
  memory term     = bytes_per_chip / 819e9       (HBM bandwidth)
  collective term = coll_bytes_per_chip / 50e9   (ICI per link)

FLOPs/bytes come from the scan-aware jaxpr totals (global ÷ chips) — XLA's
cost_analysis counts while-loop bodies once and is reported alongside for
reference.  Collective bytes are the trip-count-aware per-chip sums parsed
from the post-SPMD HLO (launch/dryrun.py).

MODEL_FLOPS = 6·N·D for training (3·N·D fwd+bwd split: 2 fwd + 4 bwd ≈ 6),
2·N_active·D for inference steps.  The ratio MODEL/HLO exposes recompute
and padding waste — for our plans the gap *is* the paper's overhead
T(V \\ U_k), so it doubles as a faithfulness check.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import REGISTRY, SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    n_active = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def load_cells(mesh: str = "single") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        cells.append(r)
    return cells


def roofline_row(r: Dict) -> Optional[Dict]:
    chips = r["devices"]
    if "jaxpr_flops_global" not in r:
        return None
    flops_chip = r["jaxpr_flops_global"] / chips
    bytes_chip = r["jaxpr_bytes_global"] / chips
    coll_chip = r["collectives"]["total_bytes_per_chip"]
    t_comp = flops_chip / PEAK_FLOPS_BF16
    t_mem = bytes_chip / HBM_BW
    t_coll = coll_chip / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    mf = model_flops(r["arch"], r["shape"])
    useful = mf / max(r["jaxpr_flops_global"], 1.0)
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful-model-compute time over the bound term
    frac = (mf / chips / PEAK_FLOPS_BF16) / bound if bound else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "devices": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom[1], "model_flops": mf,
        "useful_flops_ratio": useful, "roofline_frac": frac,
        "temp_gb_per_chip": r.get("temp_size_in_bytes", 0) / 1e9,
        "n_micro": r.get("n_micro", 1),
    }


def main(mesh: str = "single") -> List[Dict]:
    cells = load_cells(mesh)
    rows = [x for x in (roofline_row(r) for r in cells) if x]
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    print(f"\n== Roofline (per chip, {mesh}-pod mesh, v5e constants) ==")
    print(f"{'arch':24s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
          f"{'coll_s':>8s} {'bound':>10s} {'useful%':>8s} {'roofl%':>7s} "
          f"{'temp GB':>8s}")
    for x in rows:
        print(f"{x['arch']:24s} {x['shape']:12s} {x['t_compute_s']:8.3f} "
              f"{x['t_memory_s']:8.3f} {x['t_collective_s']:8.3f} "
              f"{x['dominant']:>10s} {100*x['useful_flops_ratio']:7.1f}% "
              f"{100*x['roofline_frac']:6.1f}% {x['temp_gb_per_chip']:8.1f}")
    # aggregate
    from collections import Counter

    doms = Counter(x["dominant"] for x in rows)
    print(f"  dominant-term distribution: {dict(doms)}")
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "single")
