"""Computation-graph language of the paper (§2).

A directed acyclic graph ``G = (V, E)`` over the *intermediate* nodes of a
neural network.  Input nodes and parameters are excluded (§2).  Each node
``v`` carries a forward-computation cost ``T_v > 0`` and a memory cost
``M_v > 0``.

Definitions implemented here, verbatim from the paper:

* ``δ⁺(S) = {v ∈ V | (s, v) ∈ E for some s ∈ S}``
* ``δ⁻(S) = {v ∈ V | (v, s) ∈ E for some s ∈ S}``
* ``L ⊆ V`` is a *lower set* iff there is no edge from ``V \\ L`` into ``L``
  (equivalently ``δ⁻(L) ⊆ L``), written ``L ≺ V``.
* the *boundary* ``∂(L) = δ⁻(V \\ L) ∩ L``.

Node sets are represented as Python ``frozenset`` of integer node ids for
hashability (DP table keys), with bitmask fast paths for small graphs.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

NodeSet = FrozenSet[int]

EMPTY: NodeSet = frozenset()


# Bitmask form of node sets (big-int; bit v = node v).  Single source of
# truth for the DP (core.dp) and the liveness analytics (core.liveness).


def to_mask(s: Iterable[int]) -> int:
    m = 0
    for v in s:
        m |= 1 << v
    return m


def from_mask(m: int) -> NodeSet:
    out = []
    v = 0
    while m:
        if m & 1:
            out.append(v)
        m >>= 1
        v += 1
    return frozenset(out)


def mask_iter(m: int) -> Iterable[int]:
    v = 0
    while m:
        if m & 1:
            yield v
        m >>= 1
        v += 1


@dataclasses.dataclass(frozen=True)
class Node:
    """A single intermediate value in the network.

    Attributes:
      idx: integer id, also the index into ``Graph.nodes``.
      name: human-readable name (layer / jaxpr eqn primitive).
      time: forward computation cost ``T_v`` (paper: 10 for conv, 1 otherwise).
      memory: memory consumption cost ``M_v`` (bytes, or abstract units).
      kind: free-form tag ("conv", "matmul", "elementwise", ...).
      must_store: hard pin from effect analysis (``repro.analysis``) — the
        node's value may not be recomputed (PRNG draw, side effect, opaque
        higher-order equation), so every plan must keep it resident from its
        forward computation until its last use.
    """

    idx: int
    name: str
    time: float
    memory: float
    kind: str = "generic"
    must_store: bool = False


class Graph:
    """Directed graph ``G = (V, E)`` with per-node costs ``T_v``, ``M_v``.

    Edges mean: ``(v, w) ∈ E`` iff the value of ``v`` is directly required to
    compute ``w``.
    """

    def __init__(
        self,
        nodes: Sequence[Node],
        edges: Iterable[Tuple[int, int]],
        cost_source: str = "",
    ):
        #: Provenance of the ``T_v`` values ("" = analytic / paper costs,
        #: ``"profile:<key>"`` = microbenchmark-calibrated,
        #: ``"compiled:<key>"`` = XLA cost_analysis-calibrated).  Non-empty
        #: sources are hashed into ``graph_digest`` so plans priced under
        #: different cost models never alias in the plan cache, even when the
        #: quantized T_v happen to coincide.
        self.cost_source: str = cost_source
        self.nodes: List[Node] = list(nodes)
        n = len(self.nodes)
        for i, node in enumerate(self.nodes):
            if node.idx != i:
                raise ValueError(f"node {node.name} has idx {node.idx}, expected {i}")
            if node.time <= 0 or node.memory <= 0:
                raise ValueError(
                    f"node {node.name}: costs must be positive "
                    f"(T={node.time}, M={node.memory})"
                )
        self.succ: List[List[int]] = [[] for _ in range(n)]
        self.pred: List[List[int]] = [[] for _ in range(n)]
        seen = set()
        for v, w in edges:
            if not (0 <= v < n and 0 <= w < n):
                raise ValueError(f"edge ({v},{w}) out of range")
            if v == w:
                raise ValueError(f"self loop at {v}")
            if (v, w) in seen:
                continue
            seen.add((v, w))
            self.succ[v].append(w)
            self.pred[w].append(v)
        self.edges: FrozenSet[Tuple[int, int]] = frozenset(seen)
        self._topo: Optional[List[int]] = None
        self._assert_acyclic()
        # Cost vectors.
        self.time_v: List[float] = [nd.time for nd in self.nodes]
        self.mem_v: List[float] = [nd.memory for nd in self.nodes]
        # Hard store pins (effect analysis): bit v set ⇔ nodes[v].must_store.
        self.store_pins_mask: int = to_mask(
            v for v, nd in enumerate(self.nodes) if nd.must_store
        )

    @property
    def store_pins(self) -> NodeSet:
        """Nodes pinned ``must_store`` by effect analysis (∅ when unanalyzed)."""
        return from_mask(self.store_pins_mask)

    # ------------------------------------------------------------------ basics

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n(self) -> int:
        return len(self.nodes)

    def _assert_acyclic(self) -> None:
        order = self.topological_order()
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")

    def topological_order(self) -> List[int]:
        """Kahn topological order; cached."""
        if self._topo is not None:
            return self._topo
        indeg = [len(p) for p in self.pred]
        stack = [v for v in range(len(self.nodes)) if indeg[v] == 0]
        order: List[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for w in self.succ[v]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    stack.append(w)
        if len(order) == len(self.nodes):
            self._topo = order
        return order

    # --------------------------------------------------------- paper notation

    def delta_plus(self, s: Iterable[int]) -> NodeSet:
        """δ⁺(S): nodes with an incoming edge from S."""
        out = set()
        for v in s:
            out.update(self.succ[v])
        return frozenset(out)

    def delta_minus(self, s: Iterable[int]) -> NodeSet:
        """δ⁻(S): nodes with an outgoing edge into S."""
        out = set()
        for v in s:
            out.update(self.pred[v])
        return frozenset(out)

    def is_lower_set(self, L: Iterable[int]) -> bool:
        """L ≺ V  ⇔  δ⁻(L) ⊆ L (no edge from V\\L into L)."""
        Ls = set(L)
        return all(p in Ls for v in Ls for p in self.pred[v])

    def boundary(self, L: Iterable[int]) -> NodeSet:
        """∂(L) = δ⁻(V \\ L) ∩ L — the nodes of L still needed outside L."""
        Ls = frozenset(L)
        comp = [v for v in range(len(self.nodes)) if v not in Ls]
        return self.delta_minus(comp) & Ls

    # ------------------------------------------------------------- aggregates

    def T(self, s: Iterable[int]) -> float:
        """T(S) = Σ_{v∈S} T_v."""
        return sum(self.time_v[v] for v in s)

    def M(self, s: Iterable[int]) -> float:
        """M(S) = Σ_{v∈S} M_v."""
        return sum(self.mem_v[v] for v in s)

    @property
    def total_time(self) -> float:
        return sum(self.time_v)

    @property
    def total_memory(self) -> float:
        return sum(self.mem_v)

    # ------------------------------------------------------------ reachability

    def reachable_from(self, v: int) -> NodeSet:
        """All nodes reachable from v (including v) following edges forward."""
        seen = {v}
        stack = [v]
        while stack:
            u = stack.pop()
            for w in self.succ[u]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return frozenset(seen)

    def ancestors_of(self, v: int) -> NodeSet:
        """L^v = {w | v reachable from w} — the principal lower set at v (§4.3)."""
        seen = {v}
        stack = [v]
        while stack:
            u = stack.pop()
            for w in self.pred[u]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return frozenset(seen)

    # --------------------------------------------------------------- closure

    def lower_closure(self, s: Iterable[int]) -> NodeSet:
        """Smallest lower set containing S (union of ancestor sets)."""
        out: set = set()
        for v in s:
            if v not in out:
                out.update(self.ancestors_of(v))
        return frozenset(out)

    # ------------------------------------------------------------- validation

    def check_increasing_sequence(self, seq: Sequence[NodeSet]) -> None:
        """Validate {L₁ ≺ … ≺ L_k = V}: each Lᵢ a lower set, strictly increasing,
        terminating at V."""
        if not seq:
            raise ValueError("empty sequence")
        prev: NodeSet = EMPTY
        for i, L in enumerate(seq):
            if not self.is_lower_set(L):
                raise ValueError(f"L_{i+1} is not a lower set")
            if not (prev < L):
                raise ValueError(f"L_{i+1} does not strictly contain L_{i}")
            prev = L
        if seq[-1] != frozenset(range(len(self.nodes))):
            raise ValueError("sequence must terminate at V")

    # ------------------------------------------------------------------ debug

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Graph(n={len(self.nodes)}, e={len(self.edges)})"


# ---------------------------------------------------------------------------
# Canonical hashing (plan-cache keys).
#
# ``graph_digest`` is a stable content address for (topology, quantized
# costs, kinds): invariant under node-id permutation, sensitive to any edge
# or cost change.  It is built in two steps:
#
#   1. Weisfeiler–Lehman refinement over both edge directions, seeded with
#      each node's quantized (T_v, M_v, kind) — permutation-invariant colors;
#   2. a canonical topological order (Kahn, ties broken by the canonical
#      positions of already-placed predecessors, then the WL color), which
#      yields an explicit relabeling so cached *plans* — not just digests —
#      transfer between isomorphic labelings (core.plan_cache stores lower-set
#      sequences in canonical coordinates).
#
# WL-equivalent non-automorphic nodes can in principle canonicalize
# differently across labelings; for the DP's DAGs this at worst costs a cache
# miss, never a wrong hit, because plan_cache re-validates every hit against
# the querying graph.
# ---------------------------------------------------------------------------


def _qcost(x: float, sig: int) -> str:
    """Quantize a cost to ``sig`` significant digits (string form, stable)."""
    return f"{float(x):.{sig}g}"


def _h(*parts: object) -> bytes:
    m = hashlib.sha256()
    for p in parts:
        if isinstance(p, bytes):
            m.update(p)
        else:
            m.update(str(p).encode())
        m.update(b"\x1f")
    return m.digest()


def _wl_colors(g: Graph, cost_sig: int) -> List[bytes]:
    """Permutation-invariant per-node colors (bidirectional WL refinement)."""
    colors = [
        _h("node", _qcost(nd.time, cost_sig), _qcost(nd.memory, cost_sig), nd.kind,
           *(("pin",) if nd.must_store else ()))
        for nd in g.nodes
    ]
    rounds = min(g.n, 16) + 1
    for _ in range(rounds):
        colors = [
            _h(
                colors[v],
                b"pred", *sorted(colors[p] for p in g.pred[v]),
                b"succ", *sorted(colors[s] for s in g.succ[v]),
            )
            for v in range(g.n)
        ]
    return colors


def canonical_order(g: Graph, cost_sig: int = 12) -> List[int]:
    """Canonical topological order: position → original node id.

    Deterministic for a given graph and identical (up to automorphism) for
    isomorphic graphs: Kahn's algorithm where the next node is the ready node
    with the lexicographically smallest (canonical-pred-positions, WL-color)
    key.  Cached per (graph, cost_sig) — Graphs are immutable after init.
    """
    cache = getattr(g, "_canon_cache", None)
    if cache is None:
        cache = {}
        g._canon_cache = cache
    if cost_sig in cache:
        return cache[cost_sig][0]

    colors = _wl_colors(g, cost_sig)
    pos: Dict[int, int] = {}
    indeg = [len(p) for p in g.pred]
    ready = [v for v in range(g.n) if indeg[v] == 0]
    order: List[int] = []
    while ready:
        best = min(
            ready, key=lambda v: (tuple(sorted(pos[p] for p in g.pred[v])), colors[v])
        )
        ready.remove(best)
        pos[best] = len(order)
        order.append(best)
        for w in g.succ[best]:
            indeg[w] -= 1
            if indeg[w] == 0:
                ready.append(w)

    digest = hashlib.sha256()
    digest.update(f"G|{g.n}|{len(g.edges)}".encode())
    if getattr(g, "cost_source", ""):
        digest.update(_h("cost_source", g.cost_source))
    for i, v in enumerate(order):
        nd = g.nodes[v]
        preds = sorted(pos[p] for p in g.pred[v])
        digest.update(
            _h(i, _qcost(nd.time, cost_sig), _qcost(nd.memory, cost_sig),
               nd.kind, *preds, *(("pin",) if nd.must_store else ()))
        )
    cache[cost_sig] = (order, digest.hexdigest())
    return order


def graph_digest(g: Graph, cost_sig: int = 12) -> str:
    """Stable content digest of (topology, quantized costs, kinds).

    Equal for isomorphic graphs regardless of node numbering; different
    whenever an edge, a cost (beyond ``cost_sig`` significant digits), or a
    node kind differs.  This is the plan cache's graph key.
    """
    canonical_order(g, cost_sig)
    return g._canon_cache[cost_sig][1]


def canonical_maps(g: Graph, cost_sig: int = 12) -> Tuple[Dict[int, int], List[int]]:
    """(node id → canonical position, canonical position → node id)."""
    order = canonical_order(g, cost_sig)
    return {v: i for i, v in enumerate(order)}, order


# ---------------------------------------------------------------------------
# Constructors for common topologies (used by tests and benchmarks).
# ---------------------------------------------------------------------------


def chain(n: int, time: float = 1.0, memory: float = 1.0, **kw: Any) -> Graph:
    """A simple path v₀ → v₁ → … → v_{n-1} (feed-forward net)."""
    nodes = [Node(i, f"v{i}", time, memory, **kw) for i in range(n)]
    return Graph(nodes, [(i, i + 1) for i in range(n - 1)])


def from_cost_lists(
    times: Sequence[float],
    mems: Sequence[float],
    edges: Iterable[Tuple[int, int]],
    names: Optional[Sequence[str]] = None,
    kinds: Optional[Sequence[str]] = None,
) -> Graph:
    n = len(times)
    assert len(mems) == n
    names = names or [f"v{i}" for i in range(n)]
    kinds = kinds or ["generic"] * n
    nodes = [Node(i, names[i], times[i], mems[i], kinds[i]) for i in range(n)]
    return Graph(nodes, edges)
