"""Apply the paper's planner to the production models.

One instance of the unified pipeline (carrier → Planner → lowering): the
carrier here is the unit-granularity *chain graph* of the scan-over-units
LM, the Planner is the shared process-default one (plan cache + budget
sweep + lazy cap extension), and the lowering is the scan-chain projection
of the ``"segment"`` backend (``segments_from_result`` →
``models.transformer`` ``segment_sizes``).

The scan-over-units LM is, at unit granularity, a *chain* — and on a chain
the lower-set lattice is exactly the set of prefixes, so the DP solution is
the true optimum (DESIGN.md §3).  Each unit is modelled as two nodes:

  interior  (M_v = unit's interior activation bytes, T_v = unit FLOPs)
  boundary  (M_v = bytes of the unit output h = (B_loc, S_loc, d),  T_v ≈ 0)

so eq. (2)'s ``2M(V_i)`` sees the real working set while the cached
boundary ∂(L_i) costs only the h tensor — the same accounting XLA applies to
the per-segment ``jax.checkpoint`` this plan lowers to (models.transformer
``segment_sizes``).

Budget: per-device HBM minus params+optimizer+workspace, i.e. the activation
budget the paper's B represents (§3 "budget semantics on TPU").
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import Graph
from repro.core.dp import DPResult, quantize_times
from repro.core.graph import Node
from repro.core.planner import get_default_planner
from repro.launch.mesh import HBM_BYTES
from repro.models.transformer import unit_pattern


@dataclasses.dataclass(frozen=True)
class PlanInputs:
    n_units: int
    bytes_boundary: float  # unit output h, per device
    bytes_interior: float  # unit interior activations, per device
    flops_unit: float
    budget: float


def activation_expansion(cfg: ModelConfig, model_shards: int = 1) -> float:
    """Interior-activation bytes of one unit, in units of the h tensor.

    Tensors whose live axis is TP-sharded (ffn hidden, q/k/v heads, expert
    rows) are divided by ``model_shards`` — the planner budgets *per-device*
    bytes, matching the sharded step it lowers to.
    """
    d = cfg.d_model
    replicated = 6.0  # ln outs, attn/ssm out, residual adds (batch-sharded only)
    sharded = 0.0
    if cfg.d_ff > 0:
        sharded += 3.0 * cfg.d_ff / d  # gate/up/act
    heads_dim = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim / d  # q,k,v
    if cfg.n_kv_heads % model_shards == 0 and cfg.n_heads % model_shards == 0:
        sharded += heads_dim
    else:
        replicated += heads_dim  # divisibility guard replicates these
    if cfg.moe is not None:
        e_term = cfg.moe.capacity_factor * cfg.moe.top_k * 3.0 * cfg.moe.d_ff_expert / d
        if cfg.moe.num_experts % model_shards == 0:
            sharded += e_term
        else:
            replicated += e_term
    if cfg.ssm is not None:
        sharded += 2.0 * cfg.ssm.expand  # z / x branches (ffn-sharded)
    kinds, _ = unit_pattern(cfg)
    return (replicated + sharded / max(model_shards, 1)) * len(kinds)


def unit_flops(cfg: ModelConfig, tokens: int) -> float:
    """Forward FLOPs of one unit (≈ 2 · active-params-per-unit · tokens)."""
    kinds, n_units = unit_pattern(cfg)
    per_unit_params = (cfg.num_active_params() - 2 * cfg.vocab_size * cfg.d_model) / max(
        n_units, 1
    )
    return 2.0 * max(per_unit_params, 1.0) * tokens


def chain_graph(pi: PlanInputs) -> Graph:
    """2-node-per-unit chain: interior → boundary → interior → …"""
    nodes = []
    edges = []
    for u in range(pi.n_units):
        i_int = 2 * u
        nodes.append(
            Node(i_int, f"u{u}_interior", max(pi.flops_unit, 1.0), max(pi.bytes_interior, 1.0), "unit")
        )
        nodes.append(
            Node(i_int + 1, f"u{u}_out", 1.0, max(pi.bytes_boundary, 1.0), "boundary")
        )
        edges.append((i_int, i_int + 1))
        if u:
            edges.append((i_int - 1, i_int))
    return Graph(nodes, edges)


def static_bytes(cfg: ModelConfig, model_shards: int, fsdp_shards: int = 1) -> float:
    """Per-device params (f32) + AdamW mu/nu (f32)."""
    return cfg.num_params() * (4 + 8) / max(model_shards, 1) / max(fsdp_shards, 1)


def needs_fsdp(cfg: ModelConfig, model_shards: int,
               hbm_bytes: float = HBM_BYTES) -> bool:
    """TP-only static state over ~35% of HBM → also shard params over data."""
    return static_bytes(cfg, model_shards) > 0.35 * hbm_bytes


def plan_inputs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    n_micro: int = 1,
    hbm_bytes: float = HBM_BYTES,
    act_bytes: int = 2,  # bf16
) -> PlanInputs:
    _, n_units = unit_pattern(cfg)
    b_loc = max(1, shape.global_batch // max(dp_shards, 1) // max(n_micro, 1))
    s_loc = shape.seq_len // max(seq_shards, 1)
    h_full = b_loc * s_loc * cfg.d_model * act_bytes
    # boundary caches are sequence-parallel (models shard(h, batch, seq_act))
    h_boundary = h_full / max(model_shards, 1)
    # interior: ~2h of gathered full-sequence tensors (attention k/v/ctx) plus
    # the rest either feature-sharded (activation_expansion already divides
    # those by tp) or sequence-shardable under SP — halve the replicated part
    # as the conservative middle ground between the two GSPMD layouts.
    interior = h_full * (2.0 + activation_expansion(cfg, model_shards) / 2.0)
    flops = unit_flops(cfg, b_loc * s_loc)
    fsdp = dp_shards if needs_fsdp(cfg, model_shards, hbm_bytes) else 1
    static = static_bytes(cfg, model_shards, fsdp)
    if n_micro > 1:
        static += cfg.num_params() * 4 / max(model_shards, 1) / max(fsdp, 1)  # grad accum f32
    budget = max(hbm_bytes - static, 0.05 * hbm_bytes)
    return PlanInputs(
        n_units=n_units,
        bytes_boundary=float(h_boundary),
        bytes_interior=float(interior),
        flops_unit=float(flops),
        budget=float(budget),
    )


def segments_from_result(
    res: DPResult, n_units: int
) -> Tuple[Tuple[int, ...], Tuple[bool, ...]]:
    """Lower-set sequence on the 2-node chain → (group sizes, remat flags).

    This is the scan-chain projection of the ``"segment"`` lowering backend
    (``core.lowering.segment.segment_groups``), specialized to the
    interior/boundary 2-node unit encoding of :func:`chain_graph`.

    On the chain, ∂(L) = {max(L)}: a lower set ending at a unit's *interior*
    node caches that interior — the unit runs unwrapped (vanilla residuals,
    no recompute).  Lower sets ending at *boundary* nodes delimit
    jax.checkpoint groups whose interiors are recomputed.  With ample budget
    the time-centric DP caches everything (overhead 0 = vanilla); under
    pressure it mixes — exactly the paper's trade, lowered to XLA.
    """
    cached_units = set()
    end_units = []
    for L in res.sequence:
        m = max(L)
        if m % 2 == 0:
            cached_units.add(m // 2)
        else:
            end_units.append(m // 2)
    sizes: list = []
    remat: list = []

    def emit(lo: int, hi: int) -> None:
        """units [lo, hi] — split into maximal cached/uncached runs."""
        u = lo
        while u <= hi:
            flag = u in cached_units
            v = u
            while v + 1 <= hi and ((v + 1) in cached_units) == flag:
                v += 1
            sizes.append(v - u + 1)
            remat.append(not flag)
            u = v + 1

    prev = -1
    for e in end_units:
        if e > prev:
            emit(prev + 1, e)
            prev = e
    if prev < n_units - 1:
        emit(prev + 1, n_units - 1)
    return tuple(sizes), tuple(remat)


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    sizes: Tuple[int, ...]
    remat: Tuple[bool, ...]
    n_micro: int = 1

    @property
    def n_segments(self) -> int:
        return len(self.sizes)


def _dp_chain_graph(pi: PlanInputs, measured: Optional[bool] = None) -> Graph:
    """Chain graph with the DP's integer t-axis.

    With measured costs (``measured=True`` or ``REPRO_MEASURED_COSTS=1``) the
    interior/boundary nodes are priced by the profiled cost model
    (FLOPs·matmul-rate vs bytes·HBM-rate) before quantization, so the DP
    trades real seconds, not FLOP proxies.  Default stays analytic —
    profiling costs a one-off timing run per backend.
    """
    raw = chain_graph(pi)
    if measured is None:
        measured = bool(os.environ.get("REPRO_MEASURED_COSTS"))
    if measured:
        from repro.core.cost_model import calibrated_graph, load_or_profile

        return calibrated_graph(raw, load_or_profile(), levels=32)
    return quantize_times(raw, levels=32)


def plan_unit_segments(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    n_micro: int = 1,
    budget: Optional[float] = None,
    objective: str = "time_centric",
    measured_costs: Optional[bool] = None,
) -> Tuple[SegmentPlan, DPResult]:
    """One-call front door used by the launchers and the dry-run.

    Solves through the process-default ``Planner``: repeated cells of the
    dry-run matrix, microbatch escalation retries, and job restarts hit the
    plan cache instead of re-running the exact DP.
    """
    pi = plan_inputs(cfg, shape, dp_shards, seq_shards, model_shards, n_micro)
    g = _dp_chain_graph(pi, measured_costs)
    B = budget if budget is not None else pi.budget
    res = get_default_planner().solve(g, B, "exact_dp", objective)
    if not res.feasible:
        sp = SegmentPlan(tuple(1 for _ in range(pi.n_units)),
                         tuple(True for _ in range(pi.n_units)), n_micro)
        return sp, res
    sizes, remat = segments_from_result(res, pi.n_units)
    return SegmentPlan(sizes, remat, n_micro), res


def plan_with_microbatching(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    objective: str = "time_centric",
    max_micro: int = 16,
) -> Tuple[SegmentPlan, DPResult]:
    """§5.1 protocol, production edition: find the smallest gradient-
    accumulation factor for which the general recomputation problem has a
    solution, then take the DP-optimal canonical strategy at that factor.

    Each escalation step is a frontier lookup: the planner's budget sweep
    for the candidate chain graph yields the *exact* minimal feasible
    budget, so infeasible factors are rejected by one comparison instead of
    a full budgeted DP — and the final ``plan_unit_segments`` solve reuses
    the same cached sweep.
    """
    b_loc = max(1, shape.global_batch // max(dp_shards, 1))
    planner = get_default_planner()
    n_micro = 1
    while n_micro <= min(max_micro, b_loc):
        pi = plan_inputs(cfg, shape, dp_shards, seq_shards, model_shards,
                         n_micro)
        g = _dp_chain_graph(pi)
        if planner.min_feasible_budget(g, "exact_dp") <= pi.budget:
            return plan_unit_segments(
                cfg, shape, dp_shards, seq_shards, model_shards, n_micro,
                objective=objective,
            )
        n_micro *= 2
    return plan_unit_segments(
        cfg, shape, dp_shards, seq_shards, model_shards,
        min(max_micro, b_loc), objective=objective,
    )
