"""Budget-sweep engine (PR 2): one DP pass = the whole (budget → plan)
Pareto surface, bit-identical to the per-budget DP it subsumes.

The property-based cross-check here is the oracle that pins the eq. 1 /
memory-functional bookkeeping inside the DP transitions (eq. 2's peak is
replaced by the liveness-tight ``transition_excess`` charge since PR 5):
for random DAGs, both objectives, and budgets spanning infeasible → ample,

  * ``Sweep.solve(B)`` returns exactly ``dp.solve(g, B, family, objective)``
    (same lower-set sequence, same overhead, same feasibility);
  * the reported overhead/peak equal the strategy evaluators
    ``dp.overhead`` / ``dp.peak_memory`` recomputed from the sequence;
  * the terminal frontier's minimum is the exact minimal feasible budget
    (feasible itself, infeasible just below, ≤ the retired binary search).
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dp as dp_mod
from repro.core.dp import (
    Sweep,
    SweepOverflow,
    decode_sweep,
    min_feasible_budget_exact,
    overhead,
    peak_memory_live,
    solve,
    sweep,
)
from repro.core.graph import canonical_maps, chain
from repro.core.lower_sets import all_lower_sets, pruned_lower_sets
from repro.core.planner import Planner, _min_feasible_budget_uncached
from repro.core.plan_cache import PlanCache

from conftest import random_dag


def _budget_grid(sw: Sweep, n: int = 8):
    """Budgets spanning infeasible → ample, plus every critical budget."""
    mfb = sw.min_feasible_budget()
    grid = {mfb * (0.5 + 3.0 * i / (n - 1)) for i in range(n)}
    grid |= {b for b, _ in sw.frontier()}
    grid |= {mfb, mfb * (1.0 - 1e-9), 1e12}
    return sorted(grid)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 7), st.booleans(), st.booleans())
def test_sweep_bit_identical_to_per_budget_solve(seed, n, topo, exact_family):
    r = random.Random(seed)
    g = random_dag(r, n, topo_ids=topo)
    fam = all_lower_sets(g) if exact_family else pruned_lower_sets(g)
    for objective in ("time_centric", "memory_centric"):
        sw = sweep(g, fam, objective)
        for B in _budget_grid(sw):
            ref = solve(g, B, fam, objective)
            got = sw.solve(g, B)
            assert got.feasible == ref.feasible
            if ref.feasible:
                assert got.sequence == ref.sequence  # bit-identical plan
                assert got.overhead == ref.overhead
                assert got.peak_memory == ref.peak_memory
                # eq. 1 / liveness-functional oracles on the strategy
                assert got.overhead == pytest.approx(overhead(g, got.sequence))
                assert got.peak_memory == pytest.approx(
                    peak_memory_live(g, got.sequence))
                assert got.peak_memory <= B + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 7))
def test_exact_min_feasible_budget(seed, n):
    """Terminal-frontier min == scalar one-pass DP == tight and feasible,
    and the retired binary search lands within its tolerance above it."""
    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g)
    mfb = min_feasible_budget_exact(g, fam)
    for objective in ("time_centric", "memory_centric"):
        assert sweep(g, fam, objective).min_feasible_budget() == mfb
    assert solve(g, mfb, fam).feasible
    assert not solve(g, mfb * (1.0 - 1e-9), fam).feasible
    tol = 1e-3
    bs = _min_feasible_budget_uncached(g, tol=tol, family=fam)
    assert mfb <= bs + 1e-9
    assert bs <= mfb * (1.0 + 2.0 * tol) + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.floats(1.1, 3.0))
def test_capped_sweep_matches_below_cap(seed, n, span):
    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g)
    full = sweep(g, fam)
    cap = full.min_feasible_budget() * span
    capped = sweep(g, fam, cap=cap)
    for B in [b for b in _budget_grid(full) if b <= cap]:
        ref = solve(g, B, fam)
        got = capped.solve(g, B)
        assert got.feasible == ref.feasible
        if ref.feasible:
            assert got.sequence == ref.sequence
    with pytest.raises(ValueError):
        capped.extract(cap * 2.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.booleans())
def test_extend_bit_identical_to_fresh_sweep(seed, n, exact_family):
    """Lazy cap extension: growing a capped surface with ``Sweep.extend``
    is bit-identical to building a fresh sweep at the larger cap — at every
    budget (incl. ulp-adjacent), on the frontier, and on the exact minimal
    feasible budget — and the full extension matches the uncapped sweep."""
    import math

    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g) if exact_family else pruned_lower_sets(g)
    for objective in ("time_centric", "memory_centric"):
        full = sweep(g, fam, objective)
        caps = sorted({b for b, _ in full.frontier()})
        if len(caps) < 2:
            continue
        prior = sweep(g, fam, objective, cap=caps[0])
        ext = prior.extend(g, cap=caps[-1])
        fresh = sweep(g, fam, objective, cap=caps[-1])
        assert ext.cap == fresh.cap
        probes = set()
        for b in caps:
            probes |= {b, math.nextafter(b, 0.0), math.nextafter(b, math.inf)}
        for B in sorted(p for p in probes if p <= caps[-1]):
            assert ext.extract(B) == fresh.extract(B)
        assert ext.frontier() == fresh.frontier()
        assert ext.min_feasible_budget() == fresh.min_feasible_budget()
        # extend to the full (uncapped) surface
        ext_full = prior.extend(g)
        assert ext_full.cap is None
        for B in sorted(probes) + [caps[-1] * 3.0]:
            assert ext_full.extract(B) == full.extract(B)
        # extending to a smaller/equal cap is a no-op (cap only grows)
        assert prior.extend(g, cap=caps[0]) is prior
        assert full.extend(g, cap=caps[0]) is full


def test_planner_extends_cached_sweep_instead_of_rebuilding(rng):
    """A grid whose max budget outgrows the cached capped sweep extends it:
    the cache entry is replaced (key is budget-free) and the answers stay
    bit-identical to per-budget solves."""
    g = random_dag(rng, 6)
    c = PlanCache()
    p = Planner(cache=c)
    fam = all_lower_sets(g)
    mfb = p.min_feasible_budget(g, "exact_dp")
    small = p.solve_grid(g, [mfb, mfb * 1.2], "exact_dp")
    sw_small = p._cached_sweep(p.prepare(g), "exact_dp", "time_centric")
    assert sw_small is not None and sw_small.cap is not None
    budgets = [mfb * (1.0 + 3.0 * i / 7) for i in range(8)]
    grid = p.solve_grid(g, budgets, "exact_dp")
    sw_big = p._cached_sweep(p.prepare(g), "exact_dp", "time_centric")
    assert sw_big.cap is not None and sw_big.cap >= max(budgets)
    for got, ref in zip(grid, [solve(g, B, fam) for B in budgets]):
        assert got.feasible == ref.feasible
        assert got.sequence == ref.sequence
        assert got.overhead == ref.overhead
    # frontier() grows the same surface to the full (uncapped) one
    crit = p.frontier(g, "exact_dp")
    assert crit == sweep(g, fam).frontier()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_sweep_serialization_roundtrip(seed, n):
    """encode → JSON → decode preserves the whole extraction surface, through
    canonical coordinates (the plan cache's storage form)."""
    import json

    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g)
    sw = sweep(g, fam)
    to_pos, from_pos = canonical_maps(g)
    entry = json.loads(json.dumps(sw.to_canonical(to_pos).encode()))
    back = decode_sweep(entry).remap({p: v for p, v in enumerate(from_pos)})
    assert back.min_feasible_budget() == sw.min_feasible_budget()
    assert back.frontier() == sw.frontier()
    for B in _budget_grid(sw):
        a, b = sw.solve(g, B), back.solve(g, B)
        assert a.feasible == b.feasible and a.sequence == b.sequence


def test_decode_sweep_rejects_garbage():
    assert decode_sweep({"objective": "nope"}) is None
    assert decode_sweep({}) is None
    assert decode_sweep({"objective": "time_centric", "n": 2,
                         "family": [[0]], "cells": [[]]}) is None


def test_sweep_overflow_is_deterministic(rng):
    g = random_dag(rng, 6)
    fam = all_lower_sets(g)
    with pytest.raises(SweepOverflow):
        sweep(g, fam, max_states=1)
    with pytest.raises(SweepOverflow):
        sweep(g, fam, max_states=1)


def test_frontier_staircase_monotone(rng):
    for _ in range(10):
        g = random_dag(rng, 6)
        fam = all_lower_sets(g)
        tc = sweep(g, fam, "time_centric").frontier()
        assert all(b1 < b2 and t1 > t2
                   for (b1, t1), (b2, t2) in zip(tc, tc[1:]))
        mc = sweep(g, fam, "memory_centric").frontier()
        assert all(b1 < b2 and t1 < t2
                   for (b1, t1), (b2, t2) in zip(mc, mc[1:]))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_float_memory_ulp_thresholds(seed, n):
    """Regression: with non-dyadic float memories (the shape the measured
    cost model produces), the exact min budget must sit on the per-budget
    DP's own float feasibility threshold — feasible at B, infeasible one
    ulp below — and extraction must stay bit-identical at ulp-adjacent
    budgets.  This requires the sweep and the scalar pass to carry the
    same float expressions as ``solve`` (no re-associated closed forms,
    which drift by ulps and move thresholds)."""
    import math

    from repro.core.graph import Graph, Node

    r = random.Random(seed)
    edges = [(i, j) for i in range(n) for j in range(i + 1, n)
             if r.random() < 0.35]
    g = Graph(
        [Node(i, f"v{i}", r.choice([1.0, 10.0]), r.uniform(1e3, 1e9))
         for i in range(n)],
        edges,
    )
    fam = all_lower_sets(g)
    mfb = min_feasible_budget_exact(g, fam)
    assert solve(g, mfb, fam).feasible
    assert not solve(g, math.nextafter(mfb, 0.0), fam).feasible
    sw = sweep(g, fam)
    assert sw.min_feasible_budget() == mfb
    probes = set()
    for b, _ in sw.frontier():
        probes |= {b, math.nextafter(b, 0.0), math.nextafter(b, math.inf)}
    for B in sorted(probes):
        ref = solve(g, B, fam)
        got = sw.solve(g, B)
        assert got.feasible == ref.feasible
        if ref.feasible:
            assert got.sequence == ref.sequence
            assert got.overhead == ref.overhead


# ----------------------------------------------------------- planner route


def test_planner_grid_one_sweep_bit_identical(rng):
    """Acceptance: one sweep answers an 8-point grid bit-identically to
    per-budget solves, from a single cache entry."""
    g = random_dag(rng, 6)
    c = PlanCache()
    p = Planner(cache=c)
    mfb = p.min_feasible_budget(g, "exact_dp")
    budgets = [mfb * (1.0 + 3.0 * i / 7) for i in range(8)]
    grid = p.solve_grid(g, budgets, "exact_dp")
    assert c.stats()["misses"] == 1  # one cold sweep admitted all 8 budgets
    fresh = [solve(g, B, all_lower_sets(g)) for B in budgets]
    for got, ref in zip(grid, fresh):
        assert got.feasible == ref.feasible
        assert got.sequence == ref.sequence
        assert got.overhead == ref.overhead
    # later single-budget solves on the swept graph are frontier lookups
    again = p.solve(g, budgets[3], "exact_dp")
    assert again.sequence == fresh[3].sequence
    assert c.stats()["misses"] == 1  # no new DP, no new cache entry


def test_planner_sweep_shared_across_processes(tmp_path, rng):
    """A sweep cached on disk by one planner serves budgets a second planner
    (≈ another process) never solved."""
    g = random_dag(rng, 5)
    store = str(tmp_path / "plans")
    p1 = Planner(cache=PlanCache(cache_dir=store))
    mfb = p1.min_feasible_budget(g, "exact_dp")
    p1.solve_grid(g, [mfb, mfb * 2.0], "exact_dp")
    c2 = PlanCache(cache_dir=store)
    p2 = Planner(cache=c2)
    res = p2.solve(g, mfb * 1.5, "exact_dp")  # budget p1 never solved
    assert c2.stats()["disk_hits"] == 1
    assert res.sequence == solve(g, mfb * 1.5, all_lower_sets(g)).sequence


def test_planner_grid_overflow_falls_back(rng):
    g = random_dag(rng, 6)
    p = Planner(cache=PlanCache(), sweep_max_states=1)
    mfb = p.min_feasible_budget(g, "exact_dp")
    budgets = [mfb, mfb * 1.5, mfb * 3.0]
    grid = p.solve_grid(g, budgets, "exact_dp")
    fresh = [solve(g, B, all_lower_sets(g)) for B in budgets]
    for got, ref in zip(grid, fresh):
        assert got.sequence == ref.sequence and got.overhead == ref.overhead


def test_planner_min_budget_exact_and_cached(rng):
    g = random_dag(rng, 6)
    c = PlanCache()
    p = Planner(cache=c)
    b1 = p.min_feasible_budget(g, "exact_dp")
    b2 = p.min_feasible_budget(g, "exact_dp")  # aux-cache hit
    assert b1 == b2 == min_feasible_budget_exact(g, all_lower_sets(g))
    assert p.solve(g, b1, "exact_dp").feasible
    assert not p.solve(g, b1 * (1.0 - 1e-9), "exact_dp").feasible


def test_corrupt_sweep_entry_degrades_to_per_budget(tmp_path, rng):
    import os

    g = random_dag(rng, 5)
    store = str(tmp_path / "plans")
    p1 = Planner(cache=PlanCache(cache_dir=store))
    mfb = p1.min_feasible_budget(g, "exact_dp")
    ref = p1.solve_grid(g, [mfb * 1.2], "exact_dp")[0]
    for root, _dirs, files in os.walk(store):
        for f in files:
            with open(os.path.join(root, f), "w") as fh:
                fh.write('{"version": 1, "kind": "sweep", "cells": "junk"}')
    p2 = Planner(cache=PlanCache(cache_dir=store))
    res = p2.solve(g, mfb * 1.2, "exact_dp")  # no crash, correct plan
    assert res.sequence == ref.sequence


def test_min_feasible_budget_is_min_simulated_live_peak(rng):
    """End-to-end anchor for the liveness functional: the exact §5.1
    minimum equals the min over ALL canonical strategies of the *simulated*
    last-use-liveness execution peak (tiny graphs, exhaustive enumeration
    of increasing sequences)."""
    from repro.core.liveness import simulate

    for _ in range(8):
        g = random_dag(rng, rng.randint(2, 4))
        fam = all_lower_sets(g)
        steps = [L for L in fam if L]
        full = frozenset(range(g.n))
        best = [float("inf")]

        def rec(cur, seq):
            if cur == full:
                pk = simulate(g, seq, liveness=True).peak_memory
                if pk < best[0]:
                    best[0] = pk
                return
            for L in steps:
                if cur < L:
                    seq.append(L)
                    rec(L, seq)
                    seq.pop()

        rec(frozenset(), [])
        assert min_feasible_budget_exact(g, fam) == best[0]


# ------------------------------------------------------ satellite bugfixes


def test_quantize_times_degenerate_graphs():
    from repro.core.graph import Graph

    empty = Graph([], [])
    assert dp_mod.quantize_times(empty) is empty
    g = chain(4)
    g.time_v = [0.0] * 4  # pure-view subgraph assembled past the ctor
    assert dp_mod.quantize_times(g) is g


def test_exact_family_limit_single_source_of_truth():
    import inspect

    from repro.core.lower_sets import DEFAULT_LOWER_SET_LIMIT, all_lower_sets

    sig = inspect.signature(all_lower_sets)
    assert sig.parameters["limit"].default == DEFAULT_LOWER_SET_LIMIT
    # dp.exact_dp defaults to the same limit (None → shared constant)
    sig = inspect.signature(dp_mod.exact_dp)
    assert sig.parameters["limit"].default is None


def test_planner_falls_back_to_pruned_family_over_limit(rng, caplog):
    """A graph whose 𝓛_G overflows the limit plans via the pruned family
    with a logged note instead of surfacing RuntimeError."""
    import logging

    from repro.core import lower_sets as ls
    from repro.core.planner import _family

    g = random_dag(rng, 7, p=0.05)  # sparse → wide antichains, many ideals
    orig = ls.DEFAULT_LOWER_SET_LIMIT
    try:
        ls.DEFAULT_LOWER_SET_LIMIT = 4  # force the overflow
        with caplog.at_level(logging.WARNING, "repro.core.planner"):
            fam = _family(g, "exact_dp")
        assert sorted(fam, key=lambda s: (len(s), sorted(s))) == \
            pruned_lower_sets(g)
        assert any("pruned" in rec.message for rec in caplog.records)
    finally:
        ls.DEFAULT_LOWER_SET_LIMIT = orig


def test_binary_search_bracket_and_feasibility(rng):
    """Satellite: the search bracket is [max_v M_v, 2·M(V) + max_v M_v] and
    the returned budget is itself feasible even at coarse tolerance."""
    from repro.core.dp import _prepare, feasible

    for tol in (0.5, 1e-1, 1e-3):
        g = random_dag(rng, 6)
        fam = all_lower_sets(g)
        b = _min_feasible_budget_uncached(g, tol=tol, family=fam)
        assert max(g.mem_v) <= b <= 2.0 * g.total_memory + max(g.mem_v)
        assert feasible(g, b, fam, _prepare(g, fam))
