"""Table 2 (Appendix C) — the liveness-analysis ablation: same protocol as
Table 1 with liveness disabled in the simulator."""

from .table1_memory import main as _table1_main


def main(nets=None):
    return _table1_main(liveness=False, nets=nets)


if __name__ == "__main__":
    main()
