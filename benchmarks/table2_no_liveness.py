"""Table 2 (Appendix C) — liveness ablations, two ways.

1. **Simulator ablation** (the paper's Table 2): rerun the Table 1 protocol
   with last-use liveness disabled in the event simulator
   (:func:`ablation`, kept for ``benchmarks.run``'s paper-claims check).

2. **Functional gap report** (PR 5): how much of eq. 2's analytic peak was
   slack.  For each network and objective the DP is solved twice — under
   the paper's original eq. 2 charge (``functional="eq2"``) and under the
   liveness-tight functional the planner now uses — each at its own exact
   minimal feasible budget, and each realized schedule is scored three
   ways:

       eq. 2 peak   —  dp.peak_memory        (the old analytic model)
       live  peak   —  dp.peak_memory_live   (the new functional)
       measured     —  liveness.simulate(..., liveness=True).peak_memory

   Before PR 5 the gap ``eq. 2 − measured`` was pure over-charge (the DP
   rejected strategies the hardware could run); after, ``live == measured``
   by construction and the min feasible budget / per-budget overhead drop.

``--smoke`` asserts the acceptance ordering on a trimmed network set and
exits 1 on violation (wired into CI):

  * measured == liveness-aware analytic peak (the oracle property),
  * liveness-aware peak ≤ eq. 2 peak for the same strategy,
  * the exact min feasible budget does not increase,
  * overhead at eq. 2's min budget does not increase.

Every run writes ``BENCH_table2.json`` at the repo root (alongside
``BENCH_dp_runtime.json``) so the gap trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, Optional

from repro.core.dp import (
    min_feasible_budget_exact,
    peak_memory,
    peak_memory_live,
    solve,
)
from repro.core.liveness import simulate
from repro.core.lower_sets import pruned_lower_sets

from .networks import NETWORKS
from .table1_memory import main as _table1_main

SMOKE_NETS = ("vgg19", "unet")


def ablation(nets=None):
    """The paper's Table 2: Table 1's protocol with liveness disabled in the
    simulator (Appendix C)."""
    return _table1_main(liveness=False, nets=nets)


def gap_rows(nets) -> Dict[str, Dict]:
    """Per network: eq. 2 vs liveness-aware analytic peaks vs measured."""
    print("\n== eq. 2 vs liveness-aware functional (peaks in GB) ==")
    print(f"{'network':12s} {'obj':>3s} {'B_eq2':>7s} {'B_live':>7s} "
          f"{'ratio':>6s} {'eq2_pk':>7s} {'live_pk':>7s} {'measured':>8s} "
          f"{'oh@B_eq2':>9s} {'':>1s}{'(was)':>6s} {'t_s':>6s}")
    out: Dict[str, Dict] = {}
    for name in nets:
        g = NETWORKS[name]()
        fam = pruned_lower_sets(g)
        t0 = time.perf_counter()
        b_eq2 = min_feasible_budget_exact(g, fam, functional="eq2")
        b_live = min_feasible_budget_exact(g, fam, functional="liveness")
        row: Dict = {"n": g.n, "min_budget_eq2": b_eq2,
                     "min_budget_live": b_live}
        for objective, key in (("time_centric", "tc"),
                               ("memory_centric", "mc")):
            # the new world: plan at the liveness-exact minimal budget
            res = solve(g, b_live, fam, objective)
            seq = res.sequence
            eq2_pk = peak_memory(g, seq)
            live_pk = peak_memory_live(g, seq)
            measured = simulate(g, seq, liveness=True).peak_memory
            # per-budget overhead at the OLD functional's minimal budget —
            # the like-for-like "does the same budget buy less recompute"
            oh_live = solve(g, b_eq2, fam, objective).overhead
            oh_eq2 = solve(g, b_eq2, fam, objective,
                           functional="eq2").overhead
            row[key] = {
                "eq2_peak": eq2_pk,
                "live_peak": live_pk,
                "measured": measured,
                "overhead_at_Beq2_live": oh_live,
                "overhead_at_Beq2_eq2": oh_eq2,
                "overhead_at_Blive": res.overhead,
                "segments": res.num_segments,
            }
            print(f"{name:12s} {key:>3s} {b_eq2/1e9:7.2f} {b_live/1e9:7.2f} "
                  f"{b_live/b_eq2:6.3f} {eq2_pk/1e9:7.2f} {live_pk/1e9:7.2f} "
                  f"{measured/1e9:8.2f} {oh_live:9.0f} {oh_eq2:7.0f} "
                  f"{time.perf_counter() - t0:6.1f}")
        row["seconds"] = time.perf_counter() - t0
        out[name] = row
    return out


def check_gap(rows: Dict[str, Dict]) -> list:
    """Acceptance guards (returned as a list of failure strings)."""
    failures = []
    for name, r in rows.items():
        if not (r["min_budget_live"] <= r["min_budget_eq2"] * (1 + 1e-12)):
            failures.append(
                f"{name}: liveness min budget {r['min_budget_live']:.4g} "
                f"above eq. 2's {r['min_budget_eq2']:.4g}"
            )
        for key in ("tc", "mc"):
            c = r[key]
            if abs(c["measured"] - c["live_peak"]) > 1e-6 * c["live_peak"]:
                failures.append(
                    f"{name}/{key}: measured {c['measured']:.6g} != "
                    f"liveness-aware analytic peak {c['live_peak']:.6g}"
                )
            if c["live_peak"] > c["eq2_peak"] * (1 + 1e-12):
                failures.append(
                    f"{name}/{key}: liveness-aware peak {c['live_peak']:.4g} "
                    f"above eq. 2 peak {c['eq2_peak']:.4g} for the same plan"
                )
            # On these segment-structured nets the liveness charge is
            # below eq. 2's on every transition (verified empirically by
            # the peak columns above — NOT a theorem on general DAGs, see
            # dp.py's module docstring), so eq. 2's admissible set is a
            # subset and the objective can only improve: TC minimizes
            # overhead (must not increase), MC maximizes it (must not
            # decrease).
            worse = (
                c["overhead_at_Beq2_live"] > c["overhead_at_Beq2_eq2"] + 1e-9
                if key == "tc"
                else c["overhead_at_Beq2_live"] < c["overhead_at_Beq2_eq2"] - 1e-9
            )
            if worse:
                failures.append(
                    f"{name}/{key}: objective at B_eq2 got worse "
                    f"({c['overhead_at_Beq2_live']} vs "
                    f"{c['overhead_at_Beq2_eq2']})"
                )
    return failures


def main(nets=None, smoke: bool = False,
         out_json: str = "BENCH_table2.json") -> Dict[str, Dict]:
    nets = tuple(nets) if nets else (SMOKE_NETS if smoke else tuple(NETWORKS))
    gaps = gap_rows(nets)
    failures = check_gap(gaps)
    rows = ablation(nets=nets)
    if out_json:
        import json

        with open(out_json, "w") as f:
            json.dump({"smoke": smoke, "failures": failures,
                       "gap": gaps, "no_liveness_ablation": rows},
                      f, indent=1, default=str)
        print(f"\nwrote {out_json}")
    if failures:
        print("\nREGRESSIONS:")
        for msg in failures:
            print(f"  - {msg}")
        if smoke:
            sys.exit(1)
    elif smoke:
        print("\nsmoke OK: measured == liveness-aware analytic peak; "
              "liveness-aware <= eq. 2 per plan; min feasible budget and "
              "per-budget overhead did not increase")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed network set + hard assertions (CI mode)")
    ap.add_argument("--nets", nargs="*", default=None)
    ap.add_argument("--out-json", default="BENCH_table2.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    main(nets=args.nets, smoke=args.smoke, out_json=args.out_json)
