"""Acceptance for ``repro.analysis`` (ISSUE 6): effect analysis, the static
plan verifier, and lowering conformance — plus the satellites.

* effect classification + must_store pins on PRNG / custom_vjp / effectful
  equations, recursing into scan / while / cond bodies;
* pins flow through the DP (cached, never recomputed, digests diverge);
* the verifier accepts valid plans and rejects a deliberately corrupted
  save-set and a PRNG-tainted unpinned plan with actionable diagnostics;
* conformance accepts the plan's own lowering and rejects a stale one;
* planned twins stay bit-identical to vanilla ``jax.value_and_grad`` for
  carriers containing PRNG keys, scan/while/cond and custom_vjp;
* ``liveness.transition_excess``'s memo no longer keeps graphs alive;
* the plan_lint CLI's exit codes.
"""

import dataclasses
import gc
import sys
import weakref
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro import analysis
from repro.core import dp
from repro.core.graph import Graph, Node, graph_digest
from repro.core.liveness import transition_excess
from repro.core.lowering.carriers import TracedCarrier
from repro.core.lowering.front_door import plan_function
from repro.core.planner import Planner
from repro.core.schedule import make_plan

DN = (((1,), (0,)), ((), ()))


# ---------------------------------------------------------------------- nets


def _dropout_net():
    """Seeded-dropout MLP — the PRNG canary."""

    def fn(params, x, key):
        h = x
        for i, w in enumerate(params):
            h = lax.tanh(lax.dot_general(h, w, DN))
            keep = jax.random.bernoulli(jax.random.fold_in(key, i), 0.9,
                                        h.shape)
            h = jnp.where(keep, h / 0.9, 0.0)
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, 10 + i), (16, 16)) * 0.3
        for i in range(2)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    return fn, (params, x, jax.random.PRNGKey(7))


def _chain_graph(n=6, mem=10.0):
    nodes = [Node(i, f"v{i}", 1.0, mem, "op") for i in range(n)]
    return Graph(nodes, [(i, i + 1) for i in range(n - 1)])


# ------------------------------------------------------------ effect analysis


def test_pure_function_has_no_taint():
    def fn(params, x):
        h = lax.tanh(lax.dot_general(x, params, DN))
        return jnp.sum(h * h)

    c = TracedCarrier.trace(fn, (jnp.ones((4, 8)) * 0.1, jnp.ones((4, 4))),
                            analyze_effects=True)
    assert c.effects.pure
    assert not c.effects.pins
    assert analysis.check_graph(c).ok


def test_dropout_taints_and_pins_storable_frontier():
    fn, args = _dropout_net()
    c = TracedCarrier.trace(fn, args, analyze_effects=True)
    ea = c.effects
    assert not ea.pure
    # PRNG classes present, pins non-empty and on storable equations only
    klasses = {ea.effects[v].klass for v in ea.tainted}
    assert "prng" in klasses
    assert ea.pins
    for v in ea.pins:
        assert ea.effects[v].storable or v in ea.tainted
        assert c.jg.graph.nodes[v].must_store
    # warnings name every tainted equation
    flagged = {f.node for f in ea.report.warnings()}
    assert ea.tainted <= flagged | ea.pins


def test_taint_recurses_into_scan_body():
    def fn(x, key):
        def body(carry, k):
            bits = jax.random.normal(k, carry.shape)
            return carry + bits, ()

        keys = jax.random.split(key, 3)
        out, _ = lax.scan(body, x, keys)
        return jnp.sum(out)

    c = TracedCarrier.trace(fn, (jnp.ones(4), jax.random.PRNGKey(0)),
                            analyze_effects=True)
    ea = c.effects
    scan_idx = [i for i, e in enumerate(ea.effects) if e.primitive == "scan"]
    assert scan_idx and all(i in ea.tainted for i in scan_idx)
    assert any(e.klass == "prng" for e in ea.effects if e.primitive == "scan")


def test_custom_vjp_is_opaque_and_pinned():
    @jax.custom_vjp
    def f(x):
        return jnp.tanh(x)

    def f_fwd(x):
        return jnp.tanh(x), x

    def f_bwd(res, ct):
        return ((1.0 - jnp.tanh(res) ** 2) * ct,)

    f.defvjp(f_fwd, f_bwd)

    def loss(x):
        return jnp.sum(f(x) * f(x))

    c = TracedCarrier.trace(loss, (jnp.ones(8) * 0.3,), analyze_effects=True)
    ea = c.effects
    assert any(e.klass == "opaque" for e in ea.effects)
    assert ea.pins  # opaque float output pins itself


# ----------------------------------------------------------------- pins in DP


def test_pin_marker_changes_digest_only_when_pinned():
    g = _chain_graph()
    unpinned_digest = graph_digest(g)
    same = analysis.pin_graph(g, frozenset())
    assert graph_digest(same) == unpinned_digest
    pinned = analysis.pin_graph(g, frozenset({2}))
    assert graph_digest(pinned) != unpinned_digest
    assert pinned.store_pins == frozenset({2})


def test_pins_are_cached_and_never_recomputed():
    g = analysis.pin_graph(_chain_graph(8), frozenset({2, 5}))
    rep = Planner(cache=None).plan(g, budget=None, method="exact_dp")
    plan = rep.plan
    assert frozenset({2, 5}) <= plan.cached
    for seg in plan.segments:
        assert not (frozenset({2, 5}) & seg.recompute)
    assert analysis.check_plan(g, plan).ok


def test_pinned_peak_matches_event_simulation():
    from repro.core.liveness import simulate

    g = analysis.pin_graph(_chain_graph(7), frozenset({1, 4}))
    rep = Planner(cache=None).plan(g, budget=None, method="exact_dp")
    seq = [s.lower_set for s in rep.plan.segments]
    assert rep.plan.peak_memory == pytest.approx(
        simulate(g, seq, liveness=True).peak_memory
    )


def test_eq2_functional_rejects_pins():
    g = analysis.pin_graph(_chain_graph(5), frozenset({2}))
    with pytest.raises(ValueError, match="eq2"):
        dp.peak_memory(g, [frozenset(range(3)), frozenset(range(5))])


# ------------------------------------------------------------------- verifier


def test_verifier_accepts_valid_plan_and_budget():
    g = _chain_graph(8)
    rep = Planner(cache=None).plan(g, budget=None, method="exact_dp")
    r = analysis.check_plan(g, rep.plan, budget=rep.plan.peak_memory)
    assert r.ok


def test_verifier_rejects_corrupted_save_set():
    g = _chain_graph(8)
    plan = Planner(cache=None).plan(g, budget=None, method="exact_dp").plan
    # mutate the save-set: drop a cached node from one segment's decisions
    seg = next(s for s in plan.segments if s.keep)
    victim = max(seg.keep)
    bad_seg = dataclasses.replace(
        seg, boundary=seg.boundary - {victim}, keep=seg.keep - {victim}
    )
    segs = tuple(bad_seg if s.index == seg.index else s
                 for s in plan.segments)
    bad = dataclasses.replace(plan, segments=segs)
    r = analysis.check_plan(g, bad)
    assert not r.ok
    codes = {f.code for f in r.errors()}
    assert codes & {"boundary-mismatch", "keep-mismatch",
                    "cache-set-mismatch"}
    # diagnostics are actionable: they name the derived-vs-declared sets
    assert any(str(victim) in f.message for f in r.errors())


def test_verifier_rejects_over_budget_and_wrong_peak():
    g = _chain_graph(8)
    plan = Planner(cache=None).plan(g, budget=None, method="exact_dp").plan
    r = analysis.check_plan(g, plan, budget=plan.peak_memory / 2)
    assert any(f.code == "over-budget" for f in r.errors())
    lied = dataclasses.replace(plan, peak_memory=plan.peak_memory * 2)
    r2 = analysis.check_plan(g, lied)
    assert any(f.code == "peak-mismatch" for f in r2.errors())
    lied3 = dataclasses.replace(plan, overhead=plan.overhead + 5.0)
    r3 = analysis.check_plan(g, lied3)
    assert any(f.code == "overhead-mismatch" for f in r3.errors())


def test_verifier_rejects_prng_tainted_unpinned_plan():
    fn, args = _dropout_net()
    c = TracedCarrier.trace(fn, args, analyze_effects=True)
    ea = c.effects
    # plan on the UNPINNED graph with an empty cache set: the storable
    # tainted frontier is necessarily in a recompute set → rejected
    unpinned = TracedCarrier.trace(fn, args).to_graph()
    plan = make_plan(unpinned, [frozenset(range(unpinned.n))])
    assert not plan.cached
    r = analysis.check_plan(unpinned, plan, effects=ea)
    assert not r.ok
    errs = [f for f in r.errors() if f.code == "tainted-recompute"]
    assert errs and "must_store pin" in errs[0].message
    # ...and the pinned plan passes the same check
    pinned_plan = Planner(cache=None).plan(
        c.to_graph(), budget=None, method="approx_dp"
    ).plan
    r2 = analysis.check_plan(c.to_graph(), pinned_plan, effects=ea,
                             jg=c.jg)
    assert r2.ok


# ---------------------------------------------------------------- conformance


def test_conformance_accepts_own_lowering():
    fn, args = _dropout_net()
    c = TracedCarrier.trace(fn, args, analyze_effects=True)
    plan = Planner(cache=None).plan(
        c.to_graph(), budget=None, method="approx_dp"
    ).plan
    r = analysis.check_lowering(c, plan)
    assert r.ok, str(r)


def test_conformance_rejects_stale_lowering():
    from repro.core.lowering.policy import traced_value_and_grad

    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, DN))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [jax.random.normal(jax.random.fold_in(key, i), (8, 8)) * 0.3
              for i in range(6)]
    x = jnp.ones((4, 8))
    c = TracedCarrier.trace(fn, (params, x))
    g = c.to_graph()
    planner = Planner(cache=None)
    tight = planner.plan(g, budget=None, method="exact_dp").plan
    from repro.core.liveness import vanilla_peak

    roomy = planner.plan(g, budget=vanilla_peak(g, liveness=True),
                         method="exact_dp").plan
    assert tight.cached != roomy.cached
    stale = traced_value_and_grad(c, tight)
    r = analysis.check_lowering(c, roomy, lowered=stale)
    assert not r.ok
    codes = {f.code for f in r.errors()}
    assert codes & {"remat-set-mismatch", "residual-not-saved"}


# -------------------------------------------------- bit-identity (satellite 3)


def _assert_bit_identical(fn, args, argnums=0, analyze=True):
    planned = plan_function(fn, argnums=argnums, analyze_effects=analyze,
                            verify=True)
    loss, grads = planned(*args)
    ref_loss, ref_grads = jax.value_and_grad(fn, argnums=argnums)(*args)
    assert float(loss) == float(ref_loss)
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(ref_grads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_planned_dropout_bit_identical_to_vanilla():
    fn, args = _dropout_net()
    _assert_bit_identical(fn, args)


def test_planned_scan_bit_identical():
    def fn(w, x):
        def body(h, _):
            return lax.tanh(lax.dot_general(h, w, DN)), ()

        out, _ = lax.scan(body, x, None, length=4)
        return jnp.sum(out * out)

    w = jnp.eye(8) * 0.5
    x = jnp.ones((4, 8))
    _assert_bit_identical(fn, (w, x))


def test_planned_while_and_cond_bit_identical():
    # reverse-mode AD through lax.while_loop is unsupported in JAX itself,
    # so the while sits on a stop_gradient path (a data-dependent scale),
    # exactly how it shows up in real training code
    def fn(w, x):
        def cond_fn(c):
            return c[0] < 3

        def body_fn(c):
            i, s = c
            return i + 1, s * 1.5

        _, scale = lax.while_loop(
            cond_fn, body_fn, (0, lax.stop_gradient(jnp.sum(x)) * 0.01)
        )
        h = lax.tanh(lax.dot_general(x, w, DN))
        h = lax.cond(jnp.sum(h) > 0, lambda a: a * 2.0, lambda a: a, h)
        return jnp.sum(h * h) * scale

    w = jnp.eye(8) * 0.5
    x = jnp.ones((4, 8))
    _assert_bit_identical(fn, (w, x))


def test_planned_custom_vjp_bit_identical():
    @jax.custom_vjp
    def sq(x):
        return x * x

    def sq_fwd(x):
        return x * x, x

    def sq_bwd(res, ct):
        return (2.0 * res * ct,)

    sq.defvjp(sq_fwd, sq_bwd)

    def fn(w, x):
        h = lax.tanh(lax.dot_general(x, w, DN))
        return jnp.sum(sq(h))

    w = jnp.eye(8) * 0.5
    x = jnp.ones((4, 8))
    _assert_bit_identical(fn, (w, x))


# ---------------------------------------------- liveness memo (satellite 2)


def test_transition_excess_memo_does_not_leak_graphs():
    from repro.core.graph import to_mask

    g = _chain_graph(6)
    m1, m2 = to_mask(range(3)), to_mask(range(6))
    transition_excess(g, m1, m2, 0)  # populate the memo (∂(V) = ∅)
    ref = weakref.ref(g)
    del g
    gc.collect()
    assert ref() is None, "transition_excess memo kept the graph alive"


def test_transition_excess_memo_still_caches():
    from repro.core.graph import to_mask
    from repro.core.liveness import _EXCESS_MEMO

    g = _chain_graph(6)
    m1, m2 = to_mask(range(3)), to_mask(range(6))
    a = transition_excess(g, m1, m2, 0)
    assert g in _EXCESS_MEMO and _EXCESS_MEMO[g]
    b = transition_excess(g, m1, m2, 0)
    assert a == b


# --------------------------------------------------------------- CLI / smoke


def test_cli_traced_quickstart_ok(tmp_path):
    from repro.analysis.cli import main

    out = tmp_path / "report.json"
    rc = main(["--traced", "quickstart", "--json", str(out)])
    assert rc == 0
    import json

    data = json.loads(out.read_text())
    assert data["ok"] and data["targets"][0]["target"] == "quickstart"
    checkers = [r["checker"] for r in data["targets"][0]["reports"]]
    assert checkers == ["effects", "plan", "lowering"]


def test_cli_infeasible_budget_exits_2(capsys):
    from repro.analysis.cli import main

    rc = main(["--traced", "quickstart", "--budget", "10"])
    assert rc == 2
    outp = capsys.readouterr().out
    assert "minimal feasible budget" in outp


def test_cli_network_ok():
    root = Path(__file__).resolve().parent.parent
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    pytest.importorskip("benchmarks.networks")
    from repro.analysis.cli import main

    assert main(["--network", "unet"]) == 0


# ---------------------------------------------------------- front-door verify


def test_plan_function_verify_knob_passes():
    def fn(w, x):
        h = lax.tanh(lax.dot_general(x, w, DN))
        return jnp.sum(h * h)

    planned = plan_function(fn, verify=True)
    w = jnp.eye(8) * 0.5
    x = jnp.ones((4, 8))
    loss, _ = planned(w, x)
    assert np.isfinite(float(loss))


def test_launch_verify_hook(monkeypatch):
    from repro.launch.plan import _maybe_verify

    g = _chain_graph(8)
    res = Planner(cache=None).plan(g, budget=None, method="exact_dp").result
    monkeypatch.setenv("REPRO_VERIFY_PLANS", "1")
    _maybe_verify(g, res, budget=res.peak_memory)  # must not raise
    from repro.analysis.report import PlanVerificationError

    with pytest.raises(PlanVerificationError):
        _maybe_verify(g, res, budget=res.peak_memory / 4)
