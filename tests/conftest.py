"""Shared test fixtures: random-DAG generators for the paper's algorithms.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
host's single device; only launch/dryrun.py forces 512 placeholder devices
(in its own process).
"""

import random
import sys

import pytest

try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    # Offline container: register the minimal fallback under the real name so
    # test modules keep their ordinary `from hypothesis import ...` imports.
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback

from repro.core.graph import Graph, Node


def random_dag(rng: random.Random, n: int, p: float = 0.35,
               topo_ids: bool = True) -> Graph:
    """Erdős–Rényi-style DAG with T ∈ {1, 10} (the paper's cost model) and
    small integer memories.  topo_ids=False permutes node ids to exercise
    non-topological numbering."""
    perm = list(range(n))
    if not topo_ids:
        rng.shuffle(perm)
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                edges.append((perm[i], perm[j]))
    nodes = [
        Node(i, f"v{i}", rng.choice([1.0, 10.0]), float(rng.randint(1, 6)))
        for i in range(n)
    ]
    return Graph(nodes, edges)


@pytest.fixture
def rng():
    return random.Random(0)
