"""Fault-tolerant training loop.

Responsibilities (each one individually testable — see tests/test_train_loop.py):

* build the jitted ``train_step`` with donated params/opt-state, the
  recomputation plan (the paper's technique) applied via ``segment_sizes``,
  and optional int8 error-feedback gradient compression (the numerical twin
  of the cross-pod hierarchical all-reduce);
* **NaN guard** — a non-finite loss or grad-norm skips the parameter update
  (params pass through unchanged) and increments a skip counter; the run
  never poisons its weights;
* **checkpoint/restart** — async committed checkpoints every
  ``ckpt_every`` steps; on start, the loop resumes from the latest committed
  step automatically (crash-restart = rerun the same command);
* **straggler mitigation** — per-step wall-times feed an EWMA; steps slower
  than ``straggler_factor``× the EWMA are counted and surfaced through
  ``on_straggler`` (on a real pod this hook re-dispatches that host's data
  slice and flags the host for replacement; in tests it is observed
  directly);
* **elastic re-mesh** — ``Trainer.remesh(new_mesh)`` re-jits the step and
  reshard-restores the live state onto the new mesh via the mesh-agnostic
  checkpoint format;
* **plan cache** — ``plan_cache_dir`` attaches the on-disk recomputation-plan
  store (core.plan_cache): crash-restarts and elastic re-meshes recover their
  DP remat segmentation as a content-addressed lookup instead of a re-solve.
  Planning itself goes through the unified pipeline (``core.lowering``):
  the launchers hand this loop a loss whose remat segmentation is the
  ``"segment"`` lowering of a Planner ExecutionPlan on the unit chain;
* **sharded planned steps** — ``plan_budget`` routes the loss through
  ``repro.plan_function(loss_fn, budget, mesh=..., in_shardings=...)``: the
  Trainer's mesh and input shardings flow into the traced carrier, the DP
  budgets **per-device** activation bytes, and the planned twin keeps the
  caller's shardings (pjit-composable).  ``in_shardings`` is then the
  2-tuple ``(param_shardings, batch_shardings)`` matching the loss args.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import AsyncCheckpointer, latest_step, restore
from repro.optim import adamw
from repro.optim.compression import (
    init_error_feedback,
    quantize_roundtrip_with_feedback,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    compress_grads: bool = False
    # On-disk recomputation-plan cache (core.plan_cache): a restarted or
    # re-meshed job re-plans its remat segmentation from the store instead of
    # re-running the DP.  None keeps the cache in-memory only.
    plan_cache_dir: Optional[str] = None
    # Per-device activation-byte budget for the DP recomputation plan: when
    # set, the step's value_and_grad is ``repro.plan_function(loss_fn,
    # plan_budget, mesh=..., in_shardings=...)`` — the Trainer's mesh and
    # input shardings flow into the traced carrier, so the plan budgets
    # per-device bytes of the *sharded* step.  None keeps vanilla
    # jax.value_and_grad (losses whose remat the launchers already planned
    # via segment_sizes stay on that path).
    plan_budget: Optional[float] = None
    plan_backend: str = "auto"
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig
    )


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
        params: Any,
        cfg: TrainConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        in_shardings: Any = None,
        donate: bool = True,
    ):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.in_shardings = in_shardings
        if cfg.plan_cache_dir:
            from repro.core.plan_cache import set_default_cache_dir

            set_default_cache_dir(cfg.plan_cache_dir)
        # Private copy: the jitted step donates params/opt-state buffers, and
        # donating the *caller's* arrays would delete them under the caller
        # (breaks restart-from-same-init and interactive use).
        self.params = jax.tree_util.tree_map(
            lambda x: jnp.array(x, copy=True), params
        )
        self.opt_state = adamw.init(params)
        self.err_fb = init_error_feedback(params) if cfg.compress_grads else None
        self.step = 0
        self.skipped = 0
        self.straggler_steps = 0
        self._ewma: Optional[float] = None
        self.on_straggler: Optional[Callable[[int, float, float], None]] = None
        self._ckpt = (
            AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.ckpt_keep)
            if cfg.ckpt_dir
            else None
        )
        self._train_step = self._build_step(donate=donate)

    # ------------------------------------------------------------- step fn

    def _value_and_grad(self):
        """The step's value_and_grad: vanilla, or the planned twin.

        With ``cfg.plan_budget`` the loss goes through the one planning
        pipeline (``repro.plan_function``): trace → per-device budget →
        plan cache → checkpoint lowering, sharding-aware via the Trainer's
        mesh + input shardings.  Re-jitting after ``remesh`` re-plans under
        the new mesh (different per-device bytes → different digest).
        """
        if self.cfg.plan_budget is None:
            return jax.value_and_grad(self.loss_fn)
        from repro.core.lowering import plan_function

        return plan_function(
            self.loss_fn, self.cfg.plan_budget,
            backend=self.cfg.plan_backend, mesh=self.mesh,
            in_shardings=self.in_shardings,
        )

    def _build_step(self, donate: bool):
        ocfg = self.cfg.optimizer
        compress = self.cfg.compress_grads
        value_and_grad = self._value_and_grad()

        def step_fn(params, opt_state, err_fb, batch):
            loss, grads = value_and_grad(params, batch)
            if compress:
                grads, err_fb = quantize_roundtrip_with_feedback(grads, err_fb)
            new_params, new_opt, metrics = adamw.update(
                ocfg, grads, opt_state, params
            )
            # NaN guard: skip the update when loss/grad-norm is non-finite.
            ok = jnp.isfinite(loss) & jnp.isfinite(metrics["grad_norm"])
            sel = lambda a, b: jax.tree_util.tree_map(
                lambda x, y: jnp.where(ok, x, y), a, b
            )
            new_params = sel(new_params, params)
            new_opt = adamw.AdamWState(
                step=jnp.where(ok, new_opt.step, opt_state.step),
                mu=sel(new_opt.mu, opt_state.mu),
                nu=sel(new_opt.nu, opt_state.nu),
            )
            metrics = dict(metrics, loss=loss, ok=ok)
            return new_params, new_opt, err_fb, metrics

        donate_argnums = (0, 1, 2) if donate else ()
        kw = {}
        return jax.jit(step_fn, donate_argnums=donate_argnums, **kw)

    # --------------------------------------------------------- run control

    def maybe_restore(self) -> bool:
        """Resume from the latest committed checkpoint, if any."""
        if not self.cfg.ckpt_dir:
            return False
        s = latest_step(self.cfg.ckpt_dir)
        if s is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored = restore(self.cfg.ckpt_dir, s, state)
        as_jnp = lambda t: jax.tree_util.tree_map(jnp.asarray, t)
        self.params = as_jnp(restored["params"])
        self.opt_state = as_jnp(restored["opt"])
        self.step = s
        return True

    def save(self, wait: bool = False) -> None:
        if not self._ckpt:
            return
        self._ckpt.save_async(
            self.step, {"params": self.params, "opt": self.opt_state}
        )
        if wait:
            self._ckpt.wait()

    def _track_time(self, dt: float) -> None:
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_steps += 1
            if self.on_straggler:
                self.on_straggler(self.step, dt, self._ewma)
        a = self.cfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt

    def run(
        self,
        batches,
        log: Callable[[str], None] = print,
    ) -> Dict[str, Any]:
        """Run to total_steps; ``batches`` is an iterable of host batches."""
        c = self.cfg
        it = iter(batches)
        losses = []
        while self.step < c.total_steps:
            batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, self.err_fb, m = self._train_step(
                self.params, self.opt_state, self.err_fb, batch
            )
            loss = float(m["loss"])
            dt = time.perf_counter() - t0
            self._track_time(dt)
            if not bool(m["ok"]):
                self.skipped += 1
            self.step += 1
            losses.append(loss)
            if c.log_every and self.step % c.log_every == 0:
                log(
                    f"step {self.step:6d}  loss {loss:.4f}  "
                    f"gnorm {float(m['grad_norm']):.3f}  lr {float(m['lr']):.2e}  "
                    f"{dt*1e3:.0f} ms"
                    + (f"  [skipped={self.skipped}]" if self.skipped else "")
                )
            if self._ckpt and self.step % c.ckpt_every == 0:
                self.save()
        if self._ckpt:
            self.save(wait=True)
        return {
            "final_loss": losses[-1] if losses else float("nan"),
            "losses": losses,
            "skipped": self.skipped,
            "straggler_steps": self.straggler_steps,
            "step": self.step,
        }

    # ------------------------------------------------------ elastic re-mesh

    def remesh(self, new_mesh: jax.sharding.Mesh, shardings: Any = None) -> None:
        """Re-jit for a new mesh; reshard live state (elastic scale up/down).

        The checkpoint format stores full arrays, so resharding is a
        device_put onto the new shardings; with shardings=None the state
        stays as fully-replicated host arrays and the next jit call lays it
        out under the new mesh.
        """
        self.mesh = new_mesh
        if shardings is not None:
            self.params = jax.device_put(self.params, shardings)
        self._train_step = self._build_step(donate=True)

    def close(self) -> None:
        if self._ckpt:
            self._ckpt.close()
