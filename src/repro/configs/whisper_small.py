"""whisper-small — enc-dec audio backbone, conv frontend stub
[arXiv:2212.04356; unverified].

12L (each side) d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.
input_specs() supplies 1500 precomputed frame embeddings (30 s of audio
after the conv frontend, which is a stub per the assignment).
"""

from .base import ModelConfig

ARCH_ID = "whisper-small"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        encoder_decoder=True,
        frontend="audio",
        frontend_seq=1500,
        rope_theta=0.0,
    )
