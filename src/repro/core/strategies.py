"""Per-node storage-strategy lattice for the joint memory-strategy DP.

The paper's DP decides a per-node binary — *store* (the node joins the
cache ``U_i`` at full bytes) or *recompute* (it does not join at all).
Gruslys et al. and the Capuchin/byteprofile line (PAPERS.md / SNIPPETS.md)
show mixed storage strategies dominate pure recomputation, so the planner
generalizes the choice for every node that enters the cache:

=============  ======================  =====================================
strategy       device bytes charged    time tax (added to the t axis)
=============  ======================  =====================================
``store``      ``M_v``                 0
``offload``    0                       ``2·M_v / offload_bytes_per_sec``
``quantize``   ``quantized_bytes(M_v)``  ``2·M_v / quantize_bytes_per_sec``
=============  ======================  =====================================

**Model.**  A node picks its strategy once, when it first enters the cache
(the DP's ``m_step`` charges each newly cached node exactly once, so the
per-transition choice *is* a per-node choice).  During its own forward
window the node exists on device at full bytes regardless of strategy —
compression/offload happens when the segment retires — which is why
``liveness.transition_excess`` stays strategy-independent and only the
*carried* cache mass ``m`` shrinks.  Readback on replay is streamed in
chunks (double-buffered, Gruslys-style), so its transient device footprint
is not charged against the budget; its cost is the time tax, which
``core.replay`` prices into the backward stream where overlap can hide it.

The time taxes enter the DP's ``t`` axis for the ``time_centric`` and
``wallclock`` objectives (total time overhead = recomputation + transfer +
codec).  ``memory_centric`` maximizes *recomputation* overhead and treats
strategies purely as byte reduction: every node takes its minimal-bytes
legal strategy (canonical order breaks ties), which weakly enlarges the
feasible set and leaves the objective untouched.

Legality: ``quantize`` is illegal for ``must_store``-pinned nodes (PRNG
draws and effectful values must be preserved bit-exactly); ``offload``
preserves bits and stays legal everywhere.

``StrategyConfig`` is frozen and hashable; ``digest_token()`` is the
content-address fragment ``planner``/``plan_cache`` mix into their keys —
the empty string when only {store, recompute} is enabled, so legacy digests
are unchanged by this subsystem's existence.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import Graph, mask_iter

#: Strategy codes.  "store" and "recompute" are the paper's binary;
#: "offload" and "quantize" extend it.
STORE = "store"
RECOMPUTE = "recompute"
OFFLOAD = "offload"
QUANTIZE = "quantize"

_ALL = (STORE, RECOMPUTE, OFFLOAD, QUANTIZE)

#: Default bandwidths pricing the extended strategies, re-exported by
#: ``cost_model`` (defined here so ``strategies`` stays import-light —
#: ``cost_model`` imports ``dp`` which imports this module).
#: Host link: one PCIe 4.0 x16 direction, de-rated for pageable staging.
DEFAULT_HOST_BYTES_PER_SEC = 1.6e10
#: int8 block codec throughput (memory-bound elementwise kernel).
DEFAULT_QUANTIZE_BYTES_PER_SEC = 2.5e11
#: Canonical order in which a node's storage options are generated (and in
#: which ties are broken everywhere — DP, oracle, sweep).
_STORAGE_ORDER = (STORE, OFFLOAD, QUANTIZE)

#: int8 payload of an f32 source plus one f32 scale per 256-element block
#: (``optim.compression``: BLOCK=256, int8 q + f32 scale).
QUANTIZE_BYTES_RATIO = 0.25 + 1.0 / 256.0


def quantized_bytes(mem: float) -> float:
    """Device bytes of an int8 block-quantized residual of ``mem`` f32 bytes."""
    return mem * QUANTIZE_BYTES_RATIO


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    """Enabled strategy set + the bandwidths that price the extensions.

    ``strategies`` always behaves as if "store" and "recompute" are present
    (they are the paper's baseline); the config is *extended* iff "offload"
    or "quantize" is enabled.  Bandwidths are bytes per second of the
    graph's time unit — pass ``seconds_per_time_unit`` when the graph's
    ``T_v`` axis is not literal seconds (e.g. after ``quantize_times``) so
    taxes land on the same axis as ``T_v``.
    """

    strategies: Tuple[str, ...] = (STORE, RECOMPUTE)
    offload_bytes_per_sec: float = 0.0  # filled from cost_model defaults
    quantize_bytes_per_sec: float = 0.0
    seconds_per_time_unit: float = 1.0

    def __post_init__(self) -> None:
        names = tuple(self.strategies)
        for s in names:
            if s not in _ALL:
                raise ValueError(f"unknown strategy {s!r} (choose from {_ALL})")
        # canonical, deduplicated, baseline always present
        canon = tuple(
            s for s in _ALL if s in names or s in (STORE, RECOMPUTE)
        )
        object.__setattr__(self, "strategies", canon)
        if not self.offload_bytes_per_sec:
            object.__setattr__(
                self, "offload_bytes_per_sec", DEFAULT_HOST_BYTES_PER_SEC
            )
        if not self.quantize_bytes_per_sec:
            object.__setattr__(
                self, "quantize_bytes_per_sec", DEFAULT_QUANTIZE_BYTES_PER_SEC
            )
        if self.offload_bytes_per_sec <= 0 or self.quantize_bytes_per_sec <= 0:
            raise ValueError("strategy bandwidths must be positive")
        if self.seconds_per_time_unit <= 0:
            raise ValueError("seconds_per_time_unit must be positive")

    # ------------------------------------------------------------- identity

    @property
    def extended(self) -> bool:
        """True iff any strategy beyond the paper's binary is enabled."""
        return OFFLOAD in self.strategies or QUANTIZE in self.strategies

    def digest_token(self) -> str:
        """Content-address fragment for planner/plan-cache keys.

        Empty for the legacy binary, so every pre-existing digest is
        unchanged when this subsystem is disabled.
        """
        if not self.extended:
            return ""
        return (
            f"strat={','.join(self.strategies)}"
            f"|off={self.offload_bytes_per_sec!r}"
            f"|qz={self.quantize_bytes_per_sec!r}"
            f"|spu={self.seconds_per_time_unit!r}"
        )

    # -------------------------------------------------------------- pricing

    def node_options(self, g: Graph, v: int) -> List[Tuple[str, float, float]]:
        """Legal ``(code, device_bytes, time_tax)`` options for node ``v``.

        Canonical order (store, offload, quantize); taxes are on the
        graph's ``T_v`` axis.  Pinned nodes may be offloaded (bit-exact)
        but never quantized.
        """
        mem = g.mem_v[v]
        spu = self.seconds_per_time_unit
        out: List[Tuple[str, float, float]] = [(STORE, mem, 0.0)]
        if OFFLOAD in self.strategies:
            out.append((OFFLOAD, 0.0, 2.0 * mem / self.offload_bytes_per_sec / spu))
        if QUANTIZE in self.strategies and not g.nodes[v].must_store:
            out.append(
                (QUANTIZE, quantized_bytes(mem),
                 2.0 * mem / self.quantize_bytes_per_sec / spu)
            )
        return out

    def min_bytes_choice(self, g: Graph, v: int) -> Tuple[str, float, float]:
        """The minimal-device-bytes legal option (canonical tie-break)."""
        opts = self.node_options(g, v)
        best = opts[0]
        for o in opts[1:]:
            if o[1] < best[1]:
                best = o
        return best

    def min_device_bytes(self, g: Graph) -> List[float]:
        """Per-node minimal legal device bytes (the ``mem_eff`` vector).

        Feasibility and the minimal feasible budget only care about the
        smallest carryable footprint: a smaller carried mass never shrinks
        the feasible continuation set, so the extended feasibility problem
        is exactly the binary one with ``mem_v`` replaced by this vector.
        """
        return [self.min_bytes_choice(g, v)[1] for v in range(g.n)]


#: Default legacy config (the paper's binary).
LEGACY = StrategyConfig()


def device_bytes(g: Graph, assignment: Optional[Dict[int, str]]) -> List[float]:
    """Per-node device bytes under a plan's strategy assignment.

    Nodes absent from ``assignment`` (or assigned "store") keep ``M_v``;
    offloaded nodes charge 0; quantized nodes charge
    :func:`quantized_bytes`.  This is the single byte-pricing rule shared
    by the DP's carried mass, ``schedule``'s plan peak, ``replay``'s
    window headroom, and the verifier's re-derivation.
    """
    out = list(g.mem_v)
    if assignment:
        for v, code in assignment.items():
            if code == OFFLOAD:
                out[v] = 0.0
            elif code == QUANTIZE:
                out[v] = quantized_bytes(g.mem_v[v])
    return out


def assignment_taxes(
    g: Graph, assignment: Optional[Dict[int, str]], cfg: StrategyConfig
) -> float:
    """Total time tax of an assignment (left-folded in ascending node id)."""
    if not assignment:
        return 0.0
    total = 0.0
    for v in sorted(assignment):
        code = assignment[v]
        for c, _b, tax in cfg.node_options(g, v):
            if c == code:
                total += tax
                break
        else:
            raise ValueError(f"assignment {code!r} illegal for node {v}")
    return total


# ---------------------------------------------------------------------------
# Transition option frontiers (the DP's per-pair Minkowski sums)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransitionOption:
    """One way to cache a transition's newly cached set.

    ``m_add``/``tax`` are left folds over the set's nodes in ascending id —
    float-identical to the oracle's enumeration and to the legacy
    ``m_step`` fold when every node stores.  ``codes`` aligns with the
    ascending node ids of the newly cached mask.
    """

    m_add: float
    tax: float
    codes: Tuple[str, ...]


_OPT_MEMO: "weakref.WeakKeyDictionary[Graph, Dict[Tuple[str, int, bool], Tuple[TransitionOption, ...]]]" = (
    weakref.WeakKeyDictionary()
)


def transition_options(
    g: Graph, cfg: StrategyConfig, new_mask: int, tc: bool
) -> Tuple[TransitionOption, ...]:
    """Pareto frontier of strategy choices for one newly cached set.

    Incremental Minkowski sum over the set's nodes in ascending id.  For
    the time-centric direction (``tc``) an option is dominated when
    another has ≤ bytes and ≤ tax; pruning after every node keeps the
    frontier small and is exact because both coordinates are additive.
    The all-store option always survives with ``m_add`` bitwise equal to
    the legacy ``m_step`` fold, and the all-min-bytes option survives with
    ``m_add`` equal to the ``mem_eff`` fold — the two anchors the
    feasibility/mfb reductions rely on.

    The memory-centric direction ignores taxes (they are not part of its
    objective), so the frontier collapses to the single minimal-bytes
    assignment.
    """
    per_g = _OPT_MEMO.setdefault(g, {})
    key = (cfg.digest_token(), new_mask, tc)
    cached = per_g.get(key)
    if cached is not None:
        return cached

    if not tc:
        m_add = 0.0
        tax = 0.0
        codes: List[str] = []
        for v in mask_iter(new_mask):
            code, b, tx = cfg.min_bytes_choice(g, v)
            m_add += b
            tax += tx
            codes.append(code)
        out = (TransitionOption(m_add, tax, tuple(codes)),)
        per_g[key] = out
        return out

    acc: List[Tuple[float, float, Tuple[str, ...]]] = [(0.0, 0.0, ())]
    for v in mask_iter(new_mask):
        opts = cfg.node_options(g, v)
        nxt = [
            (m + b, tax + tx, codes + (code,))
            for (m, tax, codes) in acc
            for (code, b, tx) in opts
        ]
        # (m asc, tax asc, generation order) — keep strict-tax-improvers;
        # first-insertion wins ties, so the canonical-order combination
        # survives among float-equal ones.
        nxt.sort(key=lambda o: (o[0], o[1]))
        acc = []
        best_tax = float("inf")
        for o in nxt:
            if o[1] < best_tax:
                acc.append(o)
                best_tax = o[1]
    out = tuple(TransitionOption(m, tax, codes) for m, tax, codes in acc)
    per_g[key] = out
    return out


def assignment_of(new_mask: int, codes: Sequence[str]) -> Dict[int, str]:
    """Expand an option's code tuple into a node → strategy mapping."""
    return {v: code for v, code in zip(mask_iter(new_mask), codes)}
