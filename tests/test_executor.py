"""Canonical-strategy executors vs vanilla backprop — gradients must match.

This is the paper's core guarantee: "any canonical strategy is a legitimate
recomputation strategy in the sense that it never alters the network output."
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_dp, min_feasible_budget, make_plan
from repro.core.blockgraph import Block, BlockGraph, plan_blockgraph
from repro.core.executor import planned_value_and_grad, vanilla_value_and_grad
from repro.core.remat import apply_with_policy


def _mlp_with_skip(d=8):
    """4-block MLP with a skip connection (non-chain graph)."""

    def lin_init(rng, *in_shapes):
        k1, k2 = jax.random.split(rng)
        din = sum(s[-1] for s in in_shapes)
        return {
            "w": jax.random.normal(k1, (din, d)) * 0.3,
            "b": jax.random.normal(k2, (d,)) * 0.1,
        }

    def lin(p, *xs):
        x = jnp.concatenate(xs, axis=-1) if len(xs) > 1 else xs[0]
        return jnp.tanh(x @ p["w"] + p["b"])

    blocks = [
        Block("l1", lin, ("x",), lin_init),
        Block("l2", lin, ("l1",), lin_init),
        Block("l3", lin, ("l2",), lin_init),
        # skip: l4 consumes both l3 and l1
        Block("l4", lin, ("l3", "l1"), lin_init),
    ]
    return BlockGraph(blocks, ["x"], ["l4"])


@pytest.fixture
def setup():
    bg = _mlp_with_skip()
    rng = jax.random.PRNGKey(0)
    params = bg.init(rng, {"x": (4, 8)})
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    loss_fn = lambda out: jnp.sum(out**2)
    return bg, params, {"x": x}, loss_fn


def _plans(bg, params, inputs):
    g = bg.to_graph(params, inputs)
    B0 = min_feasible_budget(g, "exact_dp")
    for slack in (1.0, 1.5, 3.0):
        res = exact_dp(g, B0 * slack)
        assert res.feasible
        yield make_plan(g, res.sequence)


def test_planned_executor_matches_vanilla(setup):
    bg, params, inputs, loss_fn = setup
    ref_loss, ref_grads = vanilla_value_and_grad(bg, loss_fn)(params, inputs)
    for plan in _plans(bg, params, inputs):
        loss, grads = planned_value_and_grad(bg, plan, loss_fn)(params, inputs)
        assert jnp.allclose(loss, ref_loss, rtol=1e-6)
        for name in ref_grads:
            jax.tree_util.tree_map(
                lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
                grads[name],
                ref_grads[name],
            )


def test_checkpoint_policy_backend_matches_vanilla(setup):
    bg, params, inputs, loss_fn = setup
    ref_loss, ref_grads = vanilla_value_and_grad(bg, loss_fn)(params, inputs)
    for plan in _plans(bg, params, inputs):
        f = lambda p, x: loss_fn(apply_with_policy(bg, p, x, plan))
        loss, grads = jax.value_and_grad(f)(params, inputs)
        assert jnp.allclose(loss, ref_loss, rtol=1e-6)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
            grads,
            ref_grads,
        )


def test_apply_planned_segment_backend_matches_vanilla(setup):
    bg, params, inputs, loss_fn = setup
    ref_loss, ref_grads = vanilla_value_and_grad(bg, loss_fn)(params, inputs)
    report, planned_apply = plan_blockgraph(bg, params, inputs)
    f = lambda p, x: loss_fn(planned_apply(p, x))
    loss, grads = jax.value_and_grad(f)(params, inputs)
    assert jnp.allclose(loss, ref_loss, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
        grads,
        ref_grads,
    )


def test_live_trace_respects_plan_ordering(setup):
    """The segment-interpreter's live-byte trace peaks during backward, as
    the paper's canonical strategy predicts (§3)."""
    bg, params, inputs, loss_fn = setup
    plan = next(iter(_plans(bg, params, inputs)))
    run = planned_value_and_grad(bg, plan, loss_fn, track_live=True)
    _, _, trace = run(params, inputs)
    assert trace, "trace must be non-empty"
    fwd_peak = max(b for tag, b in trace if tag.startswith("fwd"))
    bwd_peak = max(b for tag, b in trace if tag.startswith("bwd"))
    assert bwd_peak >= fwd_peak


def test_budgeted_executor_plans_through_cache(setup):
    """planned_value_and_grad_under_budget: gradients match vanilla, and
    rebuilding the runner reuses the cached DP solution."""
    from repro.core import PlanCache, Planner
    from repro.core.executor import planned_value_and_grad_under_budget

    bg, params, inputs, loss_fn = setup
    planner = Planner(cache=PlanCache())
    run, report = planned_value_and_grad_under_budget(
        bg, params, inputs, loss_fn, budget=None, method="exact_dp",
        planner=planner,
    )
    assert report.feasible
    loss, grads = run(params, inputs)
    ref_loss, ref_grads = vanilla_value_and_grad(bg, loss_fn)(params, inputs)
    assert jnp.allclose(loss, ref_loss, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
        grads,
        ref_grads,
    )
    # rebuild: the solve is a cache hit, the plans identical
    run2, report2 = planned_value_and_grad_under_budget(
        bg, params, inputs, loss_fn, budget=None, method="exact_dp",
        planner=planner,
    )
    assert planner.cache.stats()["hits"] >= 1
    assert report2.result.sequence == report.result.sequence
    assert report2.plan == report.plan
