"""Liveness analysis [Appel & Palsberg] + an event-level execution simulator
for the canonical strategy (§3, §4.4, Appendix C).

The paper scores strategies three ways:

* the analytic model, eq. (2)            → ``core.dp.peak_memory``
* measured execution *with liveness analysis*, where every buffer is freed at
  its last use                           → ``simulate(..., liveness=True)``
* measured execution *without* liveness (Appendix C ablation), where buffers
  are freed only at the canonical strategy's own segment-boundary rules
                                          → ``simulate(..., liveness=False)``

Since PR 5 the liveness-analyzed execution also has an exact *analytic*
form: :func:`transition_excess` (bottom of this module) decomposes the
liveness=True simulation per DP transition, and ``core.dp`` prices 𝓜⁽ⁱ⁾
with it — so the DP's budgets are last-use-liveness execution peaks, not
eq. 2's looser footprint.

The simulator expands the canonical strategy into a linear event list:

  forward  : for each segment i, compute f(v) for v ∈ V_i in topo order;
             at segment end, discard f(V_i \\ ∂(L_i)) (canonical rule).
  backward : for each segment i = k…1:
               recompute f(v) for uncached v ∈ V_i from the live caches;
               for w ∈ V_i in reverse topo order, run VJP(w): reads
               {f(p) : p ∈ pred(w)} ∪ {f(w), g(w)}, writes {g(p)};
             at segment end discard f/g buffers of V_i, keeping gradient
             contributions flowing to earlier segments
             (the δ⁺(L_{i-1}) ∩ V_i backward-cache rule of §3).

Because a discarded value is *recomputed* later, the same logical buffer has
several **versions** (live intervals).  The canonical strategy's explicit
discards delimit versions; liveness analysis can only shorten a version (free
at its last use inside the interval), never extend it.

Buffer sizes: both f(v) and g(v) occupy M_v (a gradient has the shape of its
value).  Parameters and inputs are excluded, as in §2.
"""

from __future__ import annotations

import dataclasses
import os
import weakref
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np
from numpy.typing import NDArray

from .graph import EMPTY, Graph, NodeSet, mask_iter

Buffer = Tuple[str, int]  # ("f"|"g", node)


@dataclasses.dataclass
class SimResult:
    peak_memory: float
    total_compute: float  # forward + recompute T (backward T excluded, §2)
    recompute_overhead: float  # T of recomputed nodes only
    num_events: int


@dataclasses.dataclass
class _Event:
    reads: List[Buffer]
    writes: List[Buffer]
    cost: float  # T_v for fwd/recompute events, 0 for VJP events (§2)
    frees_after: List[Buffer]  # explicit canonical-strategy discards


def _topo_within(g: Graph, nodes: NodeSet) -> List[int]:
    order = g.topological_order()
    return [v for v in order if v in nodes]


def build_events(
    g: Graph, sequence: Sequence[NodeSet], with_marks: bool = False
):
    """Expand a lower-set sequence into the canonical-strategy event list.

    With ``with_marks`` returns ``(events, fwd_end, bwd_start)`` where
    ``fwd_end[i]``/``bwd_start[i]`` are the event index of segment ``i``'s
    last forward event and first backward-window event — the two boundaries
    the storage-strategy repricing in :func:`simulate_events` splits cached
    buffers' live intervals at.
    """
    g.check_increasing_sequence(sequence)
    events: List[_Event] = []
    k = len(sequence)
    fwd_end: List[int] = [0] * k
    bwd_start: List[int] = [0] * k
    prev: NodeSet = EMPTY
    segs: List[NodeSet] = []
    bounds: List[NodeSet] = []
    pins = g.store_pins
    for L in sequence:
        segs.append(L - prev)
        # effective cached set: the paper's boundary plus any must_store pins
        # (effect analysis) — pinned values are kept from their forward
        # computation and never recomputed.
        bounds.append(g.boundary(L) | (pins & L))
        prev = L
    # U_i = ∪_{j≤i} ∂(L_j)  (plus pins, when present)
    Us: List[NodeSet] = []
    acc: Set[int] = set()
    for b in bounds:
        acc |= b
        Us.append(frozenset(acc))
    U_k = Us[-1]

    # ---------------- forward ----------------
    for i, Vi in enumerate(segs):
        for v in _topo_within(g, Vi):
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # canonical rule: cache U_k ∩ V_i (its boundary nodes), discard rest
        drop = Vi - U_k
        if drop and events:
            events[-1].frees_after.extend(("f", v) for v in drop)
        fwd_end[i] = len(events) - 1

    # ---------------- backward ----------------
    for i in range(k - 1, -1, -1):
        Vi = segs[i]
        bwd_start[i] = len(events)
        # recompute uncached forward values of V_i
        for v in _topo_within(g, Vi):
            if v in U_k:
                continue  # cached since the forward pass
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # VJP sweep in reverse topological order
        for w in reversed(_topo_within(g, Vi)):
            reads: List[Buffer] = [("f", p) for p in g.pred[w]]
            reads.append(("f", w))
            if g.succ[w]:
                reads.append(("g", w))
            events.append(
                _Event(
                    reads=reads,
                    writes=[("g", p) for p in g.pred[w]] or [("g", w)],
                    cost=0.0,
                    frees_after=[],
                )
            )
        # segment-end frees: drop f/g of V_i; gradient contributions to
        # earlier segments are ("g", p) with p ∉ V_i and thus survive.
        frees = [("f", v) for v in Vi] + [("g", v) for v in Vi]
        if events:
            events[-1].frees_after.extend(frees)
    if with_marks:
        return events, fwd_end, bwd_start
    return events


def build_vanilla_events(g: Graph) -> List[_Event]:
    """No-recomputation baseline: cache every forward value, then backprop."""
    events: List[_Event] = []
    order = g.topological_order()
    for v in order:
        events.append(
            _Event([("f", p) for p in g.pred[v]], [("f", v)], g.time_v[v], [])
        )
    for w in reversed(order):
        reads: List[Buffer] = [("f", p) for p in g.pred[w]] + [("f", w)]
        if g.succ[w]:
            reads.append(("g", w))
        events.append(
            _Event(reads, [("g", p) for p in g.pred[w]] or [("g", w)], 0.0, [])
        )
    if events:
        events[-1].frees_after = [("f", v) for v in order] + [
            ("g", v) for v in order
        ]
    return events


def simulate_events(
    g: Graph, events: List[_Event], liveness: bool,
    reprice: Optional[Dict[Buffer, Tuple[int, int, float]]] = None,
) -> SimResult:
    """Peak live bytes over an event list, with versioned buffer intervals.

    A buffer *version* opens at its first write (or lazy-read for gradient
    seeds) and closes at the strategy's explicit discard.  liveness=True
    shrinks each version to end at its last use instead.

    ``reprice`` prices the joint memory-strategy DP's reduced footprints:
    it maps a cached f-buffer to ``(retire_idx, bwd_start_idx, carried)``
    — full bytes from the forward write through the end of its segment's
    forward window (the value exists on device before it is offloaded /
    quantized), ``carried`` bytes while the cache holds it (0 for
    offloaded, int8+scale for quantized; reads by *later* backward windows
    are streamed and stay at the carried price), and full bytes again from
    its own backward window's first event (the VJP sweep needs the
    materialized value) to the version's end.  Only the version spanning
    the retire point — the forward-computed cached one — is repriced.
    """

    def size(buf: Buffer) -> float:
        return g.mem_v[buf[1]]

    # Pass 1: version intervals.
    open_ver: Dict[Buffer, int] = {}
    nver: Dict[Buffer, int] = defaultdict(int)
    start: Dict[Tuple[Buffer, int], int] = {}
    last_touch: Dict[Tuple[Buffer, int], int] = {}
    end: Dict[Tuple[Buffer, int], int] = {}

    def touch(b: Buffer, idx: int) -> None:
        if b not in open_ver:
            v = nver[b]
            nver[b] += 1
            open_ver[b] = v
            start[(b, v)] = idx
        last_touch[(b, open_ver[b])] = idx

    n_events = len(events)
    for idx, ev in enumerate(events):
        for b in ev.reads:
            touch(b, idx)
        for b in ev.writes:
            touch(b, idx)
        for b in ev.frees_after:
            if b in open_ver:
                end[(b, open_ver[b])] = idx
                del open_ver[b]
    for b, v in open_ver.items():
        end[(b, v)] = n_events - 1

    # Pass 2: sweep with a difference array.
    delta = [0.0] * (n_events + 1)
    for key, s_idx in start.items():
        e_idx = last_touch[key] if liveness else end[key]
        e_idx = min(e_idx, end.get(key, e_idx))
        full = size(key[0])
        if reprice is not None and key[0] in reprice:
            retire, bstart, carried = reprice[key[0]]
            if s_idx <= retire < e_idx:
                delta[s_idx] += full
                delta[retire + 1] += carried - full
                if retire < bstart <= e_idx:
                    delta[bstart] += full - carried
                    delta[e_idx + 1] -= full
                else:
                    delta[e_idx + 1] -= carried
                continue
        delta[s_idx] += full
        delta[e_idx + 1] -= full
    peak = 0.0
    cur = 0.0
    for idx in range(n_events):
        cur += delta[idx]
        peak = max(peak, cur)

    total_T = sum(ev.cost for ev in events)
    return SimResult(
        peak_memory=peak,
        total_compute=total_T,
        recompute_overhead=total_T - g.total_time,
        num_events=n_events,
    )


def simulate(
    g: Graph, sequence: Sequence[NodeSet], liveness: bool = True,
    assignment: Optional[Dict[int, str]] = None,
) -> SimResult:
    """Simulate the canonical strategy for a lower-set sequence.

    ``assignment`` (node → ``core.strategies`` code) prices cached
    residuals at their storage strategy's device bytes between their
    forward window and their own backward window — the event-level
    counterpart of ``dp.peak_memory_live(g, sequence, assignment)``, and
    the oracle ``analysis.verifier`` replays strategy-annotated plans
    against.
    """
    from .strategies import STORE, device_bytes

    live = {v: c for v, c in (assignment or {}).items() if c != STORE}
    if not live:
        return simulate_events(g, build_events(g, sequence), liveness)
    events, fwd_end, bwd_start = build_events(g, sequence, with_marks=True)
    w = device_bytes(g, live)
    seg_of: Dict[int, int] = {}
    prev: NodeSet = EMPTY
    for i, L in enumerate(sequence):
        for v in L - prev:
            seg_of[v] = i
        prev = L
    reprice: Dict[Buffer, Tuple[int, int, float]] = {
        ("f", v): (fwd_end[seg_of[v]], bwd_start[seg_of[v]], w[v])
        for v in live
        if v in seg_of
    }
    return simulate_events(g, events, liveness, reprice=reprice)


# ---------------------------------------------------------------------------
# Analytic per-transition form of the liveness=True simulation.
#
# The event simulation above decomposes exactly along the strategy's
# transitions: while segment i's window runs (its forward pass, or its
# backward recompute + VJP sweep), the buffers alive from *outside* the
# window are precisely f(U_{i-1}) — every cached value of an earlier segment
# is still awaiting its own VJP — plus window-entry gradients determined by
# (L_{i-1}, L_i) alone.  So with last-use liveness,
#
#     simulated peak  =  max_i ( M(U_{i-1}) + excess(L_{i-1}, L_i) )
#
# where ``excess`` is a pure function of the transition pair — exactly the
# shape Algorithm 1's transition relation needs (eq. 2's
# ``𝓜⁽ⁱ⁾ = m + m_fixed`` with a tighter ``m_fixed``).  ``transition_excess``
# computes it in closed form, without building event lists:
#
# Within the backward window of V' = L' \ L (topo order u_1 … u_s, VJP
# events processed u_s … u_1), nothing dies during the recompute phase, and
# the first VJP event dominates it, so only the VJP events matter.  Each
# buffer contributes one interval on the t-axis (t = the index of VJP(u_t)):
#
#   f(u_i)            [i, s]   recomputed/cached value, read last by VJP(uᵢ)
#   g(u_i)            [i, s]   if u_i ∈ ∂(L')   (gradient arrived at entry)
#                     [i, max succ idx in V']   otherwise (first written by
#                                               the VJP of its latest succ)
#                     [i, i]   pred-less node with no succ in V' (self-seed)
#   g(p), p ∈ L       [1, s]   if p ∈ ∂(L')∩L  (arrived at entry, survives)
#                     [1, max succ idx in V']   if p ∈ δ⁻(V') ∩ L otherwise
#                                               (written here, flows onward)
#
# The forward window of the same transition holds only a subset of f(V')
# over the same baseline M(U_{i-1}) and is dominated by the backward
# window's first VJP event (which holds all of f(V') plus gradients), so the
# backward window alone decides the transition's peak.
# ---------------------------------------------------------------------------


# Per-graph transition memo, weakly keyed: entries die with their graph, so
# long-lived processes (planner services, sweeps over many models) don't
# accumulate excess tables for graphs nothing else references.
_EXCESS_MEMO: "weakref.WeakKeyDictionary[Graph, Dict[Tuple[int, int], float]]" = (
    weakref.WeakKeyDictionary()
)


def _topo_rank(g: Graph) -> List[int]:
    rank = getattr(g, "_topo_rank", None)
    if rank is None:
        rank = [0] * g.n
        for r, v in enumerate(g.topological_order()):
            rank[v] = r
        g._topo_rank = rank
    return rank


def scalar_only() -> bool:
    """True when ``REPRO_DP_SCALAR=1`` pins the DP hot paths to the scalar
    oracles (the per-pair difference-array walk here, the per-candidate
    frontier inserts in ``core.dp``).  The vectorized paths are bit-identical
    — same float expressions, just batched — so this is an escape hatch and
    a CI leg, not a semantic switch."""
    return os.environ.get("REPRO_DP_SCALAR", "") not in ("", "0")


def _excess_scalar(g: Graph, mask_L: int, mask_Lp: int, bd_mask: int) -> float:
    """The per-pair difference-array walk (the vectorized path's oracle).

    Accumulation order per delta slot is canonical — selected nodes in rank
    order emitting (f, g, g-end) triples, then ``maxq`` gradients by
    ascending node id, then entry gradients of ∂(L')∩L by ascending node id
    — and :func:`_excess_row` replays exactly this order with
    ``np.add.at`` (unbuffered, applied in index order) + ``np.cumsum``
    (a sequential left fold), which is what makes the two paths
    bit-identical even for masses where float addition does not commute.
    """
    rank = _topo_rank(g)
    vp_mask = mask_Lp & ~mask_L
    nodes = sorted(mask_iter(vp_mask), key=rank.__getitem__)  # u_1 … u_s
    s = len(nodes)
    idx: Dict[int, int] = {u: i for i, u in enumerate(nodes, 1)}
    mem = g.mem_v
    pred = g.pred
    succ = g.succ

    # interval [lo, hi] → delta[lo] += M, delta[hi+1] -= M
    delta = [0.0] * (s + 2)
    maxq_L: Dict[int, int] = {}  # p ∈ δ⁻(V') ∩ L \ ∂(L') → max succ idx
    for i, u in enumerate(nodes, 1):
        mu = mem[u]
        # f(u): alive from before the VJP sweep until VJP(u) = e_i
        delta[i] += mu
        delta[s + 1] -= mu
        # g(u)
        if (bd_mask >> u) & 1:
            hi = s  # gradient arrived from later segments at window entry
        else:
            hi = 0
            for w in succ[u]:
                j = idx.get(w)  # non-boundary ⇒ every successor is in V'
                if j is not None and j > hi:
                    hi = j
            if hi == 0 and not pred[u]:
                hi = i  # VJP of a pred-less node writes g(u) itself
        if hi:
            delta[i] += mu
            delta[hi + 1] -= mu
        # gradients this window writes for earlier segments
        for p in pred[u]:
            if (mask_L >> p) & 1 and not ((bd_mask >> p) & 1):
                maxq_L[p] = i  # i ascends, so the last write wins
    for p in sorted(maxq_L):  # ascending node id — the canonical slot order
        delta[1] += mem[p]
        delta[maxq_L[p] + 1] -= mem[p]
    for p in mask_iter(bd_mask & mask_L):
        # entry gradients of earlier-segment boundary nodes: live all window
        delta[1] += mem[p]
        delta[s + 1] -= mem[p]

    peak = 0.0
    cur = 0.0
    for t in range(1, s + 1):
        cur += delta[t]
        if cur > peak:
            peak = cur
    return peak


def transition_excess(g: Graph, mask_L: int, mask_Lp: int, bd_mask: int) -> float:
    """Liveness-tight ``m_fixed`` of one DP transition ``L → L'`` (bitmasks).

    The peak live bytes of the transition's execution window *beyond* the
    carried cache mass ``M(U_{i-1})``, with every buffer freed at its last
    use (``simulate(..., liveness=True)`` factored per transition — see the
    derivation above).  ``bd_mask`` must be the bitmask of ``∂(L')``.

    Always ≤ eq. 2's ``2·M(V') + M(δ⁺(L')\\L') + M(δ⁻(δ⁺(L'))\\L')`` on
    chain-like transitions and usually far below it on multi-node segments;
    on graphs whose gradients flow across many segments it can exceed
    eq. 2's (under-counted) charge — eq. 2 ignores gradient buffers held
    for earlier segments, this functional does not.

    Results are memoized per graph (graphs are immutable) in a weakly-keyed
    table, so the DP entry points (``solve`` / ``feasible`` / ``sweep`` /
    ``min_feasible_budget_exact``) all see the *same float* for a pair —
    the foundation of their bit-identity contract — while the memo itself
    never outlives its graph.
    """
    memo = _EXCESS_MEMO.get(g)
    if memo is None:
        memo = _EXCESS_MEMO[g] = {}
    key = (mask_L, mask_Lp)
    hit = memo.get(key)
    if hit is not None:
        return hit
    peak = _excess_scalar(g, mask_L, mask_Lp, bd_mask)
    memo[key] = peak
    return peak


# ---------------------------------------------------------------------------
# Vectorized batch form: one source L priced against many targets L' at once.
#
# The DP's outer loop fixes a source L and walks every superset L' — the
# scalar walk above re-derives the same topo-sorted complement of L, the
# same successor structure, and the same per-node masses for every pair.
# The batch form shares all of that across the targets: the complement's
# topo order, its successor/predecessor CSR and the node masses are built
# once per L, and each target contributes only a boolean membership row.
# Ranks become one cumsum over the (targets × complement) selection matrix,
# g-interval ends one masked segment-max, and the difference arrays one
# ordered np.add.at + np.cumsum — the same float expressions as the scalar
# walk, applied in the same per-slot order, so the peaks are bit-identical.
# ---------------------------------------------------------------------------


def _masks_bools(masks: Sequence[int], n: int) -> NDArray[np.bool_]:
    """(len(masks), n) boolean membership matrix from big-int bitmasks."""
    nb = max(1, (n + 7) // 8)
    buf = b"".join(m.to_bytes(nb, "little") for m in masks)
    raw = np.frombuffer(buf, dtype=np.uint8).reshape(len(masks), nb)
    out: NDArray[np.bool_] = np.unpackbits(
        raw, axis=1, bitorder="little"
    )[:, :n].astype(bool)
    return out


@dataclasses.dataclass(frozen=True)
class _VecGraph:
    """Static per-graph arrays in topo-position coordinates, built once.

    ``topo[k]`` is the node id at position ``k``; ``mem`` is indexed by
    position.  ``slots`` is a ragged successor-slot structure: level ``d``
    holds ``(pos_d, succ_d)`` — the positions with at least ``d+1``
    successors, paired with their ``d``-th successor's position — so a
    max-over-successors fold costs O(Σ out-degree), not O(max-degree · n)
    (DenseNet-style graphs have max-degree ≫ mean).  Pred-less nodes carry
    their *own* position as an extra slot — their VJP self-seeds g(u), so
    the fold naturally yields the scalar walk's ``hi = i`` fallback.
    """

    topo: NDArray[np.int64]
    mem: NDArray[np.float64]
    slots: Tuple[Tuple[NDArray[np.int64], NDArray[np.int64]], ...]


def _vec_arrays(g: Graph) -> _VecGraph:
    cached = getattr(g, "_excess_vec_arrays", None)
    if cached is None:
        n = g.n
        topo = np.asarray(g.topological_order(), dtype=np.int64)
        pos = np.empty(n, dtype=np.int64)
        pos[topo] = np.arange(n)
        pos_l = pos.tolist()
        per_node: List[List[int]] = [[] for _ in range(n)]
        for u in range(n):
            p = pos_l[u]
            for w in g.succ[u]:
                per_node[p].append(pos_l[w])
            if not g.pred[u]:
                per_node[p].append(p)
        deg = max((len(r) for r in per_node), default=0)
        slots = []
        for d in range(deg):
            ps = [p for p in range(n) if len(per_node[p]) > d]
            slots.append(
                (
                    np.asarray(ps, dtype=np.int64),
                    np.asarray(
                        [per_node[p][d] for p in ps], dtype=np.int64
                    ),
                )
            )
        cached = _VecGraph(
            topo=topo,
            mem=np.asarray(g.mem_v, dtype=np.float64)[topo],
            slots=tuple(slots),
        )
        g._excess_vec_arrays = cached
    return cached  # type: ignore[no-any-return]


def transition_excess_many(
    g: Graph, mask_L: int, pairs: Sequence[Tuple[int, int]]
) -> List[float]:
    """``transition_excess`` for one source against many ``(L', ∂(L'))``.

    Returns the per-pair excesses in order, reading/writing the same
    per-graph memo as the scalar entry point — the DP entry points price a
    whole source row with one call and every later per-pair query (e.g.
    ``peak_memory_live``) is a memo hit on the very same float.  Under
    ``REPRO_DP_SCALAR=1`` the missing pairs run the scalar walk instead.
    """
    memo = _EXCESS_MEMO.get(g)
    if memo is None:
        memo = _EXCESS_MEMO[g] = {}
    out = [memo.get((mask_L, mask_Lp)) for mask_Lp, _bd in pairs]
    missing = [p for p, hit in zip(pairs, out) if hit is None]
    if missing:
        if scalar_only():
            for mask_Lp, bd in missing:
                memo[(mask_L, mask_Lp)] = _excess_scalar(
                    g, mask_L, mask_Lp, bd
                )
        else:
            peaks = _excess_row(g, mask_L, missing)
            for (mask_Lp, _bd), pk in zip(missing, peaks.tolist()):
                memo[(mask_L, mask_Lp)] = pk
        it = iter(missing)
        for idx, hit in enumerate(out):
            if hit is None:
                out[idx] = memo[(mask_L, next(it)[0])]
    return out  # type: ignore[return-value]


def transition_excess_row(
    g: Graph,
    mask_L: int,
    pairs: Optional[Sequence[Tuple[int, int]]] = None,
    *,
    tmul: Optional[NDArray[np.bool_]] = None,
    bdful: Optional[NDArray[np.bool_]] = None,
) -> NDArray[np.float64]:
    """Memo-free row pricing for the vectorized DP.

    The DP caches whole ``m_fixed`` rows in its own per-(graph, family)
    table, so populating the per-pair memo here would be pure overhead
    (130k big-int tuple keys on a ResNet-152 family); the DP instead
    seeds the memo for just the pairs its answer uses via
    :func:`record_excess`, which keeps the one-float-per-pair contract
    for ``peak_memory_live`` without paying for the other 99%.  Callers
    that hold the family membership (``tmul``) and boundary (``bdful``)
    boolean matrices pass them to skip the per-row big-int unpack.
    Under ``REPRO_DP_SCALAR=1`` this delegates to the memoized scalar
    walks (``pairs`` required there).
    """
    if scalar_only():
        if pairs is None:
            raise ValueError("pairs required under REPRO_DP_SCALAR=1")
        return np.asarray(
            transition_excess_many(g, mask_L, pairs), dtype=np.float64
        )
    return _excess_row(g, mask_L, pairs, tmul, bdful)


def record_excess(g: Graph, mask_L: int, mask_Lp: int, value: float) -> None:
    """Seed the per-pair memo with a row-priced float (first write wins).

    Called by the vectorized DP for the transitions its chosen sequence
    actually takes, so later scalar queries (``peak_memory_live`` pricing
    the returned plan) read the *same float* the feasibility filter used.
    """
    memo = _EXCESS_MEMO.get(g)
    if memo is None:
        memo = _EXCESS_MEMO[g] = {}
    memo.setdefault((mask_L, mask_Lp), value)


def _excess_row(
    g: Graph,
    mask_L: int,
    pairs: Optional[Sequence[Tuple[int, int]]] = None,
    tmul: Optional[NDArray[np.bool_]] = None,
    bdful: Optional[NDArray[np.bool_]] = None,
) -> NDArray[np.float64]:
    n = g.n
    vg = _vec_arrays(g)
    if tmul is None or bdful is None:
        assert pairs is not None
        tmul = _masks_bools([mask_Lp for mask_Lp, _bd in pairs], n)
        bdful = _masks_bools([bd for _mask_Lp, bd in pairs], n)
    J = len(tmul)
    in_l = _masks_bools([mask_L], n)[0][vg.topo]  # L, position space
    cpos = np.nonzero(~in_l)[0]  # complement positions, topo order
    K = len(cpos)
    if K == 0 or J == 0:
        return np.zeros(J, dtype=np.float64)
    cids = vg.topo[cpos]  # complement node ids, topo order

    # Everything below lives in complement coordinates (k = 0 … K−1, topo
    # order), K-major: row k of a (K, J) matrix is the k-th node outside
    # L across every target.  K-major keeps the hot scatters (the slot
    # folds below) on contiguous rows, and makes ``np.nonzero``'s
    # row-major order mean "k ascending within each target" — the
    # canonical accumulation order the bincount pass needs.  ``cinvx``
    # maps full positions → complement rows, with the sentinel row K
    # (identically zero in ``selrank_pad``) absorbing positions inside L
    # and the static slot matrix's own sentinel ``n``.
    # (L'_j \ L) membership (complement ∩ L'_j), K-major
    sel = np.ascontiguousarray(tmul[:, cids].T)
    bd_c = np.ascontiguousarray(bdful[:, cids].T)
    rank = np.cumsum(sel, axis=0, dtype=np.int32)  # 1-based rank if selected
    s = rank[-1].copy()  # window lengths, per target
    selrank_pad = np.zeros((K + 1, J), dtype=np.int32)
    np.multiply(rank, sel, out=selrank_pad[:K])

    cinvx = np.full(n + 1, K, dtype=np.int64)
    cinvx[cpos] = np.arange(K)

    # max selected-successor rank per (node, target), folded over the
    # ragged slot structure: successors inside L / outside L' gather rank
    # 0 and drop out of the max; a pred-less node's self-slot yields its
    # own rank — exactly the scalar walk's ``hi`` fallback chain.  Slot
    # owners are distinct within a level, so the row gather/scatter is a
    # plain fancy-indexed maximum (no ``.at`` needed).
    succ_max = np.zeros((K, J), dtype=np.int32)
    for pos_d, sp_d in vg.slots:
        col_d = cinvx[pos_d]
        keep = col_d < K  # slot owner outside L
        col_k = col_d[keep]
        succ_max[col_k] = np.maximum(
            succ_max[col_k], selrank_pad[cinvx[sp_d[keep]]]
        )

    # g-interval end: boundary → s; else the successor fold.  Only
    # selected entries are ever read below, so no window mask is applied.
    gend = succ_max
    np.copyto(gend, np.broadcast_to(s[None, :], (K, J)), where=bd_c)

    S = int(s.max())
    W = S + 2  # delta row width; column 0 is a write-only dump slot
    if J * W < 2**31:
        idt = np.int32
    else:  # pragma: no cover - gigantic batches only
        idt = np.int64

    # Group 1 — per selected node, in rank order (= topo order restricted
    # to the complement): f-add @ rank, g-add @ rank, g-sub @ gend+1.  The
    # f-sub @ s+1 lands past every read slot and is dropped; a node with
    # no g-interval routes its g entries to the unread dump slot 0.
    # Compressed to the selected entries only: ``np.nonzero`` on the
    # K-major matrix emits (k, j) pairs k-ascending within each j, so the
    # per-(j, t) accumulation order below is exactly the scalar walk's.
    kk, jj = np.nonzero(sel)
    r_s = rank[kk, jj]
    ge = gend[kk, jj]
    hg = ge > 0
    cols3 = np.empty((len(kk), 3), dtype=idt)
    cols3[:, 0] = r_s
    np.multiply(r_s, hg, out=cols3[:, 1], casting="unsafe")
    np.add(ge, hg, out=cols3[:, 2], casting="unsafe")
    cols3 += (jj * W).astype(idt)[:, None]
    mem_c = vg.mem[cpos]
    m_s = mem_c[kk]
    w3 = np.empty((len(kk), 3), dtype=np.float64)
    w3[:, 0] = m_s
    w3[:, 1] = m_s
    np.negative(m_s, out=w3[:, 2])
    flat = cols3.ravel()
    w = w3.ravel()

    # Candidate earlier-segment gradient holders: p ∈ L with a successor
    # outside L — exactly ∂(L) ⊇ δ⁻(V')∩L and ⊇ ∂(L')∩L for every L' ⊇ L.
    # Ascending node id is the canonical slot order for both groups below.
    has_out = np.zeros(n, dtype=bool)
    for pos_d, sp_d in vg.slots:
        has_out[pos_d] |= cinvx[sp_d] < K
    cand = np.nonzero(in_l & has_out)[0]
    cand = cand[np.argsort(vg.topo[cand])]
    if len(cand):
        P = len(cand)
        mem_p = vg.mem[cand]
        bd_p = np.ascontiguousarray(bdful[:, vg.topo[cand]].T)  # P-major
        candinv = np.full(n, P, dtype=np.int64)
        candinv[cand] = np.arange(P)
        # qmax per candidate: max selected-successor rank via the same
        # slot fold (successors inside L gather the sentinel rank 0; a
        # pred-less candidate's self-slot is inside L, equally inert)
        qmax = np.zeros((P, J), dtype=np.int32)
        for pos_d, sp_d in vg.slots:
            ci = candinv[pos_d]
            keep = ci < P
            ci_k = ci[keep]
            qmax[ci_k] = np.maximum(
                qmax[ci_k], selrank_pad[cinvx[sp_d[keep]]]
            )
        # Group 2 — maxq gradients, alive [1, qmax]: p qualifies when it
        # is outside ∂(L') and has at least one selected successor (add @
        # 1, sub @ qmax+1 ≥ 2 — never colliding with the adds).  Group 3 —
        # entry gradients of ∂(L')∩L, alive the whole window (add @ 1; the
        # matching sub @ s+1 is past every read slot).  Both compressed to
        # the qualifying entries; p-major nonzero keeps each group's
        # per-(j, t) order p-ascending, and concatenation order (group 1,
        # then 2, then 3) matches the scalar walk's per-slot fold order.
        ok_q = (qmax > 0) & ~bd_p
        pq, jq = np.nonzero(ok_q)
        colq = np.empty((len(pq), 2), dtype=idt)
        base_q = (jq * W).astype(idt)
        np.add(base_q, 1, out=colq[:, 0])
        colq[:, 1] = qmax[pq, jq]
        colq[:, 1] += base_q
        colq[:, 1] += 1
        wq = np.empty((len(pq), 2), dtype=np.float64)
        wq[:, 0] = mem_p[pq]
        np.negative(wq[:, 0], out=wq[:, 1])
        pb, jb = np.nonzero(bd_p)
        colb = (jb * W).astype(idt)
        colb += 1
        flat = np.concatenate([flat, colq.ravel(), colb])
        w = np.concatenate([w, wq.ravel(), mem_p[pb]])

    # One sequential accumulation pass: bincount adds weights in input
    # order per bin — the same left-fold per delta slot as the scalar walk.
    delta = np.bincount(flat, weights=w, minlength=J * W).reshape(J, W)

    # Kill slots past each window with a −inf sentinel at s+1: the cumsum
    # then propagates −inf through every unread slot, so a plain row max
    # over t = 1 … S+1 reads only t ≤ s — no mask materialization.
    delta[np.arange(J), s.astype(np.int64) + 1] = -np.inf
    csum = np.cumsum(delta[:, 1:], axis=1)
    peaks: NDArray[np.float64] = np.maximum(np.max(csum, axis=1), 0.0)
    return peaks


def vanilla_peak(g: Graph, liveness: bool = True) -> float:
    """Peak of the no-recomputation baseline (cache everything)."""
    return simulate_events(g, build_vanilla_events(g), liveness).peak_memory
