"""repro.core — the paper's contribution as a library.

Graph-theoretic recomputation planning (Kusumoto et al., NeurIPS 2019),
organized as **one pipeline**:

    graph carriers → Planner → Lowering backends

* **Carriers** (``core.lowering.carriers``): what gets planned — a
  ``BlockGraph`` model DAG, or *any traced JAX function* via
  ``core.jaxpr_graph``.  Both export the paper's ``Graph`` (§2).
* **Planner** (``core.planner``): lower-set families (§4.2/§4.3), the
  exact/approximate DP (Algorithm 1), the budget-free sweep engine with
  lazy cap extension, the exact minimal feasible budget, Chen's √n
  baseline — all memoized through the content-addressed plan cache and
  optionally priced by the measured cost model.
* **Lowerings** (``core.lowering``): registered backends turning an
  ``ExecutionPlan`` into runnable code — the §3 interpreter (validation +
  live-byte audit), the ``jax.checkpoint``/``save_only_these_names``
  policy and per-segment groupings (production BlockGraph paths), and the
  jaxpr-level lowering for traced functions.

``plan_function`` (also ``repro.plan_function``) is the front door;
``core.executor`` and ``core.remat`` remain as deprecation shims.
"""

from .chen import articulation_points, candidate_split_points, chen_sqrt_n
from .cost_model import (
    OpProfile,
    calibrated_graph,
    load_or_profile,
    measured_times,
    profile_ops,
)
from .dfs import exhaustive_search
from .dp import (
    MEMORY_FUNCTIONAL,
    DPResult,
    Sweep,
    SweepOverflow,
    approx_dp,
    cached_sets,
    decode_sweep,
    exact_dp,
    min_feasible_budget_exact,
    overhead,
    peak_memory,
    peak_memory_live,
    quantize_times,
    solve,
    sweep,
)
from .graph import (
    Graph,
    Node,
    canonical_maps,
    canonical_order,
    chain,
    from_cost_lists,
    graph_digest,
)
from .liveness import SimResult, simulate, transition_excess, vanilla_peak
from .lower_sets import all_lower_sets, count_lower_sets, pruned_lower_sets
from .lowering import (
    Lowering,
    PlannedFunction,
    available_backends,
    get_lowering,
    plan_function,
    register_lowering,
)
from .plan_cache import (
    CallableStore,
    PlanCache,
    PlanKey,
    RemoteStore,
    SharedFSStore,
    SweepKey,
    default_cache,
    register_transport,
    remote_store_from_url,
    set_default_cache_dir,
    set_default_remote_store,
)
from .planner import (
    Planner,
    PlanReport,
    compare_methods,
    get_default_planner,
    min_feasible_budget,
    plan,
)
from .replay import (
    ReplayResult,
    SegmentTiming,
    rank_by_replay,
    replay,
    window_peaks,
)
from .schedule import ExecutionPlan, Segment, make_plan, plan_summary

__all__ = [
    "Graph",
    "Node",
    "chain",
    "from_cost_lists",
    "all_lower_sets",
    "pruned_lower_sets",
    "count_lower_sets",
    "DPResult",
    "solve",
    "sweep",
    "Sweep",
    "SweepOverflow",
    "decode_sweep",
    "min_feasible_budget_exact",
    "exact_dp",
    "approx_dp",
    "overhead",
    "peak_memory",
    "peak_memory_live",
    "MEMORY_FUNCTIONAL",
    "cached_sets",
    "quantize_times",
    "exhaustive_search",
    "articulation_points",
    "candidate_split_points",
    "chen_sqrt_n",
    "SimResult",
    "simulate",
    "transition_excess",
    "vanilla_peak",
    # discrete-event replay (wall-clock pricing)
    "ReplayResult",
    "SegmentTiming",
    "replay",
    "rank_by_replay",
    "window_peaks",
    "ExecutionPlan",
    "Segment",
    "make_plan",
    "plan_summary",
    "PlanReport",
    "plan",
    "compare_methods",
    "min_feasible_budget",
    # plan compilation pipeline
    "graph_digest",
    "canonical_order",
    "canonical_maps",
    "PlanCache",
    "PlanKey",
    "RemoteStore",
    "SharedFSStore",
    "CallableStore",
    "SweepKey",
    "default_cache",
    "register_transport",
    "remote_store_from_url",
    "set_default_cache_dir",
    "set_default_remote_store",
    "Planner",
    "get_default_planner",
    "OpProfile",
    "profile_ops",
    "load_or_profile",
    "measured_times",
    "calibrated_graph",
    # unified lowering pipeline
    "plan_function",
    "PlannedFunction",
    "Lowering",
    "register_lowering",
    "get_lowering",
    "available_backends",
]
