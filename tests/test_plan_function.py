"""Acceptance for the ``repro.plan_function`` front door (ISSUE 3).

A plain (non-BlockGraph) JAX MLP under a **halved byte budget** must:

* train with loss and gradients **bit-identical** to vanilla
  ``jax.value_and_grad`` (while actually recomputing — overhead > 0);
* keep measured live intermediate bytes ≤ the plan's ``peak_memory``;
* plan-cache-hit on the second call (no re-solve).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

import repro
from repro.core import PlanCache, Planner
from repro.core.jaxpr_graph import trace
from repro.core.liveness import vanilla_peak

DN = (((1,), (0,)), ((), ()))


def _mlp():
    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, DN))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
        for i in range(10)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    return fn, params, x


@pytest.fixture
def setup():
    fn, params, x = _mlp()
    g = trace(fn, params, x).graph
    budget = vanilla_peak(g, liveness=False) / 2  # the halved byte budget
    return fn, params, x, g, budget


def _bits(a, b):
    return all(
        np.array_equal(np.asarray(u), np.asarray(v))
        for u, v in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )


def test_halved_budget_bit_identical_to_vanilla(setup):
    fn, params, x, g, budget = setup
    ref_loss, ref_grads = jax.value_and_grad(fn)(params, x)

    planned = repro.plan_function(fn, budget, planner=Planner(cache=PlanCache()))
    loss, grads = planned(params, x)
    lowered = planned.lowered_for(params, x)
    assert lowered.backend == "jaxpr"  # the trace-anything production path
    assert lowered.plan.peak_memory <= budget
    assert lowered.plan.overhead > 0  # the budget actually forces recompute
    assert _bits(loss, ref_loss)
    assert _bits(grads, ref_grads)


def test_training_steps_match_vanilla(setup):
    """A few SGD steps through the planned function track vanilla exactly."""
    fn, params, x, g, budget = setup
    planned = repro.plan_function(fn, budget, planner=Planner(cache=PlanCache()))
    p1 = p2 = params
    for _ in range(3):
        _, g1 = planned(p1, x)
        _, g2 = jax.value_and_grad(fn)(p2, x)
        p1 = [w - 0.05 * gw for w, gw in zip(p1, g1)]
        p2 = [w - 0.05 * gw for w, gw in zip(p2, g2)]
    assert _bits(p1, p2)
    l1, _ = planned(p1, x)
    l2, _ = jax.value_and_grad(fn)(p2, x)
    assert _bits(l1, l2)


def test_measured_live_bytes_within_plan_peak(setup):
    fn, params, x, g, budget = setup
    audited = repro.plan_function(fn, budget, backend="interpreter",
                                  track_live=True,
                                  planner=Planner(cache=PlanCache()))
    loss, grads, live = audited(params, x)
    lowered = audited.lowered_for(params, x)
    assert live
    assert max(b for _, b in live) <= lowered.plan.peak_memory
    ref = jax.value_and_grad(fn)(params, x)
    assert _bits((loss, grads), ref)


def test_second_call_is_plan_cache_hit(setup):
    fn, params, x, g, budget = setup
    planner = Planner(cache=PlanCache())

    first = repro.plan_function(fn, budget, planner=planner)
    _ = first(params, x)
    stats_cold = planner.cache.stats()

    second = repro.plan_function(fn, budget, planner=planner)  # fresh front door
    _ = second(params, x)
    stats_warm = planner.cache.stats()
    assert stats_warm["hits"] > stats_cold["hits"]
    assert stats_warm["misses"] == stats_cold["misses"]  # no re-solve
    assert second.lowered_for(params, x).plan == first.lowered_for(params, x).plan

    # within one PlannedFunction, the lowering is memoized per signature
    assert second.lowered_for(params, x) is second.lowered_for(params, x)


def test_jit_composable(setup):
    """The lowered twin is a plain JAX function: jax.jit composes."""
    fn, params, x, g, budget = setup
    planned = repro.plan_function(fn, budget, planner=Planner(cache=PlanCache()))
    run = planned.lowered_for(params, x).run
    ref = jax.jit(jax.value_and_grad(fn))(params, x)
    got = jax.jit(run)(params, x)
    assert _bits(got, ref)


def test_budget_none_uses_exact_min_feasible(setup):
    fn, params, x, g, budget = setup
    planner = Planner(cache=PlanCache())
    planned = repro.plan_function(fn, planner=planner)
    lowered = planned.lowered_for(params, x)
    mfb = planner.min_feasible_budget(planner.prepare(g), "approx_dp")
    assert lowered.report.budget == mfb
    assert lowered.plan.peak_memory <= mfb
    assert _bits(planned(params, x), jax.value_and_grad(fn)(params, x))


def test_infeasible_budget_raises_with_hint(setup):
    fn, params, x, g, budget = setup
    planned = repro.plan_function(fn, 1.0, planner=Planner(cache=PlanCache()))
    with pytest.raises(ValueError, match="minimal feasible budget"):
        planned(params, x)


def test_argnums_tuple(setup):
    fn, params, x, g, budget = setup
    planned = repro.plan_function(fn, budget, argnums=(0, 1),
                                  planner=Planner(cache=PlanCache()))
    loss, (gp, gx) = planned(params, x)
    ref_loss, (rp, rx) = jax.value_and_grad(fn, argnums=(0, 1))(params, x)
    assert _bits((loss, gp, gx), (ref_loss, rp, rx))


def test_non_scalar_output_rejected():
    planned = repro.plan_function(lambda x: x * 2.0)
    with pytest.raises(TypeError, match="scalar-output"):
        planned(jnp.ones((3,)))


def test_changed_structure_retraces():
    fn, params, x = _mlp()
    planner = Planner(cache=PlanCache())
    planned = repro.plan_function(fn, planner=planner)
    _ = planned(params, x)
    # deeper net = different structure → a second lowering, not an error
    more = params + [jnp.eye(16)]
    l2, g2 = planned(more, x)
    assert _bits((l2, g2), jax.value_and_grad(fn)(more, x))
    assert len(planned._memo) == 2


def test_blockgraph_jaxpr_backend_equation_granularity():
    """Satellite (ISSUE 4): backend="jaxpr" for BlockGraph carriers traces
    ``bg.apply`` whole and plans at equation granularity — more nodes than
    blocks, grads bit-identical to vanilla over the same BlockGraph."""
    from jax import lax as _lax

    from repro.core.blockgraph import Block, BlockGraph

    def mk(name, src):
        return Block(
            name=name,
            apply=lambda p, h: _lax.tanh(_lax.dot_general(h, p["w"], DN)),
            inputs=(src,),
            init=lambda rng, shp: {
                "w": jax.random.normal(rng, (shp[-1], shp[-1])) * 0.3
            },
        )

    bg = BlockGraph([mk(f"b{i}", "x" if i == 0 else f"b{i-1}")
                     for i in range(6)], ["x"], ["b5"])
    params = bg.init(jax.random.PRNGKey(0), {"x": (4, 16)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 16))}
    loss = lambda out: jnp.sum(out * out)

    pf = repro.plan_function(bg, None, backend="jaxpr", loss_fn=loss,
                             planner=Planner(cache=PlanCache()))
    lowered = pf.lowered_for(params, inputs)
    assert lowered.backend == "jaxpr"
    assert lowered.carrier.to_graph().n > len(bg.blocks)

    ref = jax.value_and_grad(lambda p: loss(bg.apply(p, inputs)))(params)
    got = pf(params, inputs)
    assert _bits(got, ref)
