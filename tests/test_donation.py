"""Donation-hinted lowerings (ISSUE 9 satellite): hints name exactly the
dead-at-window buffers, donated twins change no values, the interpreter's
live-byte audit is untouched, and the drift gate stays green."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import PlanCache, Planner, make_plan
from repro.core.lowering import plan_function
from repro.core.lowering.carriers import BlockGraphCarrier, TracedCarrier
from repro.core.lowering.donation import donatable_argnums, donation_hints

DN = (((1,), (0,)), ((), ()))
D = 8


def _mlp(depth=6):
    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, DN))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [jax.random.normal(jax.random.fold_in(key, i), (D, D)) * 0.3
              for i in range(depth)]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, D))
    return fn, (params, x)


def _halved_budget(fn, args):
    from repro.core.jaxpr_graph import trace as jtrace
    from repro.core.liveness import vanilla_peak

    return vanilla_peak(jtrace(fn, *args).graph, liveness=False) / 2


def _planned(fn, args, **kw):
    planner = Planner(cache=PlanCache())
    pf = plan_function(fn, _halved_budget(fn, args), planner=planner, **kw)
    return pf.lowered_for(*args)


def _assert_bits(got, ref):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    for a, b in zip(jax.tree_util.tree_leaves(got[1]),
                    jax.tree_util.tree_leaves(ref[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------- hint shape


def test_donation_hints_are_dead_at_window(rng):
    from conftest import random_dag
    from repro.core import dp as dp_mod
    from repro.core.lower_sets import all_lower_sets

    for trial in range(20):
        g = random_dag(rng, rng.randint(2, 8))
        fam = all_lower_sets(g)
        B = dp_mod.min_feasible_budget_exact(g, fam)
        res = dp_mod.solve(g, B, fam)
        if not res.feasible:
            continue
        plan = make_plan(g, res.sequence)
        hints = donation_hints(g, plan)
        cached_names = {g.nodes[v].name for v in plan.cached}
        assert set(hints) == {seg.index for seg in plan.segments}
        for seg in plan.segments:
            names = set(hints[seg.index])
            # exactly the cached residuals outside this window's lower set
            assert names == {
                g.nodes[v].name for v in plan.cached - seg.lower_set
            }, trial
            assert names <= cached_names
            assert not names & {g.nodes[v].name for v in seg.lower_set}
        # the last window holds every cached residual: nothing is dead
        assert hints[plan.segments[-1].index] == ()


def test_donatable_argnums_skip_differentiated():
    fn, args = _mlp(3)
    c0 = TracedCarrier.trace(fn, args)  # argnums=0 (params)
    assert donatable_argnums(c0) == (1,)
    c_all = TracedCarrier.trace(fn, args, argnums=(0, 1))
    assert donatable_argnums(c_all) == ()
    # BlockGraph convention: f(params, inputs) — inputs donatable
    assert donatable_argnums(object()) == (1,)


# ----------------------------------------------------- values are unchanged


def test_donated_jaxpr_grads_bit_identical():
    """The donated twin == the jitted planned twin == jitted vanilla
    jax.value_and_grad, bit for bit (donation is a buffer hint, not a
    numeric change; the jit boundary itself is shared by all three)."""
    fn, args = _mlp()
    plain = _planned(fn, args, backend="jaxpr")
    donated = _planned(fn, args, backend="jaxpr", donate=True)
    assert donated.run.donate_argnums == (1,)
    assert set(donated.run.donation_hints) == {
        seg.index for seg in donated.plan.segments
    }
    with warnings.catch_warnings():
        # CPU backends warn that donation is unimplemented and ignore it
        warnings.simplefilter("ignore")
        out_donated = donated.run(*args)
    _assert_bits(out_donated, jax.jit(plain.run)(*args))
    _assert_bits(out_donated, jax.jit(jax.value_and_grad(fn))(*args))


def test_donated_segment_backend_bit_identical():
    from repro.core.blockgraph import Block, BlockGraph

    def lin_init(rng, *in_shapes):
        return {"w": jax.random.normal(rng, (D, D)) * 0.3}

    def lin(p, *xs):
        h = xs[0]
        for x in xs[1:]:
            h = lax.add(h, x)
        return lax.tanh(lax.dot_general(h, p["w"], DN))

    blocks = [Block("b0", lin, ("x",), lin_init)]
    for i in range(1, 5):
        blocks.append(Block(f"b{i}", lin, (f"b{i-1}",), lin_init))
    bg = BlockGraph(blocks, ["x"], ["b4"])
    params = bg.init(jax.random.PRNGKey(3), {"x": (4, D)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(4), (4, D))}
    loss_fn = lambda o: jnp.sum(o * o)

    planner = Planner(cache=PlanCache())
    carrier = BlockGraphCarrier(bg, loss_fn, params, inputs)
    budget = planner.min_feasible_budget(carrier.to_graph(), "exact_dp")
    plain = plan_function(bg, budget, loss_fn=loss_fn, backend="segment",
                          planner=planner).lowered_for(params, inputs)
    donated = plan_function(bg, budget, loss_fn=loss_fn, backend="segment",
                            donate=True, planner=planner
                            ).lowered_for(params, inputs)
    assert donated.run.donate_argnums == (1,)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out_donated = donated.run(params, inputs)
    _assert_bits(out_donated, jax.jit(plain.run)(params, inputs))


def test_donate_rejected_without_jit_boundary():
    fn, args = _mlp(3)
    for backend in ("interpreter",):
        pf = plan_function(fn, backend=backend, donate=True,
                           planner=Planner(cache=PlanCache()))
        with pytest.raises(ValueError, match="jit boundary"):
            pf.lowered_for(*args)
    from repro.core.lowering.base import reject_donate

    with pytest.raises(ValueError, match="jit boundary"):
        reject_donate("policy")


# --------------------------------------------------- audit + drift unchanged


def test_interpreter_audit_unchanged_by_donation():
    """Donation is lowering-local: the same plan's interpreter live-byte
    trace is identical before and after a donated lowering exists."""
    fn, args = _mlp()
    planner = Planner(cache=PlanCache())
    budget = _halved_budget(fn, args)
    pf_audit = plan_function(fn, budget, backend="interpreter",
                             track_live=True, planner=planner)
    _, _, live_before = pf_audit(*args)
    donated = plan_function(fn, budget, backend="jaxpr", donate=True,
                            planner=planner).lowered_for(*args)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        donated.run(*args)
    _, _, live_after = pf_audit(*args)
    assert live_before == live_after
    peak_live = max(b for _, b in live_after)
    assert peak_live <= donated.plan.peak_memory


def test_drift_gate_green_on_donated_twin():
    """check_hlo with donate=True: the donation-hinted compile passes the
    same conformance + memory-drift gate as the plain lowering."""
    from repro.analysis import check_hlo

    fn, args = _mlp(4)
    carrier = TracedCarrier.trace(fn, args)
    planner = Planner(cache=PlanCache())
    g = carrier.to_graph()
    rep = planner.plan(g, planner.min_feasible_budget(g))
    assert rep.plan is not None
    r = check_hlo(carrier, rep.plan, donate=True)
    assert r.ok, str(r.findings)
