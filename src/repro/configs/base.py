"""Config system: ModelConfig + the assigned input-shape registry.

Every architecture in ``repro.configs`` returns a ``ModelConfig``; shapes are
global (``SHAPES``) and pair with any LM arch.  ``reduced()`` produces the
CPU-smoke-test variant of a config (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256  # chunked-scan block length
    # xLSTM: index pattern of sLSTM blocks (others are mLSTM)
    slstm_every: int = 0  # 0 → none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k backbone blocks
    hybrid_shared_attn_every: int = 0
    # enc-dec (whisper): n_layers applies to each side
    encoder_decoder: bool = False
    # multimodal stub frontend: input_specs provides precomputed embeddings
    frontend: str = "none"  # none | vision | audio
    frontend_seq: int = 0  # patches / frames prepended to the text sequence
    # remat planning defaults (the paper's technique, first-class)
    remat_method: str = "approx_dp"  # approx_dp | exact_dp | chen | none | full
    remat_objective: str = "time_centric"
    remat_budget_frac: Optional[float] = None  # fraction of per-device HBM; None → min feasible

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def _attn_params(self) -> int:
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        p = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        if self.qkv_bias:
            p += h * dh + 2 * kv * dh
        return p

    def _mamba_params(self) -> int:
        d = self.d_model
        ssm = self.ssm or SSMConfig()
        d_inner = ssm.expand * d
        proj_out = 2 * d_inner + 2 * ssm.d_state + max(1, d_inner // 64)
        return d * proj_out + ssm.d_conv * d_inner + d_inner * d + d_inner + d

    def num_params(self) -> int:
        """Analytic parameter count, family-aware (feeds MODEL_FLOPS=6·N·D)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        ffn = 3 * d * self.d_ff if self.d_ff > 0 else 0
        moe = (
            d * self.moe.num_experts * (3 * self.moe.d_ff_expert + 1)
            if self.moe is not None
            else 0
        )
        if self.family == "ssm" and self.ssm and self.ssm.slstm_every:
            # xLSTM: mLSTM (4d² + gates) and sLSTM (5d²) blocks
            k = self.ssm.slstm_every
            mlstm = 4 * d * d + 2 * d * self.n_heads + 2 * d
            slstm = 5 * d * d + 2 * d
            per = ((k - 1) * mlstm + slstm) / k
            total = emb + head + L * (per + ffn) + d
        elif self.family == "ssm":
            total = emb + head + L * (self._mamba_params() + ffn) + d
        elif self.family == "hybrid":
            k = max(1, self.hybrid_shared_attn_every)
            shared = self._attn_params() + (2 * d) * d + ffn + 4 * d
            total = (
                emb + head + L * self._mamba_params() + (L // k) * 0  # reuse!
                + shared  # ONE shared block, applied L/k times
                + d
            )
        else:
            per_layer = self._attn_params() + 2 * d + (moe or ffn)
            total = emb + head + L * per_layer + d
            if self.encoder_decoder:
                total += L * per_layer  # decoder side (self+cross approx)
        return int(total)

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.num_params()
        d, L = self.d_model, self.n_layers
        dense = self.num_params() - L * (
            d * self.moe.num_experts * 3 * self.moe.d_ff_expert
        )
        return int(dense + L * d * self.moe.top_k * 3 * self.moe.d_ff_expert)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM / hybrid archs
# (see DESIGN.md §Arch-applicability).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 64) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(n_heads, cfg.n_kv_heads * n_heads // max(cfg.n_heads, 1)))
    if n_heads % n_kv:
        n_kv = 1
    changes = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 2,
        vocab_size=256,
        frontend_seq=8 if cfg.frontend != "none" else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            num_experts=4, top_k=2, d_ff_expert=d_model, capacity_factor=2.0
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm,
            d_state=16,
            chunk=8,
            slstm_every=2 if cfg.ssm.slstm_every else 0,
        )
    if cfg.hybrid_shared_attn_every:
        changes["hybrid_shared_attn_every"] = 2
    return dataclasses.replace(cfg, **changes)
