"""Chen's √n baseline + Appendix B articulation-point configuration."""

import random

from repro.core import articulation_points, candidate_split_points, chen_sqrt_n
from repro.core.graph import chain, from_cost_lists

from conftest import random_dag


def brute_articulation(g):
    """v is an articulation point iff removing it disconnects its component
    of the undirected graph."""
    import itertools

    n = g.n
    adj = [set() for _ in range(n)]
    for v, w in g.edges:
        adj[v].add(w)
        adj[w].add(v)

    def components(excl):
        seen = set()
        comps = 0
        for s in range(n):
            if s in seen or s == excl:
                continue
            comps += 1
            stack = [s]
            seen.add(s)
            while stack:
                u = stack.pop()
                for w in adj[u]:
                    if w not in seen and w != excl:
                        seen.add(w)
                        stack.append(w)
        return comps

    base = components(None)
    out = []
    for v in range(n):
        if components(v) > base - (0 if adj[v] else 1) and adj[v]:
            # removing v increased the component count (v's own removal
            # accounts for one fewer node, not one fewer component)
            if components(v) > base:
                out.append(v)
    return out


def test_articulation_points_vs_bruteforce(rng):
    for _ in range(80):
        g = random_dag(rng, rng.randint(2, 9), p=0.3)
        assert sorted(articulation_points(g)) == sorted(brute_articulation(g))


def test_chain_all_interior_are_candidates():
    g = chain(8)
    assert candidate_split_points(g) == list(range(1, 7))


def test_skip_connection_blocks_split():
    # paper §2: a skip connection from every layer to the output kills all
    # split candidates — Chen degenerates to a single segment
    n = 6
    edges = [(i, i + 1) for i in range(n - 1)] + [(i, n - 1) for i in range(n - 2)]
    g = from_cost_lists([1] * n, [1] * n, edges)
    assert candidate_split_points(g) == []
    res = chen_sqrt_n(g)
    assert res.num_segments == 1


def test_chen_sqrt_n_on_chain():
    g = chain(16)
    res = chen_sqrt_n(g)
    assert res.feasible
    g.check_increasing_sequence(res.sequence)
    # √n-ish segment count
    assert 2 <= res.num_segments <= 8


def test_chen_candidates_induce_valid_lower_sets(rng):
    for _ in range(40):
        g = random_dag(rng, 8, p=0.25)
        for c in candidate_split_points(g):
            assert g.is_lower_set(g.ancestors_of(c))


def test_chen_budgeted(rng):
    g = chain(12)
    res = chen_sqrt_n(g, budget=1e9)
    assert res.feasible
    g.check_increasing_sequence(res.sequence)
