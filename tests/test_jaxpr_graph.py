"""jaxpr → paper graph extraction + scan-aware FLOP/byte accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jaxpr_graph import (
    aval_bytes,
    eqn_flops_for,
    from_jaxpr,
    jaxpr_totals,
    trace,
)


def test_trace_simple_mlp():
    def f(w1, w2, x):
        h = jnp.tanh(x @ w1)
        return jnp.sum(h @ w2)

    w1 = jnp.ones((8, 16))
    w2 = jnp.ones((16, 4))
    x = jnp.ones((2, 8))
    jg = trace(f, w1, w2, x)
    g = jg.graph
    assert g.n >= 4
    kinds = {nd.kind for nd in g.nodes}
    assert "dot_general" in kinds
    # paper cost model: dots are heavy
    for nd in g.nodes:
        if nd.kind == "dot_general":
            assert nd.time == 10.0


def test_flops_model_matmul():
    def f(a, b):
        return a @ b

    a = jnp.ones((4, 8))
    b = jnp.ones((8, 16))
    closed = jax.make_jaxpr(f)(a, b)
    tot = jaxpr_totals(closed)
    assert tot["flops"] == pytest.approx(2 * 4 * 8 * 16, rel=0.01)


def test_scan_flops_multiply_by_length():
    """The whole point of jaxpr_totals: a scanned matmul counts length ×."""
    w = jnp.ones((16, 16))

    def step(h, _):
        return jnp.tanh(h @ w), None

    def f(h):
        out, _ = jax.lax.scan(step, h, None, length=10)
        return out

    h = jnp.ones((4, 16))
    t1 = jaxpr_totals(jax.make_jaxpr(f)(h))
    # unrolled reference
    def f_unrolled(h):
        for _ in range(10):
            h = jnp.tanh(h @ w)
        return h

    t2 = jaxpr_totals(jax.make_jaxpr(f_unrolled)(h))
    assert t1["flops"] == pytest.approx(t2["flops"], rel=0.05)


def test_remat_recompute_counted():
    """grad-of-checkpoint jaxprs contain the recompute — flops(remat) >
    flops(no remat) for the same math."""
    w = jnp.ones((32, 32))

    def block(h):
        return jnp.tanh(h @ w)

    def loss_plain(h):
        return jnp.sum(block(block(h)))

    def loss_remat(h):
        return jnp.sum(jax.checkpoint(block)(jax.checkpoint(block)(h)))

    h = jnp.ones((4, 32))
    f_plain = jaxpr_totals(jax.make_jaxpr(jax.grad(loss_plain))(h))["flops"]
    f_remat = jaxpr_totals(jax.make_jaxpr(jax.grad(loss_remat))(h))["flops"]
    assert f_remat > f_plain * 1.15


def test_aval_bytes():
    assert aval_bytes(jax.ShapeDtypeStruct((4, 4), jnp.float32)) == 64
    assert aval_bytes(jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)) == 32


def test_graph_edges_follow_dataflow():
    def f(x):
        a = x + 1
        b = a * 2
        return a + b

    jg = trace(f, jnp.ones(4))
    g = jg.graph
    # b depends on a; output depends on both
    order = g.topological_order()
    assert len(order) == g.n
    assert g.edges  # non-empty dependency structure
