"""granite-moe-3b-a800m — MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155.
"""

from .base import ModelConfig, MoEConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
    )
