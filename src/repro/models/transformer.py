"""Generic LM: dense / MoE / SSM / hybrid / VLM families from one ModelConfig.

Layer stack = repeated *units* (the repeating pattern: one block for dense,
"k mLSTM + 1 sLSTM" for xLSTM, "k Mamba2 + shared-attention" for Zamba2),
executed as ``lax.scan`` over stacked unit params.  The paper's recomputation
plan enters as ``segment_sizes``: units are partitioned into segments, each
segment scanned inside ``jax.checkpoint`` — the canonical strategy (§3) with
L_i = "first i segments of the unit chain", which for a chain is the *exact*
lower-set lattice, so the DP plan is optimal, not heuristic (DESIGN.md §3).

Decode carries per-unit caches (KV / SSM state / conv state) scanned
functionally alongside the stacked params.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.parallel.sharding import shard
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    embed,
    embedding_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    swiglu,
    swiglu_init,
    unembed,
    unembed_init,
)


# ---------------------------------------------------------------------------
# Unit patterns
# ---------------------------------------------------------------------------


def unit_pattern(cfg: ModelConfig) -> Tuple[List[str], int]:
    """Return (block kinds inside one unit, number of units)."""
    L = cfg.n_layers
    if cfg.family == "ssm" and cfg.ssm and cfg.ssm.slstm_every:
        k = cfg.ssm.slstm_every
        assert L % k == 0, (L, k)
        return ["mlstm"] * (k - 1) + ["slstm"], L // k
    if cfg.family == "ssm":
        return ["mamba"], L
    if cfg.family == "hybrid":
        k = cfg.hybrid_shared_attn_every
        assert k and L % k == 0, (L, k)
        return ["mamba"] * k + ["shared_attn"], L // k
    if cfg.moe is not None:
        return ["attn_moe"], L
    return ["attn_mlp"], L


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------


def _block_init(rng, kind: str, cfg: ModelConfig):
    d = cfg.d_model
    if kind == "attn_mlp":
        r1, r2 = jax.random.split(rng)
        return {
            "ln1": rmsnorm_init(d),
            "attn": attn.attention_init(
                r1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.qkv_bias
            ),
            "ln2": rmsnorm_init(d),
            "mlp": swiglu_init(r2, d, cfg.d_ff),
        }
    if kind == "attn_moe":
        r1, r2 = jax.random.split(rng)
        return {
            "ln1": rmsnorm_init(d),
            "attn": attn.attention_init(
                r1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.qkv_bias
            ),
            "ln2": rmsnorm_init(d),
            "moe": moe_mod.moe_init(r2, d, cfg.moe),
        }
    if kind == "mamba":
        return {"mamba": ssm_mod.mamba2_init(rng, d, cfg.ssm or SSMConfig())}
    if kind == "mlstm":
        return {"mlstm": ssm_mod.mlstm_init(rng, d, cfg.n_heads)}
    if kind == "slstm":
        return {"slstm": ssm_mod.slstm_init(rng, d)}
    raise ValueError(kind)


def _block_apply(p, h, h0, kind: str, cfg: ModelConfig, positions):
    """Full-sequence block forward.  h0 = embedding output (hybrid skip)."""
    if kind in ("attn_mlp", "attn_moe"):
        a = attn.attention(
            p["attn"],
            rmsnorm(p["ln1"], h),
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            positions=positions,
        )
        h = h + a
        hn = rmsnorm(p["ln2"], h)
        if kind == "attn_mlp":
            return h + swiglu(p["mlp"], hn)
        return h + moe_mod.moe_apply(p["moe"], hn, cfg.moe)
    if kind == "mamba":
        return ssm_mod.mamba2_apply(p["mamba"], h, cfg.ssm or SSMConfig())
    if kind == "mlstm":
        return ssm_mod.mlstm_apply(
            p["mlstm"], h, cfg.n_heads, (cfg.ssm or SSMConfig()).chunk
        )
    if kind == "slstm":
        return ssm_mod.slstm_apply(p["slstm"], h)
    raise ValueError(kind)


# Shared-attention block (zamba2): one param set reused at every application;
# concat(h, h0) is projected back to d_model first (the Zamba "concat" input).


def _shared_attn_init(rng, cfg: ModelConfig):
    r1, r2, r3 = jax.random.split(rng, 3)
    d = cfg.d_model
    return {
        "ln": rmsnorm_init(2 * d),
        "in_proj": {"w": (jax.random.normal(r1, (2 * d, d)) * (2 * d) ** -0.5)},
        "attn": attn.attention_init(
            r2, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.qkv_bias
        ),
        "ln2": rmsnorm_init(d),
        "mlp": swiglu_init(r3, d, cfg.d_ff),
    }


def _shared_attn_apply(p, h, h0, cfg: ModelConfig, positions):
    x = jnp.concatenate([h, h0], axis=-1)
    x = rmsnorm(p["ln"], x)
    x = jnp.einsum("bsd,de->bse", x, p["in_proj"]["w"].astype(h.dtype))
    a = attn.attention(
        p["attn"],
        x,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        rope_theta=cfg.rope_theta,
        positions=positions,
    )
    x = x + a
    return h + swiglu(p["mlp"], rmsnorm(p["ln2"], x))


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


class LM:
    """The language model; all methods are pure and jit/eval_shape friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern, self.n_units = unit_pattern(cfg)
        self.has_shared = "shared_attn" in self.pattern

    # ------------------------------------------------------------------ init

    def init(self, rng: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        rngs = jax.random.split(rng, self.n_units + 4)
        scan_kinds = [k for k in self.pattern if k != "shared_attn"]

        def unit_init(r):
            ks = jax.random.split(r, max(2, len(scan_kinds)))
            return {
                f"b{i}_{kind}": _block_init(ks[i], kind, cfg)
                for i, kind in enumerate(scan_kinds)
            }

        units = [unit_init(rngs[i]) for i in range(self.n_units)]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *units)
        params: Dict[str, Any] = {
            "embedding": embedding_init(rngs[-1], cfg.vocab_size, cfg.d_model),
            "layers": stacked,
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = unembed_init(rngs[-2], cfg.d_model, cfg.vocab_size)
        if self.has_shared:
            params["shared_attn"] = _shared_attn_init(rngs[-3], cfg)
        return params

    # ------------------------------------------------------- full-seq forward

    def _unit_fn(self, unit_params, h, h0, shared_params, positions):
        cfg = self.cfg
        i = 0
        for kind in self.pattern:
            if kind == "shared_attn":
                h = _shared_attn_apply(shared_params, h, h0, cfg, positions)
            else:
                h = _block_apply(
                    unit_params[f"b{i}_{kind}"], h, h0, kind, cfg, positions
                )
                i += 1
        # unit boundary = the plan's cache candidate ∂(L_i): sequence-parallel
        # (S/tp per device), so cached boundaries cost h/tp — Megatron SP.
        return shard(h, "batch", "seq_act", None)

    def forward(
        self,
        params: Dict[str, Any],
        tokens: jax.Array,
        extra_embeds: Optional[jax.Array] = None,
        segment_sizes: Optional[Tuple[int, ...]] = None,
        segment_remat: Optional[Tuple[bool, ...]] = None,
    ) -> jax.Array:
        """tokens (B, S) → logits (B, S', V).  extra_embeds (B, F, D) is the
        multimodal stub frontend output, prepended to the token embeddings."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        h = embed(params["embedding"], tokens, dt)
        if extra_embeds is not None:
            h = jnp.concatenate([extra_embeds.astype(dt), h], axis=1)
        h = shard(h, "batch", None, "model")
        S = h.shape[1]
        positions = jnp.arange(S)[None, :]
        h0 = h
        shared = params.get("shared_attn")

        def unit_body(carry, unit_params):
            h = carry
            h = self._unit_fn(unit_params, h, h0, shared, positions)
            return h, None

        h = scan_over_segments(
            h, params["layers"], unit_body, self.n_units,
            segment_sizes, segment_remat,
        )

        h = rmsnorm(params["final_norm"], h)
        head = params.get("head")
        if head is None:
            logits = jnp.einsum(
                "bsd,vd->bsv", h, params["embedding"]["embed"].astype(h.dtype)
            ).astype(jnp.float32)
        else:
            logits = unembed(head, h)
        return logits

    def loss(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        segment_sizes: Optional[Tuple[int, ...]] = None,
        segment_remat: Optional[Tuple[bool, ...]] = None,
    ) -> jax.Array:
        logits = self.forward(
            params,
            batch["tokens"],
            extra_embeds=batch.get("extra_embeds"),
            segment_sizes=segment_sizes,
            segment_remat=segment_remat,
        )
        labels = batch["labels"]
        F = logits.shape[1] - labels.shape[1]
        if F > 0:  # multimodal prefix positions carry no labels
            logits = logits[:, F:]
        return softmax_xent(logits[:, :-1], labels[:, 1:])

    # ------------------------------------------------------------------ decode

    def init_caches(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = cfg.activation_dtype
        scan_kinds = [k for k in self.pattern if k != "shared_attn"]

        def one_unit():
            c: Dict[str, Any] = {}
            for i, kind in enumerate(scan_kinds):
                key = f"b{i}_{kind}"
                if kind in ("attn_mlp", "attn_moe"):
                    c[key] = {
                        "k": jnp.zeros(
                            (batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt
                        ),
                        "v": jnp.zeros(
                            (batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt
                        ),
                    }
                elif kind == "mamba":
                    c[key] = ssm_mod.mamba2_init_state(
                        batch, cfg.d_model, cfg.ssm or SSMConfig(), dt
                    )
                elif kind == "mlstm":
                    c[key] = ssm_mod.mlstm_init_state(batch, cfg.d_model, cfg.n_heads)
                elif kind == "slstm":
                    c[key] = ssm_mod.slstm_init_state(batch, cfg.d_model)
            return c

        units = [one_unit() for _ in range(self.n_units)]
        caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *units)
        if self.has_shared:
            k = self.pattern.count("shared_attn") * self.n_units
            caches = {
                "units": caches,
                "shared": {
                    "k": jnp.zeros(
                        (self.n_units, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                        dt,
                    ),
                    "v": jnp.zeros(
                        (self.n_units, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                        dt,
                    ),
                },
            }
        return caches

    def _block_step(self, p, h, cache, kind: str, position):
        cfg = self.cfg
        if kind in ("attn_mlp", "attn_moe"):
            a, ck, cv = attn.decode_attention(
                p["attn"],
                rmsnorm(p["ln1"], h),
                cache["k"],
                cache["v"],
                position,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim,
                rope_theta=cfg.rope_theta,
            )
            h = h + a
            hn = rmsnorm(p["ln2"], h)
            if kind == "attn_mlp":
                h = h + swiglu(p["mlp"], hn)
            else:
                h = h + moe_mod.moe_apply(p["moe"], hn, cfg.moe)
            return h, {"k": ck, "v": cv}
        if kind == "mamba":
            return ssm_mod.mamba2_step(p["mamba"], h, cache, cfg.ssm or SSMConfig())
        if kind == "mlstm":
            return ssm_mod.mlstm_step(p["mlstm"], h, cache, cfg.n_heads)
        if kind == "slstm":
            return ssm_mod.slstm_step(p["slstm"], h, cache)
        raise ValueError(kind)

    def _shared_step(self, p, h, h0, cache, position):
        cfg = self.cfg
        x = jnp.concatenate([h, h0], axis=-1)
        x = rmsnorm(p["ln"], x)
        x = jnp.einsum("bsd,de->bse", x, p["in_proj"]["w"].astype(h.dtype))
        a, ck, cv = attn.decode_attention(
            p["attn"],
            x,
            cache["k"],
            cache["v"],
            position,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
        x = x + a
        h = h + swiglu(p["mlp"], rmsnorm(p["ln2"], x))
        return h, {"k": ck, "v": cv}

    def decode_step(
        self,
        params: Dict[str, Any],
        tokens: jax.Array,  # (B, 1)
        caches: Dict[str, Any],
        position: jax.Array,  # (B,)
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        dt = cfg.activation_dtype
        h = embed(params["embedding"], tokens, dt)
        h = shard(h, "batch", None, "model")
        h0 = h
        shared = params.get("shared_attn")
        scan_kinds = [k for k in self.pattern if k != "shared_attn"]

        unit_caches = caches["units"] if self.has_shared else caches
        shared_caches = caches.get("shared") if self.has_shared else None

        def unit_body(carry, xs):
            h = carry
            if self.has_shared:
                unit_params, cache, sh_cache = xs
            else:
                unit_params, cache = xs
                sh_cache = None
            new_cache: Dict[str, Any] = {}
            i = 0
            for kind in self.pattern:
                if kind == "shared_attn":
                    h, sh_cache = self._shared_step(shared, h, h0, sh_cache, position)
                else:
                    key = f"b{i}_{kind}"
                    h, new_cache[key] = self._block_step(
                        unit_params[key], h, cache[key], kind, position
                    )
                    i += 1
            if self.has_shared:
                return h, (new_cache, sh_cache)
            return h, new_cache

        if self.has_shared:
            h, (new_unit_caches, new_shared) = jax.lax.scan(
                unit_body, h, (params["layers"], unit_caches, shared_caches)
            )
            new_caches = {"units": new_unit_caches, "shared": new_shared}
        else:
            h, new_caches = jax.lax.scan(
                unit_body, h, (params["layers"], unit_caches)
            )

        h = rmsnorm(params["final_norm"], h)
        head = params.get("head")
        if head is None:
            logits = jnp.einsum(
                "bsd,vd->bsv", h, params["embedding"]["embed"].astype(h.dtype)
            ).astype(jnp.float32)
        else:
            logits = unembed(head, h)
        return logits, new_caches


def default_segments(n_units: int) -> Tuple[int, ...]:
    """√n segmentation fallback when no DP plan is supplied (Chen-style)."""
    import math

    k = max(1, int(math.isqrt(n_units)))
    sizes = [n_units // k] * k
    for i in range(n_units - sum(sizes)):
        sizes[i] += 1
    return tuple(sizes)


def scan_over_segments(
    h: jax.Array,
    stacked: Any,
    unit_body,
    n_units: int,
    segment_sizes: Optional[Tuple[int, ...]] = None,
    segment_remat: Optional[Tuple[bool, ...]] = None,
) -> jax.Array:
    """Execute the unit chain under a (sizes, remat-flags) canonical plan.

    ``unit_body(h, unit_params) -> (h, None)`` is a scan body.  Runs of equal
    (size, remat) segments lower to ONE nested scan — outer over groups,
    inner (jax.checkpoint-wrapped iff remat) over the units of a group — so
    the HLO holds a single body per run regardless of segment count.  This is
    the canonical strategy (§3) on the unit chain: checkpointed group inputs
    are exactly the cached boundaries ∂(L_i).
    """
    segs = tuple(segment_sizes or default_segments(n_units))
    assert sum(segs) == n_units, (segs, n_units)
    remat = tuple(
        segment_remat if segment_remat is not None
        else (len(segs) > 1 for _ in segs)
    )
    assert len(remat) == len(segs)

    def seg_fn(h_, sl_):
        out, _ = jax.lax.scan(unit_body, h_, sl_)
        return out

    # group consecutive segments with identical (size, remat)
    runs: list = []
    for s, r in zip(segs, remat):
        if runs and runs[-1][0] == s and runs[-1][1] == r:
            runs[-1][2] += 1
        else:
            runs.append([s, r, 1])

    offset = 0
    for size, do_remat, count in runs:
        block = jax.tree_util.tree_map(
            lambda a: a[offset : offset + size * count], stacked
        )
        if count > 1:
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((count, size) + a.shape[1:]), block
            )
            inner = jax.checkpoint(seg_fn) if do_remat else seg_fn

            def outer(c, grp, _inner=inner):
                return _inner(c, grp), None

            h, _ = jax.lax.scan(outer, h, grouped)
        else:
            h = jax.checkpoint(seg_fn)(h, block) if do_remat else seg_fn(h, block)
        offset += size * count
    return h
