from .store import (
    AsyncCheckpointer,
    atomic_write_json,
    latest_step,
    read_json,
    restore,
    retain,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "save",
    "restore",
    "latest_step",
    "retain",
    "atomic_write_json",
    "read_json",
]
