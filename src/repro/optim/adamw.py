"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

Pure-JAX (no optax dependency).  Optimizer state is a pytree shaped like the
params, so it inherits the parameter sharding (and can additionally be
ZeRO-1-scattered over the data axis — see ``zero1_partition_spec``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # ()
    mu: Any  # pytree like params
    nu: Any  # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    lr = lr_schedule(cfg, step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        AdamWState(
            step=step,
            mu=jax.tree_util.tree_unflatten(treedef, new_m),
            nu=jax.tree_util.tree_unflatten(treedef, new_v),
        ),
        metrics,
    )
