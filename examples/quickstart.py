"""Quickstart: the paper's pipeline in 40 lines.

1. Describe (or trace) a network as the paper's graph G = (V, E).
2. Solve the General Recomputation Problem under a memory budget.
3. Execute the canonical strategy and verify it computes the same gradients.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    exact_dp,
    min_feasible_budget,
    make_plan,
    plan_summary,
    simulate,
    vanilla_peak,
)
from repro.core.blockgraph import Block, BlockGraph
from repro.core.executor import planned_value_and_grad, vanilla_value_and_grad


def lin_init(rng, *in_shapes):
    din = sum(s[-1] for s in in_shapes)
    return {"w": jax.random.normal(rng, (din, 32)) * 0.2}


def lin(p, *xs):
    x = jnp.concatenate(xs, axis=-1) if len(xs) > 1 else xs[0]
    return jnp.tanh(x @ p["w"])


# 1. an 8-block MLP with a skip connection — a small "general graph"
blocks = [Block("b1", lin, ("x",), lin_init)]
for i in range(2, 8):
    blocks.append(Block(f"b{i}", lin, (f"b{i-1}",), lin_init))
blocks.append(Block("b8", lin, ("b7", "b2"), lin_init))  # skip: b2 → b8
bg = BlockGraph(blocks, ["x"], ["b8"])

params = bg.init(jax.random.PRNGKey(0), {"x": (16, 32)})
inputs = {"x": jax.random.normal(jax.random.PRNGKey(1), (16, 32))}

# 2. the paper's graph + the general recomputation problem
g = bg.to_graph(params, inputs)
B = min_feasible_budget(g, "exact_dp")
result = exact_dp(g, B)
plan = make_plan(g, result.sequence)
print(plan_summary(g, plan))
print(f"vanilla peak   : {vanilla_peak(g):.0f} bytes")
print(f"planned peak   : {simulate(g, result.sequence).peak_memory:.0f} bytes "
      f"(budget {B:.0f})")
print(f"overhead       : {result.overhead:.0f} T-units "
      f"({100 * result.overhead / g.total_time:.0f}% of one forward)")

# 3. canonical strategy == vanilla backprop, exactly
loss = lambda out: jnp.sum(out**2)
l0, g0 = vanilla_value_and_grad(bg, loss)(params, inputs)
l1, g1 = planned_value_and_grad(bg, plan, loss)(params, inputs)
diff = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1))
)
print(f"loss match: {float(l0):.6f} == {float(l1):.6f}; max grad diff {diff:.2e}")
assert diff < 1e-5
print("OK — the canonical strategy never alters the computation (§3).")
