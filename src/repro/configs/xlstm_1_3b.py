"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  Units of 8 blocks
(7 mLSTM + 1 sLSTM); d_ff=0 — no separate FFN, per the xLSTM design.
"""

from .base import ModelConfig, SSMConfig

ARCH_ID = "xlstm-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm=SSMConfig(d_state=64, chunk=256, slstm_every=8),
        rope_theta=0.0,
    )
