"""High-level planning API: solve the general recomputation problem for a
graph (or a traced JAX function) under a memory budget.

The paper's §5.1 protocol: "for the memory budget B … we chose the minimal
value B for which the solution … exists.  This value was determined using
binary search."  The budget-sweep engine (``core.dp.sweep``) retires that
search: ``min_feasible_budget`` reads the *exact* minimal budget off the
sweep's terminal frontier, and ``plan`` is the one-call front door used by
the framework.  Budgets are priced by the DP's liveness-tight memory
functional (``dp.MEMORY_FUNCTIONAL``; see core/dp.py) — a strategy is
feasible at B iff its last-use-liveness execution peak fits B.

Plan compilation pipeline (beyond-paper): planning is memoized through
``core.plan_cache`` behind a canonical graph digest.  For the DP methods
the cached object is a **budget-free sweep** — the full ``(t, m, peak)``
Pareto surface of ``core.dp.sweep``, stored under the ``sweep`` entry kind
keyed by ``(graph_digest, family, objective)`` with *no budget* — so one
cold solve admits every future budget query on that graph: per-budget
``solve`` calls become frontier lookups (bit-identical to the per-budget
DP), ``min_feasible_budget`` becomes a terminal-frontier min, and whole
trade-off grids (benchmarks/fig3_tradeoff.py) cost one DP pass.
``Planner`` is the stateful front door carrying the cache, a small decoded
sweep memo, and an optional measured cost model (``core.cost_model``); the
module-level ``plan``/``min_feasible_budget`` functions route through a
process-default ``Planner`` so existing callers inherit the caching
transparently.
"""

from __future__ import annotations

import dataclasses
import logging
import time as _time
from typing import List, Optional, Sequence, Tuple, Union

from . import dp as dp_mod
from .chen import chen_sqrt_n
from .cost_model import OpProfile, calibrated_graph
from .dp import DPResult, approx_dp, exact_dp, solve
from .graph import Graph, NodeSet, canonical_maps, graph_digest
from .liveness import simulate
from .lower_sets import all_lower_sets, pruned_lower_sets
from .plan_cache import PlanCache, SweepKey, default_cache
from .schedule import ExecutionPlan, make_plan
from .strategies import StrategyConfig

_LOG = logging.getLogger(__name__)


@dataclasses.dataclass
class PlanReport:
    """Everything the framework (and the benchmarks) need about one plan."""

    method: str  # "exact_dp" | "approx_dp" | "chen" | "vanilla"
    objective: str  # "time_centric" | "memory_centric" | "wallclock" | "-"
    budget: float
    result: DPResult
    plan: Optional[ExecutionPlan]
    peak_with_liveness: float
    peak_without_liveness: float
    plan_seconds: float
    # Replayed step time (core.replay, overlap on, budget-headroom overlap
    # stream) — filled for objective="wallclock" plans, None otherwise.
    replayed_seconds: Optional[float] = None

    @property
    def feasible(self) -> bool:
        return self.result.feasible


def _surface_objective(objective: str) -> str:
    """Sweep-surface key for an objective.

    "wallclock" shares the time-centric transition surface bit-for-bit
    (only *extraction* differs: replay ranking instead of min-t), so it
    reuses — and warms — the ``time_centric`` cache entry instead of
    storing a duplicate.
    """
    return "time_centric" if objective == "wallclock" else objective


def _family(g: Graph, method: str) -> Sequence[NodeSet]:
    """Canonical lower-set family for ``method``.

    ``exact_dp`` falls back to the pruned family (§4.3) when 𝓛_G overflows
    ``lower_sets.DEFAULT_LOWER_SET_LIMIT`` — that is the paper's own escape
    hatch for wide graphs, and it keeps the planner total (a logged note
    replaces the ``RuntimeError`` the raw enumeration raises).
    """
    if method == "exact_dp":
        from . import lower_sets

        try:
            return all_lower_sets(g, limit=lower_sets.DEFAULT_LOWER_SET_LIMIT)
        except RuntimeError as e:
            _LOG.warning(
                "exact lower-set family overflowed for %r (%s); "
                "falling back to the pruned family (§4.3)", g, e,
            )
            return pruned_lower_sets(g)
    if method == "approx_dp":
        return pruned_lower_sets(g)
    raise ValueError(method)


def _min_feasible_budget_uncached(
    g: Graph,
    method: str = "approx_dp",
    tol: float = 1e-3,
    family: Optional[Sequence[NodeSet]] = None,
) -> float:
    """Binary search the minimal B with a feasible canonical strategy (§5.1).

    Superseded by the exact terminal-frontier minimum of ``core.dp.sweep``
    (see ``Planner.min_feasible_budget``); kept as the paper-faithful
    reference that benchmarks/dp_runtime.py compares the sweep against.

    Bounds: any strategy needs at least max_v M_v; the single-segment
    strategy needs at most 2·M(V) plus one cached boundary value, so we
    search in [max_v M_v, 2·M(V) + max_v M_v] to relative tolerance
    ``tol``, using the fast feasibility-only DP (core.dp.feasible) per
    probe.  The returned budget is always one of the *feasible* probes
    (``hi`` only ever shrinks onto feasible midpoints), which the final
    check enforces.
    """
    from .dp import _prepare, feasible

    fam = list(family) if family is not None else list(_family(g, method))
    infos = _prepare(g, fam)
    lo = max(g.mem_v)
    hi = 2.0 * g.total_memory + max(g.mem_v)
    # verify hi feasible
    if not feasible(g, hi, fam, infos):
        raise RuntimeError("even the maximal budget is infeasible — bug")
    while hi - lo > tol * max(1.0, hi):
        mid = 0.5 * (lo + hi)
        if feasible(g, mid, fam, infos):
            hi = mid
        else:
            lo = mid
    if not feasible(g, hi, fam, infos):  # pragma: no cover — invariant guard
        raise RuntimeError(
            f"binary search returned an infeasible budget {hi!r} — bug"
        )
    return hi


class Planner:
    """Stateful planning front door: DP + plan cache + optional cost model.

    * ``cache``  — a ``core.plan_cache.PlanCache``; defaults to the process
      default cache (in-memory LRU, plus disk when a cache dir is attached).
    * ``profile``— an ``OpProfile`` from ``core.cost_model``; when set, every
      graph is re-priced to measured seconds and re-quantized before the DP,
      so the solved t-axis reflects the hardware instead of FLOP proxies.
    * ``quantize_levels`` — integer t-axis resolution for the calibration
      path (also usable without a profile to quantize FLOP-valued graphs).

    Budget sweeps: ``solve_grid``/``frontier`` build one **budget-free
    sweep** (``core.dp.sweep``) cached under ``(graph_digest, family,
    objective)`` — no budget in the key — and every ``solve`` first checks
    for one, so any budget on a swept graph is a frontier lookup,
    bit-identical to the per-budget DP.  When a later query outgrows a
    cached *capped* surface, the surface is **lazily extended**
    (``Sweep.extend``: only the new budget band is materialized; the cap
    only ever grows) instead of rebuilt.  ``min_feasible_budget`` is exact
    (one scalar pass, no binary search).  Custom lower-set families bypass
    the cache (their identity isn't captured by the method name).
    """

    CACHEABLE_METHODS = ("exact_dp", "approx_dp")

    def __init__(
        self,
        cache: Optional[PlanCache] = None,
        profile: Optional[OpProfile] = None,
        quantize_levels: Optional[int] = None,
        sweep_max_states: int = 10_000_000,
        strategies: Optional[Union[StrategyConfig, Sequence[str]]] = None,
    ):
        self.cache = default_cache() if cache is None else cache
        self.profile = profile
        self.quantize_levels = quantize_levels
        # Joint memory-strategy planning (core.strategies): a StrategyConfig
        # or a tuple of strategy names.  Names are priced with the profile's
        # measured host/codec bandwidths when one is attached.  A config
        # that enables nothing beyond {store, recompute} is normalized to
        # None — the planner then behaves (and caches) exactly as the
        # binary planner always has.
        if strategies is not None and not isinstance(strategies, StrategyConfig):
            strategies = StrategyConfig(
                strategies=tuple(strategies),
                offload_bytes_per_sec=(
                    profile.host_bytes_per_sec if profile is not None else 0.0
                ),
                quantize_bytes_per_sec=(
                    profile.quantize_bytes_per_sec if profile is not None else 0.0
                ),
            )
        if strategies is not None and not strategies.extended:
            strategies = None
        self.strategies = strategies
        self._strategy_token = (
            strategies.digest_token() if strategies is not None else ""
        )
        # Work cap for budget-free sweeps (dp.sweep max_states): surfaces
        # wider than this fall back to per-budget DP solves deterministically.
        self.sweep_max_states = sweep_max_states
        # Tiny memo of the most recent canonical lower-set families:
        # enumerating 𝓛_G is the dominant cold-path cost (§4.2), and one
        # budget search + solve (or a multi-budget sweep) re-enumerates the
        # same family many times.  Kept small — families can be exponential.
        from collections import OrderedDict

        self._family_memo: "OrderedDict[Tuple[str, str], List[NodeSet]]" = (
            OrderedDict()
        )
        # Decoded sweeps (canonical coordinates), so repeat budget queries
        # skip both the DP and the cache-entry decode.  The PlanCache tiers
        # below this hold the JSON-able form.
        self._sweep_memo: "OrderedDict[Tuple[str, str, str], dp_mod.Sweep]" = (
            OrderedDict()
        )

    def family(self, g: Graph, method: str = "approx_dp") -> Sequence[NodeSet]:
        """The canonical lower-set family for ``method`` (memoized).

        Public so tooling (e.g. examples/plan_explorer.py) can inspect the
        family without paying a second enumeration on top of the planner's.
        """
        return self._family_for(self.prepare(g), method)

    def _family_for(self, gp: Graph, method: str) -> Sequence[NodeSet]:
        key = (graph_digest(gp), method)
        fam = self._family_memo.get(key)
        if fam is None:
            fam = list(_family(gp, method))
            self._family_memo[key] = fam
            while len(self._family_memo) > 4:
                self._family_memo.popitem(last=False)
        else:
            self._family_memo.move_to_end(key)
        return fam

    # -------------------------------------------------------------- prepare

    def prepare(self, g: Graph) -> Graph:
        """Apply the measured cost model / quantization (identity without)."""
        if self.profile is not None:
            return calibrated_graph(
                g, self.profile, levels=self.quantize_levels or 64
            )
        if self.quantize_levels:
            return dp_mod.quantize_times(g, levels=self.quantize_levels)
        return g

    # ---------------------------------------------------------------- sweeps

    def _sweep_memo_put(self, key: Tuple[str, str, str], sw: dp_mod.Sweep) -> None:
        self._sweep_memo[key] = sw
        self._sweep_memo.move_to_end(key)
        while len(self._sweep_memo) > 4:
            self._sweep_memo.popitem(last=False)

    def _cached_sweep(
        self, gp: Graph, method: str, objective: str, count_miss: bool = False
    ) -> Optional[dp_mod.Sweep]:
        """An already-available sweep (memo or cache), never a fresh build.

        ``count_miss=False`` makes the cache probe silent on miss — used by
        ``solve``/``min_feasible_budget``, whose own primary lookups do the
        stats accounting; a found sweep is always counted as a hit.
        """
        key = (graph_digest(gp), method, objective)
        sw = self._sweep_memo.get(key)
        if sw is not None:
            self._sweep_memo.move_to_end(key)
            return sw
        if self.cache is not None:
            sw = self.cache.get_sweep(SweepKey(*key), count_miss=count_miss)
            if sw is not None:
                self._sweep_memo_put(key, sw)
        return sw

    def _build_sweep(
        self,
        gp: Graph,
        method: str,
        objective: str,
        cap: Optional[float],
        raise_overflow: bool = False,
        prior: Optional[dp_mod.Sweep] = None,
    ) -> Optional[dp_mod.Sweep]:
        """Build (or lazily extend) + cache a sweep; on ``sweep_max_states``
        overflow either re-raise (``raise_overflow``) or return None (the
        caller falls back to per-budget solves).

        ``prior`` is an already-cached *capped* sweep in canonical
        coordinates: instead of rebuilding, its surface is grown to ``cap``
        via ``Sweep.extend`` (cap only ever grows; the cache key is
        budget-free, so the extended surface simply replaces the entry).
        """
        to_pos, from_pos = canonical_maps(gp)
        try:
            if prior is not None and prior.cap is not None:
                # canonical → graph coordinates, extend, and back
                sw = prior.remap(from_pos).extend(
                    gp, cap=cap, max_states=self.sweep_max_states
                )
            else:
                fam = self._family_for(gp, method)
                sw = dp_mod.sweep(gp, fam, objective,
                                  max_states=self.sweep_max_states, cap=cap)
        except dp_mod.SweepOverflow as e:
            if raise_overflow:
                raise
            _LOG.info("budget sweep overflow for %r (%s); "
                      "falling back to per-budget DP", gp, e)
            return None
        sw = sw.to_canonical(to_pos)
        key = (graph_digest(gp), method, objective)
        if self.cache is not None:
            self.cache.put_sweep(SweepKey(*key), sw)
        self._sweep_memo_put(key, sw)
        return sw

    def _extract(
        self, sw: dp_mod.Sweep, gp: Graph, budget: float
    ) -> Optional[DPResult]:
        """Budget-B frontier lookup, validated against ``gp``; None means the
        sweep is unusable for this graph (corruption / digest collision)."""
        try:
            ok, t_star, masks = sw.extract(budget)
        except (KeyError, IndexError, TypeError, ValueError):
            return None
        if not ok:
            return DPResult([], dp_mod.INF, dp_mod.INF, feasible=False,
                            states_visited=sw.states_visited)
        _, from_pos = canonical_maps(gp)
        try:
            seq = [
                frozenset(from_pos[p] for p in dp_mod.mask_iter(mk))
                for mk in masks
            ]
            gp.check_increasing_sequence(seq)
        except (ValueError, IndexError, KeyError):
            return None
        return DPResult(
            sequence=seq,
            overhead=t_star,
            peak_memory=dp_mod.peak_memory_live(gp, seq),
            feasible=True,
            states_visited=sw.states_visited,
        )

    def _extract_wallclock(
        self, sw: dp_mod.Sweep, gp: Graph, budget: float
    ) -> Optional[DPResult]:
        """Replay-ranked budget-B extraction (``objective="wallclock"``).

        The sweep is stored in canonical coordinates; remap to the graph's
        own labels first, then rank every feasible terminal by replayed
        step time (``dp.Sweep.extract_wallclock``).  ``gp`` is already
        calibrated by :meth:`prepare`, so the replay reads its ``T_v``
        directly — the ranking is profile-aware through the calibration.
        """
        _, from_pos = canonical_maps(gp)
        try:
            res = sw.remap(from_pos).extract_wallclock(gp, budget)
        except (KeyError, IndexError, TypeError, ValueError):
            return None
        if res.feasible:
            try:
                gp.check_increasing_sequence(res.sequence)
            except (ValueError, IndexError, KeyError):
                return None
        return res

    def _solve_wallclock(
        self, gp: Graph, budget: float, method: str
    ) -> DPResult:
        """Wall-clock plan selection over the shared time-centric surface.

        The "wallclock" objective needs the whole candidate set at
        ``budget``, not one extraction — and its transition surface is
        bit-identical to the time-centric one — so it reuses (and warms)
        the *time_centric* sweep cache entry rather than storing a
        duplicate surface under its own key.  On sweep overflow the
        objective degrades to plain time-centric selection (logged).
        """
        sw = self._cached_sweep(gp, method, "time_centric", count_miss=True)
        if sw is None or not sw.covers(budget):
            sw = self._build_sweep(gp, method, "time_centric", cap=budget,
                                   prior=sw)
        if sw is not None:
            res = self._extract_wallclock(sw, gp, budget)
            if res is not None:
                return res
        _LOG.info("wallclock selection unavailable for %r (sweep overflow "
                  "or corrupt entry); degrading to time_centric", gp)
        return self.solve(gp, budget, method, "time_centric", prepared=True)

    def prewarm(
        self,
        g: Graph,
        method: str = "exact_dp",
        objective: str = "time_centric",
    ) -> bool:
        """Make sure a **full** budget-free sweep for ``(g, method,
        objective)`` is hot in this planner's tiers; returns True when it
        already was (memo, disk, or fleet store — no DP ran).

        This is the boot-time pre-warm hook: a serving replica calls it for
        every expected planning signature before taking traffic, so its
        first planned step is a warm frontier lookup.  In a fleet with a
        shared store exactly one replica pays the cold solve — everyone
        else read-throughs the pushed sweep.  A sweep wider than
        ``sweep_max_states`` stays unwarmed (False; ``solve`` falls back to
        the per-budget DP as usual).
        """
        if self.strategies is not None:
            raise ValueError(
                "prewarm builds binary (all-store) sweep surfaces; strategy "
                "planners solve per budget and have nothing to pre-warm"
            )
        gp = self.prepare(g)
        objective = _surface_objective(objective)
        sw = self._cached_sweep(gp, method, objective, count_miss=False)
        if sw is not None and sw.cap is None:
            return True
        self._build_sweep(gp, method, objective, cap=None, prior=sw)
        return False

    def frontier(
        self,
        g: Graph,
        method: str = "approx_dp",
        objective: str = "time_centric",
        prepared: bool = False,
    ) -> List[Tuple[float, float]]:
        """The full (budget → overhead) Pareto staircase from one sweep.

        Each entry is a critical budget and the overhead it unlocks; the
        plan at any budget B is ``solve(g, B, ...)`` (a frontier lookup on
        the same cached sweep).  Raises ``dp.SweepOverflow`` when the full
        surface exceeds ``sweep_max_states`` — use ``solve_grid`` with
        explicit budgets (a capped, much cheaper sweep) in that case.
        """
        if self.strategies is not None:
            raise ValueError(
                "frontier() reads the binary (all-store) sweep surface; use "
                "solve_grid for a strategy planner's budget staircase"
            )
        gp = g if prepared else self.prepare(g)
        objective = _surface_objective(objective)
        sw = self._cached_sweep(gp, method, objective, count_miss=True)
        if sw is None or sw.cap is not None:
            sw = self._build_sweep(gp, method, objective, cap=None,
                                   raise_overflow=True, prior=sw)
        return sw.frontier()

    def solve_grid(
        self,
        g: Graph,
        budgets: Sequence[float],
        method: str = "approx_dp",
        objective: str = "time_centric",
        prepared: bool = False,
    ) -> List[DPResult]:
        """Solve a whole budget grid from one (capped) sweep.

        One DP pass capped at ``max(budgets)`` answers every point —
        bit-identical to per-budget ``solve`` at each — and is cached, so
        re-grids and co-located jobs pay nothing.  Falls back to per-budget
        solves when the capped surface still overflows
        ``sweep_max_states``.
        """
        budgets = list(budgets)
        if not budgets:
            return []
        gp = g if prepared else self.prepare(g)
        if method in self.CACHEABLE_METHODS and self.strategies is None:
            b_max = max(budgets)
            surface = _surface_objective(objective)
            sw = self._cached_sweep(gp, method, surface, count_miss=True)
            if sw is None or not sw.covers(b_max):
                # lazy refinement: an existing capped surface grows to the
                # new largest budget instead of being rebuilt
                sw = self._build_sweep(gp, method, surface, cap=b_max,
                                       prior=sw)
            if sw is not None:
                out = [
                    self._extract_wallclock(sw, gp, b)
                    if objective == "wallclock"
                    else self._extract(sw, gp, b)
                    for b in budgets
                ]
                if all(r is not None for r in out):
                    return out
        return [
            self.solve(gp, b, method, objective, prepared=True)
            for b in budgets
        ]

    # ---------------------------------------------------------------- solve

    def solve(
        self,
        g: Graph,
        budget: float,
        method: str = "approx_dp",
        objective: str = "time_centric",
        family: Optional[Sequence[NodeSet]] = None,
        prepared: bool = False,
    ) -> DPResult:
        """Algorithm 1 through the cache; bit-identical to an uncached solve.

        A sweep already cached for ``(graph, family, objective)`` — by a
        prior ``solve_grid``/``frontier`` call here or in another process
        sharing the store — answers any budget it covers as a frontier
        lookup; otherwise this is the per-budget DP memoized under the
        ``plan`` entry kind, exactly as before.
        """
        gp = g if prepared else self.prepare(g)
        cfg = self.strategies
        if family is not None:
            return solve(gp, budget, list(family), objective, strategies=cfg)
        if method not in self.CACHEABLE_METHODS:
            return solve(gp, budget, self._family_for(gp, method), objective,
                         strategies=cfg)
        if cfg is not None:
            return self._solve_strategies(gp, budget, method, objective)
        if objective == "wallclock":
            return self._solve_wallclock(gp, budget, method)
        sw = self._cached_sweep(gp, method, objective)
        if sw is not None and sw.covers(budget):
            res = self._extract(sw, gp, budget)
            if res is not None:
                return res
        cacheable = self.cache is not None
        key = None
        if cacheable:
            key = PlanCache.key_for(gp, budget, method, objective)
            hit = self.cache.get(gp, key)
            if hit is not None:
                return hit
        res = solve(gp, budget, self._family_for(gp, method), objective)
        if cacheable:
            self.cache.put(gp, key, res)
        return res

    def _solve_strategies(
        self, gp: Graph, budget: float, method: str, objective: str
    ) -> DPResult:
        """Per-budget joint memory-strategy solve through the plan cache.

        Strategy planning has no budget-free sweep tier (strategy surfaces
        are in-memory only, see ``dp.StrategySweep``); per-budget results
        are memoized under :class:`~repro.core.plan_cache.PlanKey`\\ s that
        carry the config's ``digest_token()`` — disjoint by construction
        from every legacy digest.  ``wallclock`` results are not cached:
        their ranking depends on replay parameters the key does not carry.
        """
        cfg = self.strategies
        assert cfg is not None
        cacheable = self.cache is not None and objective != "wallclock"
        key = None
        if cacheable:
            key = PlanCache.key_for(
                gp, budget, method, objective, strategy=self._strategy_token
            )
            hit = self.cache.get(gp, key)
            if hit is not None:
                return hit
        res = solve(gp, budget, self._family_for(gp, method), objective,
                    strategies=cfg)
        if cacheable:
            self.cache.put(gp, key, res)
        return res

    def min_feasible_budget(
        self,
        g: Graph,
        method: str = "approx_dp",
        tol: float = 1e-3,
        family: Optional[Sequence[NodeSet]] = None,
        prepared: bool = False,
    ) -> float:
        """Exact minimal feasible budget (the §5.1 binary search, retired).

        One O(#𝓛²) scalar pass (``dp.min_feasible_budget_exact``) computes
        min over strategies of max_i 𝓜⁽ⁱ⁾ directly — faster than a single
        binary-search probe, and the result is itself exactly feasible.
        ``tol`` is kept for API compatibility and ignored.  An already
        cached sweep (whose terminal frontier carries the same value)
        answers first; feasibility does not depend on the objective.
        """
        del tol  # the scalar DP is exact — nothing to tolerate
        gp = g if prepared else self.prepare(g)
        cfg = self.strategies
        if family is not None:
            return dp_mod.min_feasible_budget_exact(
                gp, list(family), strategies=cfg
            )
        if cfg is None and method in self.CACHEABLE_METHODS:
            # legacy sweep surfaces price full-byte caches only — a strategy
            # planner's minimum is (weakly) lower, so it never reads them
            for objective in ("time_centric", "memory_centric"):
                sw = self._cached_sweep(gp, method, objective)
                if sw is not None:
                    b = sw.min_feasible_budget()
                    if b < dp_mod.INF:  # capped sweeps may not know
                        return b
        aux_key = None
        if self.cache is not None:
            # MEMORY_FUNCTIONAL in the key: min budgets computed under an
            # older functional (eq. 2) must invalidate by construction.
            # The strategy token (empty for the binary planner) keeps joint
            # minimums from ever aliasing legacy ones.
            aux_key = (f"{graph_digest(gp)}|{method}|"
                       f"{dp_mod.MEMORY_FUNCTIONAL}|exact")
            if self._strategy_token:
                aux_key += f"|{self._strategy_token}"
            v = self.cache.get_aux("min_budget", aux_key)
            if v is not None:
                return v
        b = dp_mod.min_feasible_budget_exact(
            gp, self._family_for(gp, method), strategies=cfg
        )
        if self.cache is not None:
            self.cache.put_aux("min_budget", aux_key, b)
        return b

    # ----------------------------------------------------------------- plan

    def plan(
        self,
        g: Graph,
        budget: Optional[float] = None,
        method: str = "approx_dp",
        objective: str = "time_centric",
    ) -> PlanReport:
        """Solve and lower to an ExecutionPlan (cached for the DP methods).

        budget=None reproduces the paper's protocol: minimal feasible B.
        method ∈ {"exact_dp", "approx_dp", "chen", "vanilla"}.
        """
        t0 = _time.perf_counter()
        gp = self.prepare(g)
        full = frozenset(range(gp.n))

        if method == "vanilla":
            res = DPResult(
                sequence=[full],
                overhead=0.0,
                peak_memory=dp_mod.peak_memory_live(gp, [full]),
                feasible=True,
            )
        elif method == "chen":
            res = chen_sqrt_n(gp, budget=None)
        else:
            if budget is None:
                budget = self.min_feasible_budget(gp, method, prepared=True)
            res = self.solve(gp, budget, method, objective, prepared=True)
        dt = _time.perf_counter() - t0

        if not res.feasible:
            return PlanReport(
                method=method,
                objective=objective if method.endswith("dp") else "-",
                budget=budget if budget is not None else float("nan"),
                result=res,
                plan=None,
                peak_with_liveness=float("inf"),
                peak_without_liveness=float("inf"),
                plan_seconds=dt,
            )

        ep = make_plan(gp, res.sequence, assignment=res.assignment,
                       strategies=self.strategies)
        sim_live = simulate(gp, res.sequence, liveness=True,
                            assignment=res.assignment)
        sim_nolive = simulate(gp, res.sequence, liveness=False,
                              assignment=res.assignment)
        replayed = None
        if objective == "wallclock" and method.endswith("dp"):
            from .replay import replay as _replay

            replayed = _replay(gp, ep, budget=budget,
                               strategies=self.strategies).seconds
        return PlanReport(
            method=method,
            objective=objective if method.endswith("dp") else "-",
            budget=budget if budget is not None else res.peak_memory,
            result=res,
            plan=ep,
            peak_with_liveness=sim_live.peak_memory,
            peak_without_liveness=sim_nolive.peak_memory,
            plan_seconds=dt,
            replayed_seconds=replayed,
        )


_DEFAULT_PLANNER = Planner()


def get_default_planner() -> Planner:
    """The process-wide Planner behind the module-level functions."""
    return _DEFAULT_PLANNER


def min_feasible_budget(
    g: Graph,
    method: str = "approx_dp",
    tol: float = 1e-3,
    family: Optional[Sequence[NodeSet]] = None,
) -> float:
    """§5.1 minimal feasible budget — exact, from the default Planner's
    cached sweep (the paper's binary search is retired; ``tol`` is accepted
    for compatibility and ignored)."""
    return _DEFAULT_PLANNER.min_feasible_budget(g, method, tol, family)


def plan(
    g: Graph,
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
    planner: Optional[Planner] = None,
) -> PlanReport:
    """Solve and lower to an ExecutionPlan (one-call front door).

    Routes through the process-default ``Planner`` — repeated calls on the
    same (graph, budget) hit the plan cache instead of re-running the DP.
    """
    return (planner or _DEFAULT_PLANNER).plan(g, budget, method, objective)


def compare_methods(
    g: Graph, budget: Optional[float] = None, include_exact: bool = True
) -> List[PlanReport]:
    """The paper's Table-1 row for one network: all methods, one graph."""
    reports = [plan(g, method="vanilla")]
    reports.append(plan(g, method="chen"))
    for objective in ("memory_centric", "time_centric"):
        reports.append(plan(g, budget, "approx_dp", objective))
        if include_exact:
            reports.append(plan(g, budget, "exact_dp", objective))
    return reports
