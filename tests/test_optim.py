"""Optimizer + gradient-compression units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.compression import (
    Compressed,
    compress,
    decompress,
    init_error_feedback,
    quantize_roundtrip_with_feedback,
)


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, clip_norm=None, min_lr_frac=1.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-2


def test_clipping_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                            warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, metrics = adamw.update(cfg, g, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(1e6)


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[10]                       # warmup
    assert lrs[10] == pytest.approx(1.0, abs=0.02)
    assert lrs[100] == pytest.approx(0.1, abs=0.02)  # cosine floor


def test_no_decay_on_1d_params():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1,
                            total_steps=10, clip_norm=None)
    params = {"scale": jnp.ones(4), "w": jnp.ones((4, 4))}
    state = adamw.init(params)
    g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_params, _, _ = adamw.update(cfg, g, state, params)
    np.testing.assert_array_equal(np.asarray(new_params["scale"]), np.ones(4))
    assert float(jnp.max(new_params["w"])) < 1.0  # decayed


def test_compress_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    y = decompress(compress(x))
    # int8 block quantization: error ≤ scale/2 per element
    err = jnp.abs(x - y)
    scale = jnp.max(jnp.abs(x)) / 127
    assert float(jnp.max(err)) <= float(scale) + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Σ compressed = Σ raw + residual — error feedback never loses mass."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (513,))}
    e = init_error_feedback(g)
    total_raw = jnp.zeros(513)
    total_sent = jnp.zeros(513)
    for step in range(20):
        gi = {"w": g["w"] * (0.9**step)}
        sent, e = quantize_roundtrip_with_feedback(gi, e)
        total_raw += gi["w"]
        total_sent += sent["w"]
    np.testing.assert_allclose(
        np.asarray(total_sent + e["w"]), np.asarray(total_raw), rtol=1e-4, atol=1e-4
    )


def test_compress_preserves_shape_and_zero():
    x = jnp.zeros((7, 13))
    y = decompress(compress(x))
    assert y.shape == (7, 13)
    np.testing.assert_array_equal(np.asarray(y), 0.0)
