"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.  The CLIP frontend
is a STUB: input_specs() supplies 576 precomputed patch embeddings.
"""

from .base import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        frontend="vision",
        frontend_seq=576,
    )
