"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Training runs the *chunked* formulation — quadratic only within a chunk,
recurrent across chunks — O(S·Q) memory, sub-quadratic compute, and a single
O(1) state for decode.  This is what makes the ``long_500k`` shape feasible
for the ssm/hybrid architectures (DESIGN.md §Arch-applicability).

Simplifications vs. the reference CUDA implementations (documented per
DESIGN.md hardware-adaptation): depthwise conv applies to the x-branch only
(Mamba2), and mLSTM uses sigmoid input/forget gates instead of the
stabilized-exponential pair — shapes, costs and state layout are faithful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.parallel.sharding import shard
from .layers import _init_normal, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Chunked SSD core (shared by Mamba2): h_t = a_t·h_{t-1} + b_t ⊗ x_t
# ---------------------------------------------------------------------------


def _segsum(a_log: jax.Array) -> jax.Array:
    """(…, Q) → (…, Q, Q) lower-triangular decay: out[i,j] = Σ_{k=j+1..i} a."""
    Q = a_log.shape[-1]
    cs = jnp.cumsum(a_log, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (…, i, j) = Σ_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)   dt-scaled inputs
    a_log: jax.Array,  # (B, S, H)  log decay per step (≤ 0)
    Bm: jax.Array,  # (B, S, H, N)
    Cm: jax.Array,  # (B, S, H, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xr = x.reshape(B, nc, Q, H, P)
    ar = a_log.reshape(B, nc, Q, H).astype(jnp.float32)
    Br = Bm.reshape(B, nc, Q, H, N)
    Cr = Cm.reshape(B, nc, Q, H, N)

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def per_chunk(h, inputs):
        xq, aq, Bq, Cq = inputs  # (B,Q,H,P), (B,Q,H), (B,Q,H,N), (B,Q,H,N)
        a_cum = jnp.cumsum(aq, axis=1)  # (B,Q,H)
        # intra-chunk (flash-style blockwise "attention" with decay)
        L = jnp.exp(_segsum(aq.transpose(0, 2, 1)))  # (B,H,Q,Q)
        G = jnp.einsum("bqhn,bshn->bhqs", Cq, Bq).astype(jnp.float32)
        Y_diag = jnp.einsum("bhqs,bhqs,bshp->bqhp", G, L, xr_f(xq))
        # contribution of the carried state
        state_decay = jnp.exp(a_cum)  # (B,Q,H)
        Y_off = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp", Cq.astype(jnp.float32), h, state_decay
        )
        # new carried state
        decay_to_end = jnp.exp(a_cum[:, -1:, :] - a_cum)  # (B,Q,H)
        new_h = h * jnp.exp(a_cum[:, -1, :])[:, :, None, None].transpose(
            0, 1, 2, 3
        ) + jnp.einsum(
            "bqhn,bqh,bqhp->bhpn", Bq.astype(jnp.float32), decay_to_end, xr_f(xq)
        )
        return new_h, (Y_diag + Y_off).astype(x.dtype)

    def xr_f(v):
        return v.astype(jnp.float32)

    xs = xr.transpose(1, 0, 2, 3, 4)
    as_ = ar.transpose(1, 0, 2, 3)
    Bs = Br.transpose(1, 0, 2, 3, 4)
    Cs = Cr.transpose(1, 0, 2, 3, 4)
    # checkpoint per chunk: the (B,H,Q,Q) decay/score blocks are recomputed in
    # the backward instead of being saved for all S/Q chunks — the paper's
    # recompute-don't-cache trade at the chunk level (cf. kernels/flash_attention)
    hT, ys = jax.lax.scan(jax.checkpoint(per_chunk), h0, (xs, as_, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, hT


def ssd_step(
    x: jax.Array,  # (B, H, P)
    a_log: jax.Array,  # (B, H)
    Bm: jax.Array,  # (B, H, N)
    Cm: jax.Array,  # (B, H, N)
    h: jax.Array,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the SSD recurrence."""
    a = jnp.exp(a_log.astype(jnp.float32))[..., None, None]
    h = h * a + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm.astype(jnp.float32))
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_sizes(d_model: int, cfg: SSMConfig, head_p: int = 64):
    d_inner = cfg.expand * d_model
    H = max(1, d_inner // head_p)
    P = d_inner // H
    return d_inner, H, P


def mamba2_init(rng, d_model: int, cfg: SSMConfig):
    d_inner, H, P = mamba2_sizes(d_model, cfg)
    N = cfg.d_state
    r = jax.random.split(rng, 5)
    proj_out = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "norm": rmsnorm_init(d_model),
        "in_proj": {"w": _init_normal(r[0], (d_model, proj_out), d_model**-0.5)},
        "conv_w": _init_normal(r[1], (cfg.d_conv, d_inner), 0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),  # A = -exp(A_log) < 0
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_out": rmsnorm_init(d_inner),
        "out_proj": {"w": _init_normal(r[2], (d_inner, d_model), d_inner**-0.5)},
    }


def _split_proj(zxbcdt, d_inner, N, H):
    z = zxbcdt[..., :d_inner]
    xs = zxbcdt[..., d_inner : 2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner : 2 * d_inner + N]
    Cm = zxbcdt[..., 2 * d_inner + N : 2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N :]
    return z, xs, Bm, Cm, dt


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, x (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out


def mamba2_apply(
    p, x: jax.Array, cfg: SSMConfig, state: Optional[Dict] = None
):
    """Full-sequence forward.  x (B,S,D) → (B,S,D)."""
    B, S, D = x.shape
    dt_ = x.dtype
    d_inner, H, P = mamba2_sizes(D, cfg)
    N = cfg.d_state
    h = rmsnorm(p["norm"], x)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"]["w"].astype(dt_))
    z, xs, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, N, H)
    xs = _causal_conv(xs, p["conv_w"].astype(dt_))
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(dt_)
    xs = shard(xs, "batch", None, "ffn")

    dt_soft = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    a_log = dt_soft * A  # (B,S,H) ≤ 0

    xh = xs.reshape(B, S, H, P) * dt_soft[..., None].astype(dt_)
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))

    y, _hT = ssd_chunked(xh, a_log, Bh, Ch, cfg.chunk)
    y = y + xs.reshape(B, S, H, P) * p["D_skip"][None, None, :, None].astype(dt_)
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    y = rmsnorm(p["norm_out"], y)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"]["w"].astype(dt_))
    return x + shard(out, "batch", None, "model")


def mamba2_init_state(batch: int, d_model: int, cfg: SSMConfig, dtype):
    d_inner, H, P = mamba2_sizes(d_model, cfg)
    return {
        "ssm": jnp.zeros((batch, H, P, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
    }


def mamba2_step(p, x: jax.Array, state: Dict, cfg: SSMConfig):
    """One decode step.  x (B,1,D) → (B,1,D), new state."""
    B, _, D = x.shape
    dt_ = x.dtype
    d_inner, H, P = mamba2_sizes(D, cfg)
    N = cfg.d_state
    h = rmsnorm(p["norm"], x)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"]["w"].astype(dt_))
    z, xs, Bm, Cm, dt = _split_proj(zxbcdt[:, 0], d_inner, N, H)

    conv_buf = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)
    w = p["conv_w"].astype(dt_)
    xs = jnp.einsum("bkc,kc->bc", conv_buf, w)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(dt_)
    new_conv = conv_buf[:, 1:, :]

    dt_soft = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a_log = dt_soft * A
    xh = xs.reshape(B, H, P) * dt_soft[..., None].astype(dt_)
    Bh = jnp.broadcast_to(Bm[:, None, :], (B, H, N))
    Ch = jnp.broadcast_to(Cm[:, None, :], (B, H, N))
    y, new_ssm = ssd_step(xh, a_log, Bh, Ch, state["ssm"])
    y = y + xs.reshape(B, H, P) * p["D_skip"][None, :, None].astype(dt_)
    y = y.reshape(B, d_inner) * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    y = rmsnorm(p["norm_out"], y)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"]["w"].astype(dt_))
    return x + out[:, None, :], {"ssm": new_ssm, "conv": new_conv}


# ---------------------------------------------------------------------------
# xLSTM — mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ---------------------------------------------------------------------------


def mlstm_init(rng, d_model: int, n_heads: int):
    r = jax.random.split(rng, 6)
    s = d_model**-0.5
    return {
        "norm": rmsnorm_init(d_model),
        "wq": _init_normal(r[0], (d_model, d_model), s),
        "wk": _init_normal(r[1], (d_model, d_model), s),
        "wv": _init_normal(r[2], (d_model, d_model), s),
        "w_gates": _init_normal(r[3], (d_model, 2 * n_heads), s),
        "wo": _init_normal(r[4], (d_model, d_model), s),
        "out_norm": rmsnorm_init(d_model),
    }


def mlstm_apply(p, x: jax.Array, n_heads: int, chunk: int):
    """mLSTM layer: C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ ;  y_t = C_t q_t / nrm.

    Expressed through the same chunked recurrence as SSD with N = P = d_head;
    the normalizer runs as a parallel recurrence with P = 1.
    """
    B, S, D = x.shape
    dt_ = x.dtype
    H = n_heads
    Dh = D // H
    h = rmsnorm(p["norm"], x)
    q = jnp.einsum("bsd,de->bse", h, p["wq"].astype(dt_)).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", h, p["wk"].astype(dt_)).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", h, p["wv"].astype(dt_)).reshape(B, S, H, Dh)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_gates"].astype(dt_)).astype(
        jnp.float32
    )
    i_gate = jax.nn.sigmoid(gates[..., :H])  # (B,S,H)
    f_gate = jax.nn.sigmoid(gates[..., H:] + 2.0)
    a_log = jnp.log(f_gate + 1e-9)

    k = k * (Dh**-0.5)
    # value recurrence: state (B,H,Dh_v,Dh_k)
    y, _ = ssd_chunked(v * i_gate[..., None].astype(dt_), a_log, k, q, chunk)
    # normalizer recurrence: P = 1
    ones = i_gate[..., None].astype(dt_)
    nrm, _ = ssd_chunked(ones, a_log, k, q, chunk)  # (B,S,H,1)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = rmsnorm(p["out_norm"], y.reshape(B, S, D))
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt_))
    return x + shard(out, "batch", None, "model")


def mlstm_init_state(batch: int, d_model: int, n_heads: int):
    Dh = d_model // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, Dh, Dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, 1, Dh), jnp.float32),
    }


def mlstm_step(p, x: jax.Array, state: Dict, n_heads: int):
    B, _, D = x.shape
    dt_ = x.dtype
    H, Dh = n_heads, D // n_heads
    h = rmsnorm(p["norm"], x)[:, 0]
    q = jnp.einsum("bd,de->be", h, p["wq"].astype(dt_)).reshape(B, H, Dh)
    k = jnp.einsum("bd,de->be", h, p["wk"].astype(dt_)).reshape(B, H, Dh)
    v = jnp.einsum("bd,de->be", h, p["wv"].astype(dt_)).reshape(B, H, Dh)
    gates = jnp.einsum("bd,dg->bg", h, p["w_gates"].astype(dt_)).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gates[..., :H])
    f_gate = jax.nn.sigmoid(gates[..., H:] + 2.0)
    a_log = jnp.log(f_gate + 1e-9)
    k = k * (Dh**-0.5)
    y, C = ssd_step(v * i_gate[..., None].astype(dt_), a_log, k, q, state["C"])
    nrm, n = ssd_step(i_gate[..., None].astype(dt_), a_log, k, q, state["n"])
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = rmsnorm(p["out_norm"], y.reshape(B, D))
    out = jnp.einsum("be,ed->bd", y, p["wo"].astype(dt_))
    return x + out[:, None, :], {"C": C, "n": n}


def slstm_init(rng, d_model: int):
    r = jax.random.split(rng, 2)
    return {
        "norm": rmsnorm_init(d_model),
        "w_zifo": _init_normal(r[0], (d_model, 4 * d_model), d_model**-0.5),
        "wo": _init_normal(r[1], (d_model, d_model), d_model**-0.5),
    }


def slstm_apply(p, x: jax.Array):
    """sLSTM: elementwise gated recurrence via associative scan (O(S log S))."""
    B, S, D = x.shape
    dt_ = x.dtype
    h = rmsnorm(p["norm"], x)
    zifo = jnp.einsum("bsd,dg->bsg", h, p["w_zifo"].astype(dt_)).astype(jnp.float32)
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f + 1.0)
    o = jax.nn.sigmoid(o)

    def combine(a, b):
        # states compose: c = f·c_prev + u   →  (f2, u2)∘(f1, u1) = (f1f2, u1f2+u2)
        return (a[0] * b[0], a[1] * b[0] + b[1])

    fc, uc = jax.lax.associative_scan(combine, (f, i * z), axis=1)
    fn, un = jax.lax.associative_scan(combine, (f, i), axis=1)
    c = uc  # zero initial state
    n = jnp.maximum(un, 1e-6)
    y = (o * c / n).astype(dt_)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(dt_))
    return x + shard(out, "batch", None, "model")


def slstm_init_state(batch: int, d_model: int):
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
    }


def slstm_step(p, x: jax.Array, state: Dict):
    B, _, D = x.shape
    dt_ = x.dtype
    h = rmsnorm(p["norm"], x)[:, 0]
    zifo = jnp.einsum("bd,dg->bg", h, p["w_zifo"].astype(dt_)).astype(jnp.float32)
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f + 1.0)
    o = jax.nn.sigmoid(o)
    c = f * state["c"] + i * z
    n = f * state["n"] + i
    y = (o * c / jnp.maximum(n, 1e-6)).astype(dt_)
    out = jnp.einsum("bd,de->be", y, p["wo"].astype(dt_))
    return x + out[:, None, :], {"c": c, "n": n}
