"""Compiler-truth HLO analysis: remat conformance, memory drift, compiled cost.

``analysis.check_plan`` and ``analysis.check_lowering`` verify a plan against
the *trace* — but what runs is XLA's optimized HLO, where fusion, CSE, DCE
and buffer assignment can silently break the save-set or blow the budget.
This module closes that loop with three cooperating checkers over the
compiled planned twin, all speaking the shared :class:`~.report.Report` type:

1. **remat conformance** — trace the twin's differentiated jaxpr, census its
   heavy ops (dot/conv, trip-count aware through ``scan`` bodies) into
   *forward*, *inside-remat* and *named-recompute* counts, and prove the
   optimized HLO's heavy-op multiplicity lands in the band the plan's eq. (1)
   recompute set implies.  The band is one-sided by construction: backends
   that expand ``optimization_barrier`` before CSE (XLA **CPU** does; GPU/TPU
   expand it last) may merge a planned recomputation back into its forward
   twin — that elision only ever *removes* planned-recompute ops, so

       expected − named_recompute  ≤  measured  ≤  expected

   with ``expected = forward + inside_remat``.  Anything above the band is
   unplanned recomputation (an eq. (1) breach); anything below lost forward
   or backward work.  Every cached ``checkpoint_name`` tensor must also
   survive as a materialized buffer: jax's ``save_only_these_names`` policy
   marks each saved residual with an identity ``reduce_precision`` (e8m23
   for f32), so the StableHLO must carry exactly one marker per
   backward-live saved residual and the optimized HLO at least that many
   (fusion may duplicate a marker, never drop one).
2. **memory drift** — ``compiled.memory_analysis()`` temp bytes against the
   plan's liveness-tight analytic peak, with a tolerance band
   ``peak·(1+rel) + abs_slack``.  On CPU the barrier expansion above means a
   planned twin can legitimately compile to vanilla-peak temp; when a
   vanilla compile is supplied as ceiling, drift inside the vanilla band
   downgrades to the documented ``memory-drift-remat-elided`` warning.
3. **compiled cost extraction** — per-segment sub-jaxprs compiled in
   isolation yield XLA's own FLOPs / bytes-accessed, which
   ``core.cost_model.compiled_calibrated_graph`` turns into a ``"compiled"``
   cost profile for the DP (profile source hashed into the plan-cache
   digest via ``Graph.cost_source``).

Entry points: :func:`check_hlo` / :func:`analyze_hlo` for a
``TracedCarrier`` + plan (the ``plan_function(verify_hlo=True)`` and
``REPRO_VERIFY_PLANS=hlo`` hook), :func:`analyze_twin` for an explicitly
lowered twin (the ``plan_lint --hlo`` benchmark-network path), and
:func:`extract_segment_costs` for the cost profile.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
from jax.extend import core as jcore

from ..core.prims import HIGHER_ORDER_PRIMS, INNER_JAXPR_KEYS, MATMUL_PRIMS
from ..core.schedule import ExecutionPlan
from .conformance import _remat_eqns, _tag_names
from .hlo_text import count_heavy_ops, reduce_precision_count
from .report import Report

#: Node kinds the plan-side recompute census treats as heavy (one dot/conv
#: instruction each): traced-jaxpr kinds are primitive names, the abstract
#: benchmark graphs use "conv", chain/BlockGraph twins "matmul".
HEAVY_NODE_KINDS = frozenset(MATMUL_PRIMS) | frozenset({"conv", "matmul"})

#: Default drift tolerance: relative band around the analytic peak plus an
#: absolute slack for buffer padding/alignment and the compiler's scratch.
DRIFT_REL = 0.5
DRIFT_ABS_SLACK = 256 * 1024


@dataclasses.dataclass(frozen=True)
class HeavyCensus:
    """Heavy-op (dot/conv) counts of a differentiated twin jaxpr.

    ``forward``: outside any differentiated remat body (the forward pass);
    ``remat``: inside differentiated remat bodies (planned recompute plus the
    backward's transposed heavy ops); ``remat_named``: the subset of remat
    heavy ops whose output feeds a ``name`` tag — exactly the plan's
    rematerialized nodes, the only ops a CSE-after-barrier backend may elide.
    All counts are trip-aware (a heavy op in a ``scan`` body counts
    ``length`` times).
    """

    forward: int
    remat: int
    remat_named: int

    @property
    def expected(self) -> int:
        """Heavy ops a faithful compilation of the twin executes."""
        return self.forward + self.remat


def _named_heavy(body: Any) -> int:
    """Heavy eqns in ``body`` whose output a ``name`` tag consumes."""
    producer: Dict[Any, Any] = {}
    for e in body.eqns:
        for ov in e.outvars:
            producer[ov] = e
    n = 0
    for e in body.eqns:
        if e.primitive.name == "name":
            src = producer.get(e.invars[0])
            if src is not None and src.primitive.name in MATMUL_PRIMS:
                n += 1
    return n


def heavy_census(closed: Any) -> HeavyCensus:
    """Trip-aware heavy-op census of a traced value_and_grad twin."""
    fwd = rem = named = 0

    def walk(jaxpr: Any, mult: int, in_remat: bool) -> None:
        nonlocal fwd, rem, named
        for e in jaxpr.eqns:
            nm = e.primitive.name
            if nm in MATMUL_PRIMS:
                if in_remat:
                    rem += mult
                else:
                    fwd += mult
                continue
            if nm not in HIGHER_ORDER_PRIMS:
                continue
            differentiated = bool(
                nm in ("remat2", "remat") and e.params.get("differentiated")
            )
            m2 = mult
            if nm == "scan":
                m2 = mult * max(1, int(e.params.get("length", 1)))
            for key in INNER_JAXPR_KEYS:
                sub = e.params.get(key)
                if sub is None:
                    continue
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                for s in subs:
                    inner = s.jaxpr if hasattr(s, "jaxpr") else s
                    if not hasattr(inner, "eqns"):
                        continue
                    if differentiated:
                        named += _named_heavy(inner) * mult
                    walk(inner, m2, in_remat or differentiated)

    walk(closed.jaxpr, 1, False)
    return HeavyCensus(forward=fwd, remat=rem, remat_named=named)


def saved_residual_count(closed: Any) -> int:
    """Saved residuals of a differentiated twin jaxpr.

    The checkpoint policy lowering marks every residual it saves with an
    identity ``reduce_precision`` whose output the differentiated ``remat``
    equation consumes — so this count is exactly
    |cached ∩ storable ∩ backward-live| and the number of identity
    reduce-precision markers the StableHLO must carry.
    """
    jaxpr = closed.jaxpr
    remat_ins: Set[Any] = set()
    for e in _remat_eqns(jaxpr):
        for iv in e.invars:
            if not isinstance(iv, jcore.Literal):
                remat_ins.add(iv)
    return sum(
        1
        for e in jaxpr.eqns
        if e.primitive.name == "reduce_precision"
        and any(ov in remat_ins for ov in e.outvars)
    )


def drift_findings(
    report: Report,
    *,
    analytic_peak: float,
    temp_bytes: float,
    rel: float = DRIFT_REL,
    abs_slack: float = DRIFT_ABS_SLACK,
    ceiling: Optional[float] = None,
) -> str:
    """Memory-drift gate: compare compiled temp bytes to the analytic peak.

    Returns the drift status (``"ok"`` / ``"remat-elided"`` / ``"drift"``)
    and appends the matching finding.  ``ceiling`` is the compiled *vanilla*
    twin's temp bytes: on backends that elide remat through early barrier
    expansion (XLA CPU), temp within the vanilla band is the documented
    backend behavior, not planner drift — a warning, never silence.
    """
    band = analytic_peak * (1.0 + rel) + abs_slack
    if temp_bytes <= band:
        report.add(
            "info",
            "memory-drift-ok",
            f"compiled temp {temp_bytes:.4g} B within the plan band "
            f"{band:.4g} B (analytic peak {analytic_peak:.4g} B, "
            f"rel={rel:g}, slack={abs_slack:.4g} B)",
        )
        return "ok"
    if ceiling is not None and temp_bytes <= ceiling * (1.0 + rel) + abs_slack:
        report.add(
            "warning",
            "memory-drift-remat-elided",
            f"compiled temp {temp_bytes:.4g} B exceeds the plan band "
            f"{band:.4g} B but stays within the vanilla ceiling "
            f"{ceiling:.4g} B — this backend expands optimization_barrier "
            "before CSE, so the planned recompute was merged back into the "
            "forward (documented XLA-CPU behavior; the plan's savings apply "
            "on barrier-last backends)",
        )
        return "remat-elided"
    report.add(
        "error",
        "memory-drift",
        f"compiled temp {temp_bytes:.4g} B exceeds the plan band "
        f"{band:.4g} B by {temp_bytes - band:.4g} B "
        f"(analytic peak {analytic_peak:.4g} B"
        + (f", vanilla ceiling {ceiling:.4g} B" if ceiling is not None else "")
        + ") — the compiled artifact does not respect the planned budget",
    )
    return "drift"


@dataclasses.dataclass
class HloAnalysis:
    """Report plus the machine-readable drift record (one JSON row)."""

    report: Report
    drift: Dict[str, Any]


def analyze_twin(
    fn_grad: Callable[..., Any],
    args: Sequence[Any],
    *,
    cached_tags: Set[str],
    recompute_tags: Set[str],
    plan_heavy_recompute: int,
    analytic_peak: float,
    vanilla_grad: Optional[Callable[..., Any]] = None,
    rel: float = DRIFT_REL,
    abs_slack: float = DRIFT_ABS_SLACK,
    donate_argnums: Optional[Tuple[int, ...]] = None,
) -> HloAnalysis:
    """Run all HLO checks on an explicitly lowered value_and_grad twin.

    ``fn_grad`` must be the planned twin (forward tagged with
    ``checkpoint_name``, lowered through ``jax.checkpoint`` with the plan's
    ``save_only_these_names`` policy); ``args`` may be concrete arrays or
    ``ShapeDtypeStruct``s.  ``cached_tags`` / ``recompute_tags`` are the
    plan's storable U_k and V \\ U_k tag names; ``plan_heavy_recompute`` the
    number of heavy (dot/conv) nodes in V \\ U_k; ``analytic_peak`` the
    plan's liveness-tight peak *in the twin's byte units*.  With
    ``vanilla_grad`` (the unplanned twin) the drift gate gains the vanilla
    ceiling and the record a reference compile.  ``donate_argnums``
    compiles the twin with donation hints (``lowering.donation``) — the
    gate then verifies the hinted lowering, whose values are unchanged but
    whose buffer assignment may alias donated inputs.
    """
    report = Report(checker="hlo")
    record: Dict[str, Any] = {"analytic_peak_bytes": float(analytic_peak)}

    # ---- trace the twin's own differentiated jaxpr -------------------------
    try:
        closed = jax.make_jaxpr(fn_grad)(*args)
    except Exception as e:
        report.add(
            "error",
            "lowering-untraceable",
            f"could not trace the planned twin: {type(e).__name__}: {e}",
        )
        return HloAnalysis(report, record)

    all_tags: Set[str] = set()
    _tag_names(closed.jaxpr, all_tags)
    remats = list(_remat_eqns(closed.jaxpr))
    if not remats:
        report.add(
            "error",
            "no-remat",
            "the differentiated trace contains no remat equation — the plan "
            "was not lowered through jax.checkpoint at all",
        )
        return HloAnalysis(report, record)
    recomputed: Set[str] = set()
    for eqn in remats:
        inner = eqn.params.get("jaxpr")
        body = getattr(inner, "jaxpr", inner)
        if body is not None and hasattr(body, "eqns"):
            _tag_names(body, recomputed)

    missing = sorted(cached_tags - all_tags)
    if missing:
        report.add(
            "error",
            "cached-tag-missing",
            f"plan caches {missing} but the twin's trace carries no "
            "checkpoint_name tag for them — the policy cannot save what was "
            "never tagged, so these residuals will be silently recomputed",
        )
    extras = sorted(recomputed - recompute_tags)
    if extras:
        report.add(
            "error",
            "recompute-exceeds-eq1",
            f"the twin rematerializes {extras} beyond the plan's V \\ U_k — "
            "eq. (1) overhead accounting no longer matches the lowering",
        )

    census = heavy_census(closed)
    record.update(
        heavy_forward=census.forward,
        heavy_remat=census.remat,
        heavy_recompute_planned=census.remat_named,
    )
    if census.remat_named > plan_heavy_recompute:
        report.add(
            "error",
            "recompute-exceeds-eq1",
            f"the twin rematerializes {census.remat_named} heavy ops but the "
            f"plan's recompute set V \\ U_k holds only "
            f"{plan_heavy_recompute} heavy nodes — the compiled overhead "
            "exceeds the plan's eq. (1) claim",
        )

    # ---- compile ------------------------------------------------------------
    try:
        jit_kw: Dict[str, Any] = {}
        if donate_argnums:
            jit_kw["donate_argnums"] = donate_argnums
        lowered = jax.jit(fn_grad, **jit_kw).lower(*args)
        stable_text = lowered.as_text()
        compiled = lowered.compile()
        hlo_text = compiled.as_text()
    except Exception as e:
        report.add(
            "error",
            "compile-failed",
            f"could not compile the planned twin: {type(e).__name__}: {e}",
        )
        return HloAnalysis(report, record)

    # ---- materialization: every saved residual is a real buffer ------------
    saved_used = saved_residual_count(closed)
    record["saved_residuals"] = saved_used
    if saved_used == 0 and cached_tags:
        report.add(
            "warning",
            "materialization-untrackable",
            "the trace carries no reduce_precision save markers despite a "
            "non-empty cache set — either every cached residual is dead for "
            "the backward, or this jax version lowers the policy "
            "differently; buffer materialization cannot be checked",
        )
    else:
        rp_stable = reduce_precision_count(stable_text)
        rp_opt = reduce_precision_count(hlo_text)
        record.update(rp_stablehlo=rp_stable, rp_optimized=rp_opt)
        if rp_stable != saved_used:
            report.add(
                "error",
                "cached-tensor-not-materialized",
                f"the StableHLO carries {rp_stable} identity "
                f"reduce_precision save markers but the jaxpr saves "
                f"{saved_used} residuals into the backward — a cached "
                "tensor was dropped between trace and lowering",
            )
        elif rp_opt < saved_used:
            report.add(
                "error",
                "cached-tensor-not-materialized",
                f"only {rp_opt} of the plan's {saved_used} saved residuals "
                "survive in the optimized HLO as materialized buffers — "
                "fusion/DCE ate a cached tensor",
            )

    # ---- heavy-op multiplicity vs eq. (1) ----------------------------------
    measured = count_heavy_ops(hlo_text)
    expected = census.expected
    low = expected - census.remat_named
    record.update(heavy_measured=measured, heavy_expected=expected)
    if measured > expected:
        report.add(
            "error",
            "hlo-heavy-multiplicity-mismatch",
            f"optimized HLO executes {measured} heavy ops but the twin's "
            f"jaxpr implies at most {expected} "
            f"({census.forward} forward + {census.remat} in-remat) — XLA "
            "introduced recomputation the plan never priced",
        )
    elif measured < low:
        report.add(
            "error",
            "hlo-heavy-multiplicity-mismatch",
            f"optimized HLO executes {measured} heavy ops, below the "
            f"eq. (1) band [{low}, {expected}] — forward or backward heavy "
            "work vanished, the twin no longer computes the same function",
        )
    elif measured < expected:
        report.add(
            "info",
            "hlo-cse-elided-recompute",
            f"optimized HLO executes {measured} of {expected} heavy ops: "
            f"{expected - measured} planned recomputations were merged with "
            "their forward twins (barrier-early CSE; within the eq. (1) "
            f"band [{low}, {expected}])",
        )
    else:
        report.add(
            "info",
            "hlo-heavy-multiplicity-ok",
            f"optimized HLO heavy-op count {measured} equals forward + "
            "remat exactly — eq. (1) recompute counts hold in the compiled "
            "artifact",
        )

    # ---- memory drift -------------------------------------------------------
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", None) if mem is not None else None
    if temp is None:
        report.add(
            "warning",
            "memory-analysis-unavailable",
            "compiled.memory_analysis() returned nothing on this backend — "
            "drift gate skipped",
        )
    else:
        ceiling: Optional[float] = None
        if vanilla_grad is not None:
            try:
                vcompiled = jax.jit(vanilla_grad).lower(*args).compile()
                vmem = vcompiled.memory_analysis()
                vtemp = getattr(vmem, "temp_size_in_bytes", None)
                if vtemp is not None:
                    ceiling = float(vtemp)
                    record["vanilla_temp_bytes"] = int(vtemp)
                    record["vanilla_heavy"] = count_heavy_ops(
                        vcompiled.as_text()
                    )
            except Exception:
                pass  # no ceiling → strict band only
        record["temp_bytes"] = int(temp)
        record["drift_rel"] = rel
        record["drift_abs_slack"] = abs_slack
        record["drift_status"] = drift_findings(
            report,
            analytic_peak=analytic_peak,
            temp_bytes=float(temp),
            rel=rel,
            abs_slack=abs_slack,
            ceiling=ceiling,
        )

    # ---- compiled cost (the "compiled" profile's raw numbers) ---------------
    cost = compiled.cost_analysis()
    if cost:
        c = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["compiled_flops"] = float(c.get("flops", -1.0))
        record["compiled_bytes_accessed"] = float(c.get("bytes accessed", -1.0))

    if report.ok and not any(f.severity == "warning" for f in report.findings):
        report.add(
            "info",
            "hlo-conformant",
            f"compiled twin conforms: {measured} heavy ops in band, "
            f"{saved_used} residuals materialized, temp within tolerance",
        )
    return HloAnalysis(report, record)


def analyze_hlo(
    carrier: Any,
    plan: ExecutionPlan,
    *,
    rel: float = DRIFT_REL,
    abs_slack: float = DRIFT_ABS_SLACK,
    use_vanilla_ceiling: bool = True,
    donate: bool = False,
) -> HloAnalysis:
    """HLO checks for a ``TracedCarrier`` + plan (the front-door hook).

    Lowers the plan through the ``"jaxpr"`` backend's
    ``traced_value_and_grad``, compiles it on the current backend (post-SPMD
    when the carrier holds a concrete mesh) and runs
    :func:`analyze_twin` with the plan's own tag sets and analytic peak.
    ``use_vanilla_ceiling=False`` makes the drift gate strict — no
    remat-elision allowance — which is what corruption regression tests
    want.  ``donate=True`` compiles with the donation hints the ``"jaxpr"``
    backend's ``donate=True`` lowering would attach
    (``lowering.donation.donatable_argnums``) — the drift gate then
    verifies the hinted twin.
    """
    from ..core.lowering.carriers import TracedCarrier
    from ..core.lowering.policy import traced_value_and_grad
    from .effects import _storable

    if not isinstance(carrier, TracedCarrier):
        report = Report(checker="hlo")
        report.add(
            "info",
            "not-applicable",
            f"HLO analysis needs a traced carrier "
            f"(got {type(carrier).__name__})",
        )
        return HloAnalysis(report, {})

    names = carrier.node_names()
    jg = carrier.jg
    recompute = set(range(len(names))) - set(plan.cached)
    cached_tags = {
        names[v] for v in plan.cached if _storable(jg.eqns[v])
    }
    recompute_tags = {
        names[v] for v in recompute if _storable(jg.eqns[v])
    }
    plan_heavy = sum(
        1 for v in recompute if jg.eqns[v].primitive.name in MATMUL_PRIMS
    )

    flat = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carrier.flat_avals]
    args = jax.tree_util.tree_unflatten(carrier.in_tree, flat)
    fn_grad = traced_value_and_grad(carrier, plan)
    dargs: Optional[Tuple[int, ...]] = None
    if donate:
        from ..core.lowering.donation import donatable_argnums

        dargs = donatable_argnums(carrier)
    vanilla = None
    if use_vanilla_ceiling:
        vanilla = jax.value_and_grad(carrier.fn, argnums=carrier.argnums)
    return analyze_twin(
        fn_grad,
        args,
        cached_tags=cached_tags,
        recompute_tags=recompute_tags,
        plan_heavy_recompute=plan_heavy,
        analytic_peak=plan.peak_memory,
        vanilla_grad=vanilla,
        rel=rel,
        abs_slack=abs_slack,
        donate_argnums=dargs,
    )


def check_hlo(
    carrier: Any,
    plan: ExecutionPlan,
    *,
    rel: float = DRIFT_REL,
    abs_slack: float = DRIFT_ABS_SLACK,
    use_vanilla_ceiling: bool = True,
    donate: bool = False,
) -> Report:
    """Report-only wrapper over :func:`analyze_hlo` (same contract)."""
    return analyze_hlo(
        carrier,
        plan,
        rel=rel,
        abs_slack=abs_slack,
        use_vanilla_ceiling=use_vanilla_ceiling,
        donate=donate,
    ).report


# ---------------------------------------------------------------------------
# Compiled cost extraction (checker 3's raw numbers).
# ---------------------------------------------------------------------------


def extract_segment_costs(
    carrier: Any, plan: ExecutionPlan
) -> List[Dict[str, float]]:
    """XLA ``cost_analysis()`` FLOPs / bytes-accessed per plan segment.

    Each segment's equations are evaluated as a standalone jit whose inputs
    are the values crossing into the segment; XLA compiles and prices it in
    isolation.  The result feeds
    ``core.cost_model.compiled_calibrated_graph``, which distributes each
    segment's roofline seconds over its nodes proportionally to their
    analytic FLOPs — compiler truth at segment granularity, analytic ratios
    within.
    """
    closed = carrier.closed
    jaxpr = closed.jaxpr
    const_map = dict(zip(jaxpr.constvars, closed.consts))
    out: List[Dict[str, float]] = []
    for seg in plan.segments:
        eqns = [jaxpr.eqns[v] for v in seg.nodes]
        produced = {ov for e in eqns for ov in e.outvars}
        ins: List[Any] = []
        seen: Set[Any] = set()
        for e in eqns:
            for iv in e.invars:
                if (
                    isinstance(iv, jcore.Literal)
                    or iv in produced
                    or iv in seen
                    or iv in const_map
                ):
                    continue
                seen.add(iv)
                ins.append(iv)

        def run(*vals: Any, _eqns: Any = eqns, _ins: Any = ins) -> Any:
            env: Dict[Any, Any] = dict(const_map)
            env.update(zip(_ins, vals))

            def read(v: Any) -> Any:
                return v.val if isinstance(v, jcore.Literal) else env[v]

            for e in _eqns:
                res = e.primitive.bind(
                    *[read(iv) for iv in e.invars], **e.params
                )
                outs = res if e.primitive.multiple_results else [res]
                for ov, val in zip(e.outvars, outs):
                    env[ov] = val
            return [
                env[ov]
                for e in _eqns
                for ov in e.outvars
                if type(ov).__name__ != "DropVar"
            ]

        avals = [jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype) for v in ins]
        compiled = jax.jit(run).lower(*avals).compile()
        cost = compiled.cost_analysis()
        c: Any = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
        out.append(
            {
                "flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0)),
            }
        )
    return out


__all__: Tuple[str, ...] = (
    "HEAVY_NODE_KINDS",
    "HeavyCensus",
    "HloAnalysis",
    "analyze_hlo",
    "analyze_twin",
    "check_hlo",
    "drift_findings",
    "extract_segment_costs",
    "heavy_census",
    "saved_residual_count",
)
