"""Batched serving engine: slot-based continuous batching over the decode
step of any repro model.

Design (vLLM-style, adapted to JAX's static shapes):

* a fixed pool of ``max_slots`` sequence slots, each with a position counter
  and a done flag — the jitted decode step always runs the full (B=slots)
  batch; empty slots decode garbage that is masked out on the host;
* admission: new requests claim free slots; their prompt is prefilled
  token-by-token through the same decode step (correct for every family —
  SSM/hybrid caches are recurrent states, not KV), amortized across steps;
* sampling: greedy or temperature, per-request;
* termination: eos token or per-request max_new_tokens.

Throughput-oriented serving on a real pod shards the slot batch over
("pod","data") and the heads/experts over "model" exactly as training does —
the decode_32k / long_500k dry-run cells compile this engine's step function.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 → greedy
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        model,
        params: Any,
        max_slots: int = 8,
        max_seq: int = 512,
        rng_seed: int = 0,
        frames: Optional[jax.Array] = None,
        plan_cache_dir: Optional[str] = None,
        plan_remote: Optional[str] = None,
        prewarm_shapes: Optional[List[Any]] = None,
        mesh: Optional[jax.sharding.Mesh] = None,
        step_shardings: Any = None,
    ):
        # Serving processes are usually co-located with (or restarted from)
        # training jobs; attaching the same on-disk plan cache means any
        # planning this process does (prefill remat segmentation via
        # launch.plan, or ad-hoc repro.plan_function calls) is a
        # content-addressed lookup, and plans solved here are visible to
        # the trainers — one pipeline, one store.  ``plan_remote`` attaches
        # the fleet-shared tier on top (a shared-FS path/URL for
        # core.plan_cache.remote_store_from_url): autoscaled replicas
        # read-through plans the first replica solved and pushed.
        if plan_cache_dir:
            from repro.core.plan_cache import set_default_cache_dir

            set_default_cache_dir(plan_cache_dir)
        if plan_remote:
            from repro.core.plan_cache import set_default_remote_store

            set_default_remote_store(plan_remote)
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self._rng = np.random.default_rng(rng_seed)
        cfg = model.cfg
        if cfg.encoder_decoder:
            if frames is None:
                raise ValueError("encoder-decoder serving needs `frames`")
            self.caches = model.init_caches(params, frames, max_seq)
        else:
            self.caches = model.init_caches(max_slots, max_seq)
        self.positions = np.zeros((max_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.pending: List[Request] = []
        self.next_uid = 0
        self.completed: List[Request] = []
        self.mesh = mesh

        # Sharded decode: with a mesh + ``step_shardings`` (a 4-tuple of
        # shardings for (params, tokens, caches, positions)) the jitted step
        # is pinned to the production layout — the same per-device budget
        # semantics the training side plans under.
        kw = {}
        if mesh is not None and step_shardings is not None:
            kw["in_shardings"] = step_shardings

        def _step(params, tokens, caches, positions):
            return model.decode_step(params, tokens, caches, positions)

        self._step = jax.jit(_step, **kw)

        # Boot-time sweep pre-warm: solve (or read-through) the budget-free
        # sweeps for the shapes this replica expects BEFORE taking traffic,
        # so the first planned step is a warm frontier lookup.
        if prewarm_shapes:
            self.prewarm_plans(prewarm_shapes)

    # ------------------------------------------------------------- planning

    def prewarm_plans(
        self,
        shapes: List[Any],
        dp_shards: int = 1,
        seq_shards: int = 1,
        model_shards: int = 1,
        **kw: Any,
    ) -> Dict[str, bool]:
        """Pre-warm the plan cache for the expected batch-shape signatures.

        ``shapes`` are ``repro.configs.ShapeConfig``s (e.g. ``decode_32k``,
        ``long_500k`` — the signatures the dry-run matrix compiles); shard
        counts default to this engine's single-host layout.  Delegates to
        ``launch.plan.prewarm_unit_plans`` on the process-default planner,
        so the warmed sweeps are exactly what ``plan_unit_segments`` will
        look up, and — with a fleet store attached (``plan_remote``) — one
        replica's cold solve is pushed for every other replica to
        read-through.  Returns ``{shape.name: already_warm}``.
        """
        from repro.launch.plan import prewarm_unit_plans

        return prewarm_unit_plans(
            self.model.cfg, shapes, dp_shards, seq_shards, model_shards, **kw
        )

    def plan_scoring(self, loss_fn, budget: float, in_shardings: Any = None,
                     objective: str = "wallclock", **kw):
        """A planned value_and_grad over ``(params, batch)`` sharing this
        engine's mesh and plan cache.

        Serving processes co-located with trainers use this for scoring /
        distillation / on-policy gradient steps under the serving node's
        *leftover* per-device memory: the returned twin is
        ``repro.plan_function(loss_fn, budget, mesh=self.mesh, ...)`` — one
        pipeline, one store, per-device budget semantics.

        Scoring steps steal cycles from decode, so the default objective is
        ``"wallclock"``: candidate plans at the budget are ranked by the
        replay simulator's step time (recompute hidden under backward slack
        is free), and the chosen plan's predicted step seconds are surfaced
        as ``report.replayed_seconds`` on each lowered twin — the number an
        admission controller budgets scoring traffic with.  Pass
        ``objective="time_centric"`` for the plain eq. (1) objective.
        """
        from repro.core.lowering import plan_function

        return plan_function(loss_fn, budget, mesh=self.mesh,
                             in_shardings=in_shardings, objective=objective,
                             **kw)

    # ------------------------------------------------------------ admission

    def submit(self, prompt: List[int], **kw) -> int:
        req = Request(uid=self.next_uid, prompt=list(prompt), **kw)
        self.next_uid += 1
        self.pending.append(req)
        return req.uid

    def _reset_slot(self, slot: int) -> None:
        """Zero slot state on reuse — KV is masked by position anyway, but
        recurrent (SSM/xLSTM) states would otherwise leak between requests.

        LM caches stack units on axis 0 and batch on axis 1.  Encoder-decoder
        engines keep the cross-attention KV (shared encoder context) intact
        and zero only the self-attention KV.
        """
        if self.model.cfg.encoder_decoder:
            self.caches["self"] = jax.tree_util.tree_map(
                lambda a: a.at[:, slot].set(0), self.caches["self"]
            )
            return
        self.caches = jax.tree_util.tree_map(
            lambda a: a.at[:, slot].set(0), self.caches
        )

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slot_req[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            self.slot_req[slot] = req
            self.positions[slot] = 0
            self._reset_slot(slot)
            # the prompt is fed through decode steps below

    # ----------------------------------------------------------------- step

    def _next_token_for(self, slot: int) -> int:
        """Next *input* token for this slot (prompt feed or last sampled)."""
        req = self.slot_req[slot]
        if req is None:
            return 0
        pos = self.positions[slot]
        if pos < len(req.prompt):
            return req.prompt[pos]
        return req.output[-1] if req.output else 0

    def step(self) -> int:
        """One engine step = one batched decode step.  Returns #active slots."""
        self._admit()
        active = [s for s in range(self.max_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        tokens = np.array(
            [[self._next_token_for(s)] for s in range(self.max_slots)], np.int32
        )
        logits, self.caches = self._step(
            self.params,
            jnp.asarray(tokens),
            self.caches,
            jnp.asarray(self.positions),
        )
        logits = np.asarray(logits[:, -1, :])  # (slots, V)

        for s in active:
            req = self.slot_req[s]
            pos = int(self.positions[s])
            self.positions[s] = pos + 1
            in_prompt = pos + 1 < len(req.prompt)
            if in_prompt:
                continue  # still prefilling the prompt
            if req.temperature > 0:
                z = logits[s] / req.temperature
                z = z - z.max()
                p = np.exp(z) / np.exp(z).sum()
                tok = int(self._rng.choice(len(p), p=p))
            else:
                tok = int(np.argmax(logits[s]))
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = self.positions[s] >= self.max_seq - 1
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until every submitted request completes."""
        for _ in range(max_steps):
            if not self.pending and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.completed
