"""Model substrate: layers, attention, MoE, SSM blocks, and the LM/Whisper
assemblies for the 10 assigned architectures."""

from repro.configs.base import ModelConfig

from .transformer import LM, default_segments, unit_pattern
from .whisper import WhisperModel


def build_model(cfg: ModelConfig):
    """Factory: arch family → model object with init/loss/decode_step."""
    if cfg.encoder_decoder:
        return WhisperModel(cfg)
    return LM(cfg)


__all__ = ["LM", "WhisperModel", "build_model", "default_segments", "unit_pattern"]
