"""Paper-faithful interpreter of the canonical strategy (§3).

While ``BlockGraph.apply_planned`` lowers the plan into ``jax.checkpoint``
(the production path), this module *interprets* the strategy step by step —
forward caching only ∂(L_i), backward recomputing each V_i from ∂(L_{i-1}) —
so tests can assert that the strategy's gradients match vanilla
backpropagation exactly, and so the per-step live set can be audited against
the liveness simulator.

This is the executable twin of ``core.liveness.build_events``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .blockgraph import BlockGraph
from .schedule import ExecutionPlan


def planned_value_and_grad(
    bg: BlockGraph,
    plan: ExecutionPlan,
    loss_fn: Callable[..., jax.Array],
    track_live: bool = False,
):
    """Return f(params, inputs) -> (loss, grads_params[, live_trace]).

    loss_fn consumes the BlockGraph outputs and returns a scalar.
    Gradients are produced by interpreting the canonical strategy:

      forward : run segments in order; after segment i discard every value of
                V_i not in U_k (the union of boundaries).
      backward: for i = k…1, recompute the discarded values of V_i from the
                caches, then run per-block VJPs in reverse topological order.
    """
    name_of = {i: b.name for i, b in enumerate(bg.blocks)}

    def run(params: Dict[str, Any], inputs: Dict[str, Any]):
        live_trace: List[Tuple[str, int]] = []
        cached_names = {name_of[v] for v in plan.cached}

        def snapshot(tag: str, store: Dict[str, Any]) -> None:
            if track_live:
                nbytes = sum(
                    sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(v))
                    for v in store.values()
                )
                live_trace.append((tag, int(nbytes)))

        # ---------------- forward ----------------
        cache: Dict[str, Any] = dict(inputs)
        for seg in plan.segments:
            local: Dict[str, Any] = {}
            for v in seg.nodes:
                b = bg.by_name[name_of[v]]
                args = [
                    local[i] if i in local else cache[i] for i in b.inputs
                ]
                local[b.name] = b.apply(params[b.name], *args)
            # canonical rule: keep only boundary values (and model outputs)
            for name, val in local.items():
                if name in cached_names or name in bg.outputs:
                    cache[name] = val
            snapshot(f"fwd_seg{seg.index}", cache)

        outs = tuple(cache[o] for o in bg.outputs)
        loss, loss_vjp = jax.vjp(
            lambda *o: loss_fn(*o) if len(o) > 1 else loss_fn(o[0]), *outs
        )
        out_grads = loss_vjp(jnp.ones_like(loss))

        # ---------------- backward ----------------
        grad_of: Dict[str, Any] = {}
        for o, g in zip(bg.outputs, out_grads):
            grad_of[o] = g
        param_grads: Dict[str, Any] = {}

        for seg in reversed(plan.segments):
            # recompute discarded values of V_i from live caches
            local: Dict[str, Any] = {}
            for v in seg.nodes:
                b = bg.by_name[name_of[v]]
                if b.name in cache:
                    local[b.name] = cache[b.name]
                    continue
                args = [local[i] if i in local else cache[i] for i in b.inputs]
                local[b.name] = b.apply(params[b.name], *args)
            snapshot(f"bwd_recompute_seg{seg.index}", {**cache, **local})

            # VJP sweep, reverse topological order within the segment
            for v in reversed(seg.nodes):
                b = bg.by_name[name_of[v]]
                g_out = grad_of.pop(b.name, None)
                if g_out is None:
                    continue  # value unused by the loss
                args = [local[i] if i in local else cache[i] for i in b.inputs]
                _out, vjp = jax.vjp(b.apply, params[b.name], *args)
                pulls = vjp(g_out)
                g_param, g_args = pulls[0], pulls[1:]
                param_grads[b.name] = (
                    jax.tree_util.tree_map(jnp.add, param_grads[b.name], g_param)
                    if b.name in param_grads
                    else g_param
                )
                for i_name, g_arg in zip(b.inputs, g_args):
                    if i_name in inputs:
                        continue  # no grads w.r.t. graph inputs requested
                    grad_of[i_name] = (
                        grad_of[i_name] + g_arg if i_name in grad_of else g_arg
                    )
            # discard this segment's forward values (canonical rule); its
            # cached boundary values are no longer needed either once the
            # earlier-segment gradients that flow *through* them are queued.
            for v in seg.nodes:
                cache.pop(name_of[v], None)
            snapshot(f"bwd_done_seg{seg.index}", cache)

        # blocks with no params still get an empty-grads entry for tree-match
        for b in bg.blocks:
            if b.name not in param_grads:
                param_grads[b.name] = jax.tree_util.tree_map(
                    jnp.zeros_like, params[b.name]
                )
        if track_live:
            return loss, param_grads, live_trace
        return loss, param_grads

    return run


def vanilla_value_and_grad(
    bg: BlockGraph, loss_fn: Callable[..., jax.Array]
):
    """Reference: jax.value_and_grad over the vanilla executor."""

    def f(params, inputs):
        out = bg.apply(params, inputs)
        return loss_fn(*out) if isinstance(out, tuple) else loss_fn(out)

    return jax.value_and_grad(f)


def planned_value_and_grad_under_budget(
    bg: BlockGraph,
    params: Dict[str, Any],
    inputs: Dict[str, Any],
    loss_fn: Callable[..., jax.Array],
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
    cost_model: str = "paper",
    planner=None,
    track_live: bool = False,
):
    """Trace → plan (through the plan cache) → interpret, in one call.

    The planning step routes through ``core.planner.Planner`` (the
    process-default one unless ``planner`` is given), so rebuilding the
    runner for the same BlockGraph and budget — a new training process, a
    re-created executor in a sweep — reuses the cached DP solution instead
    of re-solving it.  Returns ``(run_fn, PlanReport)``.
    """
    from .planner import get_default_planner

    g = bg.to_graph(params, inputs, cost_model=cost_model)
    pl = planner or get_default_planner()
    report = pl.plan(g, budget, method, objective)
    if report.plan is None:
        # The budget sweep that just failed already carries the exact
        # minimal feasible budget on its terminal frontier — surface it so
        # the caller knows how much memory the strategy actually needs.
        hint = ""
        if method in ("exact_dp", "approx_dp"):
            needed = pl.min_feasible_budget(g, method)
            hint = f"; minimal feasible budget is {needed:g}"
        raise ValueError(
            f"no feasible strategy for budget {budget!r} "
            f"({method}/{objective}){hint}"
        )
    return planned_value_and_grad(bg, report.plan, loss_fn, track_live), report
