"""repro — graph-theoretic recomputation planning on JAX (Kusumoto et al.).

Front door::

    import repro

    planned = repro.plan_function(loss_fn, budget=bytes)   # any JAX callable
    loss, grads = planned(params, batch)                   # value_and_grad twin

One pipeline behind it: graph carriers (traced jaxpr | BlockGraph) →
``core.planner.Planner`` (plan cache + budget sweep) → registered Lowering
backends (``core.lowering``).  Heavy imports are deferred: ``import repro``
alone stays cheap.
"""

from typing import TYPE_CHECKING

__all__ = [
    "plan_function",
    "PlannedFunction",
    "Planner",
    "plan",
    "min_feasible_budget",
]

if TYPE_CHECKING:  # pragma: no cover — static-analysis only
    from repro.core.lowering import PlannedFunction, plan_function
    from repro.core.planner import Planner, min_feasible_budget, plan


def __getattr__(name):  # PEP 562 lazy re-exports
    if name in ("plan_function", "PlannedFunction"):
        from repro.core import lowering

        return getattr(lowering, name)
    if name in ("Planner", "plan", "min_feasible_budget"):
        from repro.core import planner

        return getattr(planner, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
