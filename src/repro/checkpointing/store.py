"""Sharded, fault-tolerant checkpointing (numpy-backed, async writer).

Layout — one directory per step, one ``.npy`` per pytree leaf plus a
manifest:

  <dir>/step_000123/
      MANIFEST.json       {"step": 123, "leaves": {path: {file, dtype, shape}}}
      <sanitized-path>.npy
      COMMITTED           written last — a step directory without it is torn
                          and ignored by ``latest_step`` / ``restore``

Crash-safety: writes land in ``step_<n>.tmp`` and are renamed into place
after the COMMITTED marker is written, so a process killed mid-save never
corrupts the restore path (restart picks the previous committed step).
``AsyncCheckpointer`` runs saves on a worker thread; ``wait()`` drains it
(train.loop calls wait() at shutdown and before restores).

On a multi-host deployment each host saves only the leaves it owns
(``addressable_shards``) under a per-host subdirectory; this container is
single-host, so host 0 owns everything — the layout and commit protocol are
identical.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _sanitize(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", path)


def atomic_write_json(path: str, obj: Any) -> None:
    """Crash-safe JSON write: temp file + atomic rename (same protocol as the
    step-directory commit below, shared with core.plan_cache's disk store)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def read_json(path: str) -> Optional[Any]:
    """Read a JSON file; None when missing or torn (partial/corrupt write).

    ValueError covers both JSONDecodeError and the UnicodeDecodeError a
    non-UTF-8 corrupted file raises before the JSON parser even runs.
    """
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        keys = []
        for p in kp:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


def save(base_dir: str, step: int, tree: Any) -> str:
    """Synchronous committed save; returns the final step directory."""
    os.makedirs(base_dir, exist_ok=True)
    final = os.path.join(base_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        store = arr
        if arr.dtype.kind == "V" or dtype not in np.sctypeDict:
            # ml_dtypes (bfloat16, float8, …) don't survive np.save — store
            # the raw bits and re-view on restore from the manifest dtype
            store = arr.view(np.uint8).reshape(arr.shape + (arr.itemsize,))
        fname = _sanitize(path) + ".npy"
        np.save(os.path.join(tmp, fname), store)
        manifest["leaves"][path] = {
            "file": fname,
            "dtype": dtype,
            "shape": list(arr.shape),
        }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(base_dir: str) -> Optional[int]:
    """Largest committed step, or None. Torn (.tmp / uncommitted) dirs skipped."""
    if not os.path.isdir(base_dir):
        return None
    best = None
    for name in os.listdir(base_dir):
        m = _STEP_RE.match(name)
        if not m:
            continue
        if not os.path.exists(os.path.join(base_dir, name, "COMMITTED")):
            continue
        s = int(m.group(1))
        best = s if best is None else max(best, s)
    return best


def restore(base_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore a pytree; ``like`` provides the structure (leaves ignored).

    With ``shardings`` (a matching pytree of jax.sharding.Sharding), each leaf
    is placed with jax.device_put onto its target sharding — this is how a
    restarted job with a *different* mesh resharding-restores (elastic
    scaling): the on-disk format is mesh-agnostic full arrays.
    """
    d = os.path.join(base_dir, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    paths = _leaf_paths(like)
    shard_leaves = (
        [s for _, s in _leaf_paths(shardings)] if shardings is not None else None
    )
    leaves = []
    for i, (path, _) in enumerate(paths):
        entry = manifest["leaves"].get(path)
        if entry is None:
            raise KeyError(f"checkpoint at step {step} is missing leaf {path!r}")
        arr = np.load(os.path.join(d, entry["file"]))
        want = entry["dtype"]
        if str(arr.dtype) != want:
            # raw-bit storage of an ml_dtype: view back via the manifest
            arr = arr.reshape(tuple(entry["shape"]) + (-1,)).view(
                np.dtype(want)
            )[..., 0]
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def retain(base_dir: str, keep: int) -> None:
    """Garbage-collect all but the newest ``keep`` committed steps."""
    if not os.path.isdir(base_dir):
        return
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(base_dir)
        if (m := _STEP_RE.match(name))
        and os.path.exists(os.path.join(base_dir, name, "COMMITTED"))
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(base_dir, f"step_{s:09d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread writer overlapping serialization/disk I/O with the
    next training steps.

    ``save_async`` snapshots the tree to host memory *on the caller thread*
    (device buffers may be donated to the very next step, so holding device
    references across steps is unsafe) and enqueues the numpy copies; the
    worker thread only does file I/O — the slow part on real clusters.
    """

    def __init__(self, base_dir: str, keep: int = 3):
        self.base_dir = base_dir
        self.keep = keep
        self._q: "queue.Queue[Optional[Tuple[int, Any]]]" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.base_dir, step, tree)
                retain(self.base_dir, self.keep)
            except BaseException as e:  # surfaced on the next wait()
                self._err = e
            finally:
                self._q.task_done()

    def save_async(self, step: int, tree: Any) -> None:
        if self._err is not None:
            raise self._err
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree
        )
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
