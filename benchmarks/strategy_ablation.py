"""Joint memory-strategy DP ablation on the benchmark nets (PR 10).

For each network the planner runs with nested strategy sets —

  recompute   {store, recompute}              (the paper's binary)
  +offload    {store, recompute, offload}
  +quantize   {store, recompute, quantize}
  joint       {store, recompute, offload, quantize}

— and reports two columns per set: the **exact minimal feasible budget**
(``dp.min_feasible_budget_exact``) and the **replayed step time** of the
time-centric plan at a fixed budget (1.25 × the recompute-only minimum),
priced by the discrete-event replay (``core.replay``) with the
strategies' transfer/codec streams.

Guards (exit 1 under ``--smoke`` on any violation):

* **budget monotonicity** — enabling a strategy never raises the minimal
  feasible budget (exact: the extended feasibility problem is the binary
  one over ``StrategyConfig.min_device_bytes``, a pointwise-smaller byte
  vector), and the joint set is ≤ each single extension;
* **overhead monotonicity** — at the fixed budget, the joint DP's taxed
  t-axis objective never exceeds the recompute-only overhead (exact: the
  legacy all-store assignment stays in the option set);
* **step-time regression** — the replayed step time of each extended
  plan stays within ``REPLAY_TOL`` of the recompute-only plan (the
  time-centric objective is a proxy for replay, so a noise-sized
  tolerance applies; ``objective="wallclock"`` ranks the joint candidate
  pool by replayed seconds directly and is never-slower by construction
  — property-tested in ``tests/test_strategies.py``, too slow to sweep
  here);
* **strict wins** — on ≥ ``MIN_STRICT_WINS`` nets the joint DP finds a
  *strictly* lower feasible budget, or a strictly lower replayed step
  time at the fixed budget (the PR's acceptance criterion).

Every run writes ``BENCH_strategies.json`` (per-net columns + guard
verdicts); ``--smoke`` trims the net set and is wired into CI with the
artifact uploaded per commit.

The benchmark graphs carry the paper's abstract 10/1 time axis; one unit
is taken as ~1 ms of compute (``SECONDS_PER_TIME_UNIT``) so the PCIe and
int8-codec taxes land on the same axis as ``T_v`` and the DP actually
trades transfer time against recomputation.
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List, Optional

from repro.core import dp as dp_mod
from repro.core import make_plan
from repro.core.lower_sets import pruned_lower_sets
from repro.core.replay import replay
from repro.core.strategies import StrategyConfig

from .networks import NETWORKS

SMOKE_NETS = ("vgg19", "unet")
BUDGET_MULT = 1.25  # fixed budget = 1.25 × recompute-only minimal feasible
REPLAY_TOL = 0.02  # extended plans: replayed step within 2 % of recompute-only
MIN_STRICT_WINS = 2  # acceptance: ≥ 2 nets strictly improved by the joint DP
#: One abstract T unit ≈ 1 ms of compute (vgg-scale conv ≈ 10 ms).
SECONDS_PER_TIME_UNIT = 1e-3


def _cfg(*extra: str) -> StrategyConfig:
    return StrategyConfig(
        strategies=("store", "recompute") + extra,
        seconds_per_time_unit=SECONDS_PER_TIME_UNIT,
    )


#: Ablation cells, in nesting order ("recompute" is the legacy baseline).
STRATEGY_SETS: Dict[str, Optional[StrategyConfig]] = {
    "recompute": None,
    "+offload": _cfg("offload"),
    "+quantize": _cfg("quantize"),
    "joint": _cfg("offload", "quantize"),
}


# ------------------------------------------------------------------ per net


def bench_net(name: str) -> Dict[str, Any]:
    g = NETWORKS[name]()
    fam = pruned_lower_sets(g)
    row: Dict[str, Any] = {"nodes": g.n, "family": len(fam)}

    cells: Dict[str, Dict[str, Any]] = {}
    for tag, cfg in STRATEGY_SETS.items():
        cells[tag] = {
            "min_feasible_budget": dp_mod.min_feasible_budget_exact(
                g, fam, strategies=cfg
            )
        }

    budget = cells["recompute"]["min_feasible_budget"] * BUDGET_MULT
    row["budget_bytes"] = budget
    for tag, cfg in STRATEGY_SETS.items():
        res = dp_mod.solve(
            g, budget, fam, objective="time_centric", strategies=cfg
        )
        assert res.feasible, (name, tag)
        plan = make_plan(g, res.sequence, assignment=res.assignment,
                         strategies=cfg)
        rr = replay(g, plan, budget=budget, strategies=cfg)
        asg = res.assignment or {}
        cells[tag].update(
            overhead=res.overhead,
            replayed_step_s=rr.seconds * SECONDS_PER_TIME_UNIT,
            plan_peak_bytes=plan.peak_memory,
            segments=len(plan.segments),
            offloaded=sum(1 for c in asg.values() if c == "offload"),
            quantized=sum(1 for c in asg.values() if c == "quantize"),
        )
    row["cells"] = cells
    base = cells["recompute"]
    joint = cells["joint"]
    row["strict_budget_win"] = (
        joint["min_feasible_budget"] < base["min_feasible_budget"]
    )
    row["strict_step_win"] = joint["replayed_step_s"] < base["replayed_step_s"]
    return row


# -------------------------------------------------------------------- guards


def check_rows(rows: Dict[str, Dict[str, Any]]) -> List[str]:
    failures: List[str] = []
    for name, r in rows.items():
        c = r["cells"]
        base = c["recompute"]
        for tag in ("+offload", "+quantize", "joint"):
            if c[tag]["min_feasible_budget"] > base["min_feasible_budget"]:
                failures.append(
                    f"{name}/{tag}: min feasible budget rose "
                    f"({c[tag]['min_feasible_budget']:.3e} > "
                    f"{base['min_feasible_budget']:.3e})")
            if c[tag]["overhead"] > base["overhead"] * (1 + 1e-12):
                failures.append(
                    f"{name}/{tag}: taxed overhead rose "
                    f"({c[tag]['overhead']:.4f} > {base['overhead']:.4f})")
            if (c[tag]["replayed_step_s"]
                    > base["replayed_step_s"] * (1 + REPLAY_TOL)):
                failures.append(
                    f"{name}/{tag}: replayed step regressed "
                    f"({c[tag]['replayed_step_s']:.4e}s vs "
                    f"{base['replayed_step_s']:.4e}s, > {REPLAY_TOL:.0%})")
        for tag in ("+offload", "+quantize"):
            if c["joint"]["min_feasible_budget"] > c[tag]["min_feasible_budget"]:
                failures.append(
                    f"{name}: joint min feasible budget above {tag}'s")
    wins = sum(
        r["strict_budget_win"] or r["strict_step_win"] for r in rows.values()
    )
    if wins < min(MIN_STRICT_WINS, len(rows)):
        failures.append(
            f"joint DP strictly improved only {wins} net(s) "
            f"(budget or replayed step) — need "
            f"{min(MIN_STRICT_WINS, len(rows))}")
    return failures


# ---------------------------------------------------------------------- main


def main(smoke: bool = False,
         out_json: str = "BENCH_strategies.json") -> Dict[str, Any]:
    nets = SMOKE_NETS if smoke else tuple(NETWORKS)
    print(f"== joint memory-strategy DP ablation ({', '.join(nets)}) ==")
    print(f"{'network':12s} {'set':>10s} {'min_budget':>11s} "
          f"{'step_s':>10s} {'overhead':>9s} {'off':>4s} {'qz':>4s}")
    rows: Dict[str, Dict[str, Any]] = {}
    for name in nets:
        rows[name] = bench_net(name)
        for tag, cell in rows[name]["cells"].items():
            print(f"{name:12s} {tag:>10s} {cell['min_feasible_budget']:11.3e} "
                  f"{cell['replayed_step_s']:10.4e} {cell['overhead']:9.3f} "
                  f"{cell['offloaded']:4d} {cell['quantized']:4d}")
        print(f"{'':12s} strict win: budget={rows[name]['strict_budget_win']} "
              f"step={rows[name]['strict_step_win']}")
    failures = check_rows(rows)
    out = {
        "nets": rows,
        "thresholds": {
            "budget_mult": BUDGET_MULT,
            "replay_tol": REPLAY_TOL,
            "min_strict_wins": MIN_STRICT_WINS,
            "seconds_per_time_unit": SECONDS_PER_TIME_UNIT,
        },
        "failures": failures,
    }
    if out_json:
        import json

        with open(out_json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"\nwrote {out_json}")
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(f"  - {f}")
        if smoke:
            sys.exit(1)
    else:
        print("\nall strategy-ablation guards passed")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed net set; exit 1 on guard violations")
    ap.add_argument("--out-json", default="BENCH_strategies.json")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out_json)
