"""§4.1 — naive exhaustive search over lower-set sequences.

Exponential; used as the correctness oracle for the DP in tests (the DP's
optimum must match the exhaustive optimum on small graphs) and to expose the
triplet-state ``(L, t, m)`` observation that motivates the DP.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from .dp import DPResult, INF, peak_memory_live, to_mask
from .graph import EMPTY, Graph, NodeSet
from .liveness import transition_excess
from .lower_sets import all_lower_sets


def exhaustive_search(
    g: Graph,
    budget: float,
    objective: str = "time_centric",
    family: Optional[Sequence[NodeSet]] = None,
) -> DPResult:
    """DFS over all increasing sequences {L₁ ≺ … ≺ L_k = V} within budget.

    Tracks the triplet (L, t, m) exactly as §4.1 describes:
      t = overhead so far, m = M(U_i) of the cache so far.
    """
    fam = list(family) if family is not None else all_lower_sets(g)
    fam = [L for L in fam if L]  # drop ∅ as a sequence element
    full = frozenset(range(g.n))
    fam_sorted = sorted(fam, key=len)

    best_t = INF if objective == "time_centric" else -INF
    best_seq: List[NodeSet] = []
    states = 0

    # Precompute per-L terms.
    info = {}
    for L in fam_sorted:
        b = g.boundary(L)
        info[L] = (b, to_mask(L), to_mask(b))

    def better(t: float) -> bool:
        return t < best_t if objective == "time_centric" else t > best_t

    def rec(L: NodeSet, t: float, m: float, seq: List[NodeSet]) -> None:
        nonlocal best_t, best_seq, states
        states += 1
        if L == full:
            if better(t):
                best_t = t
                best_seq = list(seq)
            return
        mask_L = to_mask(L)
        for Lp in fam_sorted:
            if len(Lp) <= len(L) or not (L < Lp):
                continue
            b, mask_Lp, bd_mask = info[Lp]
            Vp = Lp - L
            # 𝓜⁽ⁱ⁾ with M(U_{i-1}) = m, same functional (and same memoized
            # floats) as the DP it oracles
            Mi = m + transition_excess(g, mask_L, mask_Lp, bd_mask)
            if Mi > budget:
                continue
            t2 = t + g.T(Vp - b)
            m2 = m + g.M(b - L)
            seq.append(Lp)
            rec(Lp, t2, m2, seq)
            seq.pop()

    rec(EMPTY, 0.0, 0.0, [])

    if not best_seq:
        return DPResult([], INF, INF, feasible=False, states_visited=states)
    return DPResult(
        sequence=best_seq,
        overhead=best_t,
        peak_memory=peak_memory_live(g, best_seq),
        feasible=True,
        states_visited=states,
    )
