"""Step-function builders: the exact functions the dry-run lowers and the
launchers run.

Each builder returns ``(fn, in_shardings, out_shardings, example_inputs)``
where ``example_inputs`` are ShapeDtypeStructs — so

    with jax.sharding.set_mesh(mesh):
        jax.jit(fn, in_shardings=..., out_shardings=...).lower(*example_inputs)

is the whole dry-run for one cell, and the same jitted function accepts real
arrays in the launchers.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import specs as S
from repro.launch.plan import needs_fsdp, plan_with_microbatching
from repro.models import build_model
from repro.optim import adamw


def _dp_shards(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def _model_shards(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)


def _logits_sharding(cfg: ModelConfig, global_batch: int, mesh: Mesh):
    """Logits (B, S, V): batch over dp axes, vocab over model — guarded for
    odd vocabs (49155, 51865) and batch=1 long-context cells."""
    from repro.parallel.sharding import _axis_sizes, drop_indivisible

    ba = S.batch_axes(mesh)
    spec = P(None if global_batch == 1 else ba, None, "model")
    spec = drop_indivisible(
        spec, (global_batch, 1, cfg.vocab_size), _axis_sizes(mesh)
    )
    return NamedSharding(mesh, spec)


def _seq_shards(mesh: Mesh, shape: ShapeConfig) -> int:
    if shape.global_batch > 1:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1)


def segment_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 objective: Optional[str] = None,
                 model_shards_override: Optional[int] = None):
    """The paper's technique, applied: DP-plan the remat segmentation, with
    the smallest feasible gradient-accumulation factor (§5.1's minimal-budget
    protocol turned inside out for a fixed per-device HBM).

    The whole call is one pass of the unified pipeline (chain carrier →
    shared Planner → scan-chain segment lowering); restarts and re-meshes
    re-plan through the content-addressed plan cache.

    Returns (SegmentPlan, DPResult)."""
    if cfg.remat_method == "none":
        return None, None
    from repro.parallel.sharding import get_rules

    obj = objective or cfg.remat_objective
    ms = model_shards_override or _model_shards(mesh)
    dp = _dp_shards(mesh)
    if model_shards_override == 1:  # dp_only: "model" joins the batch axes
        dp *= _model_shards(mesh)
    # the active rules table prices the chain bytes: whatever layout the
    # hillclimb knob selected is exactly what the DP budgets against
    return plan_with_microbatching(
        cfg, shape, dp, _seq_shards(mesh, shape),
        model_shards=ms, objective=obj, rules=get_rules(),
    )


# ---------------------------------------------------------------------- train


def build_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    opt: Optional[adamw.AdamWConfig] = None,
    segment_sizes: Optional[Tuple[int, ...]] = None,
    n_micro: Optional[int] = None,
    opts: Tuple[str, ...] = (),
):
    """opts (§Perf hillclimb knobs, default = paper-faithful baseline):
      "mp"      — bf16 compute copy of the f32 master params: halves weight
                  all-gather bytes (ZeRO/FSDP paths).
      "dp_only" — drop tensor parallelism; "model" axis joins data
                  parallelism, params fully sharded (ZeRO-3).  For ≤ ~4B
                  models at 256 chips this removes the per-layer
                  activation-cotangent all-reduces entirely.
    """
    from repro.parallel.sharding import (
        DEFAULT_RULES,
        DP_ATTN_RULES,
        DP_ONLY_RULES,
        set_rules,
    )

    if "dp_only" in opts:
        set_rules(DP_ONLY_RULES)
    elif "dp_attn" in opts:
        set_rules(DP_ATTN_RULES)
    else:
        set_rules(DEFAULT_RULES)
    model = build_model(cfg)
    ocfg = opt or adamw.AdamWConfig()
    model_shards = 1 if "dp_only" in opts else _model_shards(mesh)
    segment_remat = None
    if segment_sizes is None:
        sp, _ = segment_plan(cfg, shape, mesh, model_shards_override=model_shards)
        if sp is not None:
            segment_sizes, segment_remat = sp.sizes, sp.remat
            n_micro = n_micro or sp.n_micro
    n_micro = n_micro or 1
    mp = "mp" in opts

    def loss_fn(p, b):
        if mp:
            p = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32
                else x,
                p,
            )
        return model.loss(p, b, segment_sizes=segment_sizes,
                          segment_remat=segment_remat)

    grad_sharding = S.param_shardings(cfg, mesh) if "rs" in opts else None

    def _constrain_grads(grads):
        # ZeRO: pin gradients to the (sharded) parameter layout immediately,
        # so GSPMD lowers the data-axis reduction as a reduce-scatter instead
        # of all-reduce + slice-at-update.
        if grad_sharding is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads,
            grad_sharding,
        )

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain_grads(grads)
        else:
            # gradient accumulation over n_micro microbatches (lax.scan keeps
            # one live microbatch of activations at a time)
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch,
            )
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, b):
                acc_loss, acc_g = acc
                l, g = jax.value_and_grad(loss_fn)(params, b)
                g = _constrain_grads(g)
                acc_g = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g
                )
                return (acc_loss + l, acc_g), None

            (loss, grads), _ = jax.lax.scan(body, (0.0, g0), micro)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params, new_opt, metrics = adamw.update(ocfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    params = S.params_specs(cfg)
    opt_state = S.opt_specs(params)
    batch = S.input_specs(cfg, shape)

    p_sh = S.param_shardings(cfg, mesh, params)
    # mu/nu shaped like params → same shardings; step counter replicated
    o_sh = adamw.AdamWState(step=S.replicated(mesh), mu=p_sh, nu=p_sh)
    b_sh = S.input_shardings(cfg, shape, mesh)
    rep = S.replicated(mesh)
    metric_sh = {"grad_norm": rep, "lr": rep, "loss": rep}
    in_sh = (p_sh, o_sh, b_sh)
    out_sh = (p_sh, o_sh, metric_sh)
    return train_step, in_sh, out_sh, (params, opt_state, batch)


# -------------------------------------------------------------------- prefill


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       segment_sizes: Optional[Tuple[int, ...]] = None):
    model = build_model(cfg)

    if cfg.encoder_decoder:

        def prefill(params, batch):
            enc = model.encode(params, batch["frames"])
            return model.decode_train(params, batch["tokens"], enc)

    else:

        def prefill(params, batch):
            return model.forward(
                params,
                batch["tokens"],
                extra_embeds=batch.get("extra_embeds"),
                segment_sizes=segment_sizes,
            )

    params = S.params_specs(cfg, serving=True)
    batch = S.input_specs(cfg, shape)
    p_sh = S.param_shardings(cfg, mesh, params)
    b_sh = S.input_shardings(cfg, shape, mesh)
    logits_sh = _logits_sharding(cfg, shape.global_batch, mesh)
    return prefill, (p_sh, b_sh), logits_sh, (params, batch)


# --------------------------------------------------------------------- decode


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      opts: Tuple[str, ...] = ()):
    """opts:
      "ws" — weight-stationary decode: the residual-stream feature axis is
             sharded over "data", so FSDP'd weights are consumed in place by
             distributed matmuls (small activation partial-sum all-reduces)
             instead of being all-gathered every token step.
    """
    from repro.parallel.sharding import DEFAULT_RULES, set_rules

    model = build_model(cfg)
    if "ws" in opts:
        set_rules({**DEFAULT_RULES, "model": "data"})

    def serve_step(params, caches, tokens, positions):
        logits, new_caches = model.decode_step(params, tokens, caches, positions)
        return logits, new_caches

    params = S.params_specs(cfg, serving=True)
    caches = S.cache_specs(cfg, shape)
    inputs = S.input_specs(cfg, shape)
    tokens, positions = inputs["tokens"], inputs["positions"]

    p_sh = S.param_shardings(cfg, mesh, params)
    c_sh = S.cache_shardings(cfg, shape, mesh, caches)
    ba = S.batch_axes(mesh)
    long_ctx = shape.global_batch == 1
    tok_sh = NamedSharding(mesh, P(None if long_ctx else ba, None))
    pos_sh = NamedSharding(mesh, P(None if long_ctx else ba))
    logits_sh = _logits_sharding(cfg, shape.global_batch, mesh)
    in_sh = (p_sh, c_sh, tok_sh, pos_sh)
    out_sh = (logits_sh, c_sh)
    return serve_step, in_sh, out_sh, (params, caches, tokens, positions)


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opts: Tuple[str, ...] = (), **kw):
    """Dispatch on the shape kind: train / prefill / decode."""
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, opts=opts, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, **kw)
    return build_decode_step(cfg, shape, mesh, opts=opts)
