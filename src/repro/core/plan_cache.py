"""Content-addressed plan cache: solve each (graph, budget, family,
objective) once, ever.

The DP of Algorithm 1 is exponential in the worst case (§4.2), yet the
framework re-plans constantly: every ``Planner.plan`` call, every budget
point of a trade-off sweep (benchmarks/fig3_tradeoff.py), every cell of the
dry-run matrix, and every restart of a training job re-solve graphs that
were already solved.  This module memoizes solved ``DPResult``s behind a
canonical content address so repeated planning is a hash lookup:

* **two entry kinds** — ``plan`` entries keyed by ``(graph_digest, budget,
  family, objective)`` hold one ``DPResult``; ``sweep`` entries keyed by
  ``(graph_digest, family, objective)`` — **no budget** — hold the whole
  budget-free frontier of ``core.dp.sweep``, so a single cold solve admits
  every future budget query (per-budget plans, minimal-feasible-budget
  probes, trade-off grids) on that graph.  ``graph_digest`` (core.graph) is
  invariant under node-id permutation and covers topology + quantized
  costs + kinds.  Calibrated costs from the measured cost model
  (core.cost_model) flow into the digest automatically, so re-profiling on
  different hardware *invalidates* stale plans by construction — no epoch
  counters needed; sharded planning flows in the same way (per-device
  ``M_v`` is part of the digest), and the DP's memory-functional version
  (``dp.MEMORY_FUNCTIONAL``) is hashed into every key, so plans solved
  under an older functional (e.g. the pre-liveness eq. 2) can never be
  served.  docs/plan_cache.md spells out the full invalidation matrix.
* **values in canonical coordinates** — lower-set sequences are stored as
  canonical node positions and mapped back through the querying graph's
  canonical order, so a cached plan transfers between isomorphic labelings
  (e.g. the same network traced twice with different eqn numbering).
* **three tiers** — an in-memory LRU (per process) over an optional on-disk
  content-addressed store (crash-safe single-file JSON writes; filename =
  SHA-256 of the key, sharded by 2-hex-char prefix like a git object
  store), over an optional **fleet-shared remote store** (``RemoteStore``)
  in read-through mode: a miss in the local tiers fetches from the remote
  and back-fills memory + disk, and every put pushes through, so a plan
  solved by any process in the fleet is a lookup for every other one.
  Content addressing makes read-through trivially coherent — two stores
  can only ever hold the *same* bytes under a hash, so there is no
  staleness protocol; the invalidation matrix is unchanged.  Concurrent
  writers on one digest are serialized by an O_EXCL ``.lock`` file
  (``_locked_write_json``); a loser skips the write (same bytes anyway).
* **validated hits** — every hit is re-validated against the querying graph
  (``check_increasing_sequence``), so a digest collision or a corrupt cache
  file degrades to a miss, never a wrong plan.

Process-wide default: ``default_cache()`` (used by ``core.planner.Planner``
when no cache is passed); ``set_default_cache_dir`` attaches the disk tier —
the train loop and serving engine call it when configured with a
``plan_cache_dir``, so co-located jobs share one store.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.checkpointing.store import atomic_write_json, read_json

from .dp import MEMORY_FUNCTIONAL, DPResult, Sweep, decode_sweep
from .graph import Graph, NodeSet, canonical_maps, graph_digest

# Bump whenever the stored shape changes; v2 = liveness-tight memory
# functional (peaks/feasibility of stored plans and sweeps are priced by
# dp.MEMORY_FUNCTIONAL, which is also hashed into every key, so entries
# solved under eq. 2 — or any future functional — invalidate by
# construction, exactly like a cost-model recalibration does through the
# graph digest).
FORMAT_VERSION = 2

#: a ``.lock`` older than this is presumed abandoned (holder crashed between
#: acquiring and unlinking) and is broken by the next writer
STALE_LOCK_SECONDS = 60.0


def _locked_write_json(path: str, obj: object,
                       stale_s: float = STALE_LOCK_SECONDS) -> bool:
    """Cross-process exclusive JSON write; returns True when this call wrote.

    ``atomic_write_json`` alone is torn-read-safe (temp file + rename) but
    two processes read-through-solving the same digest would both write.
    An ``O_CREAT | O_EXCL`` sidecar ``<path>.lock`` serializes them; the
    loser simply *skips* — entries are content-addressed, so the winner is
    writing byte-identical data and a second write is pure waste.  A lock
    older than ``stale_s`` is presumed leaked by a crashed holder and is
    broken (unlink + retry once).
    """
    lock = path + ".lock"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    try:
        fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        try:
            age = time.time() - os.path.getmtime(lock)
        except OSError:
            return False  # holder finished between our open and stat
        if age < stale_s:
            return False  # live writer owns this digest; same bytes anyway
        try:
            os.unlink(lock)  # break the stale lock …
            fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except OSError:
            return False  # … lost the re-acquire race — fine, skip
    try:
        atomic_write_json(path, obj)
        return True
    finally:
        os.close(fd)
        try:
            os.unlink(lock)
        except OSError:  # pragma: no cover — lock vanished under us
            pass


def _locked_unlink(path: str, stale_s: float = STALE_LOCK_SECONDS) -> bool:
    """Cross-process exclusive delete; returns True when this call removed.

    The GC sweep's counterpart of :func:`_locked_write_json`: deleting an
    entry takes the same ``<path>.lock`` sidecar, so a sweep never yanks a
    file out from under an in-flight write (the writer holds the lock for
    the whole temp-file + rename).  A held live lock means someone is
    *refreshing* this digest — skip it, it is not garbage.  Stale locks are
    broken with the same age rule as writes.
    """
    lock = path + ".lock"
    try:
        fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        try:
            age = time.time() - os.path.getmtime(lock)
        except OSError:
            return False  # holder finished between our open and stat
        if age < stale_s:
            return False  # live writer — the entry is being refreshed
        try:
            os.unlink(lock)
            fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except OSError:
            return False
    except OSError:
        return False  # e.g. shard directory already swept away
    try:
        os.unlink(path)
        return True
    except OSError:
        return False
    finally:
        os.close(fd)
        try:
            os.unlink(lock)
        except OSError:  # pragma: no cover — lock vanished under us
            pass


# ---------------------------------------------------------------------------
# Remote (fleet-shared) stores: the third tier under LRU + disk.
# ---------------------------------------------------------------------------


class RemoteStore:
    """Transport interface for a fleet-shared plan store.

    Implementations move opaque ``(content_hash → JSON entry)`` pairs; all
    keying, validation, and coherence live in :class:`PlanCache` — content
    addresses make read-through trivially coherent, so a transport needs no
    consistency guarantees beyond "a fetch returns bytes some push wrote
    (or None)".  Transport failures should raise ``OSError`` (counted as
    ``remote_errors`` and degraded to a miss, never a planning failure).
    """

    scheme = "abstract"

    def fetch(self, content_hash: str) -> Optional[dict]:
        raise NotImplementedError

    def push(self, content_hash: str, entry: dict) -> None:
        raise NotImplementedError


class SharedFSStore(RemoteStore):
    """Shared-filesystem transport (NFS / Lustre / GCS-fuse mount).

    Same sharded object layout as the local disk tier, so a fleet store can
    be seeded by simply copying a warm node's cache directory.  Pushes go
    through :func:`_locked_write_json` — concurrent read-through writers on
    one digest across *hosts* are serialized by the O_EXCL lock.

    **Bounded** when constructed with ``max_bytes`` and/or ``max_age_s``:
    a fleet store accretes one entry per (graph, budget/sweep) signature
    forever — re-profiling, functional bumps and model churn all mint new
    digests and orphan the old ones.  :meth:`gc` sweeps the object tree:
    entries older than ``max_age_s`` go first, then oldest-first until the
    tree fits ``max_bytes``.  Deletions take each entry's O_EXCL ``.lock``
    (``_locked_unlink``), so a sweep never races an in-flight writer, and
    any entry it does remove is merely re-solvable — content addressing
    means eviction can never serve a *wrong* plan, only cost a re-solve.
    Every ``gc_every``-th push triggers an opportunistic sweep so
    long-running pushers keep the store bounded without a cron job.
    """

    scheme = "file"

    def __init__(self, root: str, max_bytes: Optional[int] = None,
                 max_age_s: Optional[float] = None, gc_every: int = 64):
        self.root = root
        self.max_bytes = max_bytes
        self.max_age_s = max_age_s
        self.gc_every = max(int(gc_every), 1)
        self._pushes = 0

    def _path(self, content_hash: str) -> str:
        return os.path.join(
            self.root, "plans", content_hash[:2], content_hash + ".json"
        )

    def fetch(self, content_hash: str) -> Optional[dict]:
        entry = read_json(self._path(content_hash))
        return entry if isinstance(entry, dict) else None

    def push(self, content_hash: str, entry: dict) -> None:
        _locked_write_json(self._path(content_hash), entry)
        self._pushes += 1
        if (self.max_bytes is not None or self.max_age_s is not None) \
                and self._pushes % self.gc_every == 0:
            self.gc()

    def _scan(self) -> List[Tuple[float, int, str]]:
        """All entry files as ``(mtime, size, path)``, oldest first."""
        out: List[Tuple[float, int, str]] = []
        plans = os.path.join(self.root, "plans")
        try:
            shards = sorted(os.scandir(plans), key=lambda d: d.name)
        except OSError:
            return out
        for shard in shards:
            if not shard.is_dir():
                continue
            try:
                files = os.scandir(shard.path)
            except OSError:
                continue
            for f in files:
                if not f.name.endswith(".json"):
                    continue  # .lock sidecars and foreign files
                try:
                    st = f.stat()
                except OSError:
                    continue  # deleted under us by a concurrent sweep
                out.append((st.st_mtime, st.st_size, f.path))
        out.sort()
        return out

    def gc(self, now: Optional[float] = None) -> Dict[str, int]:
        """One sweep; returns ``{scanned, removed, bytes, bytes_freed}``.

        Age rule first (anything older than ``max_age_s``), then the size
        rule (evict oldest-first until the surviving tree is ≤
        ``max_bytes``).  Entries whose lock is held by a live writer are
        skipped — they are being refreshed, not garbage.
        """
        entries = self._scan()
        total = sum(size for _, size, _ in entries)
        scanned = len(entries)
        removed = 0
        freed = 0
        t0 = time.time() if now is None else now
        survivors: List[Tuple[float, int, str]] = []
        for mtime, size, path in entries:
            if self.max_age_s is not None and t0 - mtime > self.max_age_s:
                if _locked_unlink(path):
                    removed += 1
                    freed += size
                    continue
            survivors.append((mtime, size, path))
        if self.max_bytes is not None:
            live = total - freed
            for mtime, size, path in survivors:  # oldest first
                if live <= self.max_bytes:
                    break
                if _locked_unlink(path):
                    removed += 1
                    freed += size
                    live -= size
        return {"scanned": scanned, "removed": removed,
                "bytes": total - freed, "bytes_freed": freed}


class CallableStore(RemoteStore):
    """User-supplied transport as two callables — no subclassing needed.

    ``fetch(content_hash) -> Optional[dict]`` and
    ``push(content_hash, entry: dict) -> None`` over any blob client
    (boto3, google-cloud-storage, an internal KV service…).  The adapter
    normalizes non-dict fetch results to ``None`` (a miss) so a sloppy
    transport can't feed the decoder garbage; transport exceptions follow
    the :class:`RemoteStore` contract (raise ``OSError`` to be counted and
    degraded to a miss).
    """

    def __init__(
        self,
        fetch: Callable[[str], Optional[dict]],
        push: Callable[[str, dict], None],
        scheme: str = "callable",
    ):
        self._fetch = fetch
        self._push = push
        self.scheme = scheme

    def fetch(self, content_hash: str) -> Optional[dict]:
        entry = self._fetch(content_hash)
        return entry if isinstance(entry, dict) else None

    def push(self, content_hash: str, entry: dict) -> None:
        self._push(content_hash, entry)


class _ObjectStoreStub(RemoteStore):
    """Placeholder for unregistered bucket transports (s3:// / gs://):
    constructing one names the URL it would serve; using it raises with a
    pointer to :func:`register_transport`.  Kept importable so launcher
    configs can carry bucket URLs before the blob client is wired up."""

    def __init__(self, scheme: str, url: str):
        self.scheme = scheme
        self.url = url

    def _unimplemented(self) -> "NotImplementedError":
        return NotImplementedError(
            f"no transport registered for {self.scheme}:// plan stores: "
            f"register_transport({self.scheme!r}, factory) with a factory "
            f"returning a RemoteStore/CallableStore over your object-store "
            f"client (url: {self.url!r})"
        )

    def fetch(self, content_hash: str) -> Optional[dict]:
        raise self._unimplemented()

    def push(self, content_hash: str, entry: dict) -> None:
        raise self._unimplemented()


#: URL-scheme → factory taking the full URL and returning the transport.
_TRANSPORTS: Dict[str, Callable[[str], RemoteStore]] = {}


def register_transport(
    scheme: str, factory: Callable[[str], RemoteStore]
) -> None:
    """Register (or replace) the transport factory for a URL scheme.

    Lets deployments route ``s3://`` / ``gs://`` (or any custom scheme) plan
    stores through their own client without forking this module::

        register_transport("s3", lambda url: CallableStore(
            fetch=lambda h: my_get_json(url, h),
            push=lambda h, e: my_put_json(url, h, e),
            scheme="s3"))

    Every URL-configured entry point then resolves through it —
    ``PlanCache(remote="s3://bucket/plans")``, ``set_default_remote_store``,
    the ``REPRO_PLAN_REMOTE_DIR`` env var, the serving engine's
    ``plan_remote=``.  Registering ``"file"`` overrides the built-in
    :class:`SharedFSStore` resolution (e.g. to attach GC bounds).
    """
    _TRANSPORTS[scheme] = factory


def remote_store_from_url(url: str) -> RemoteStore:
    """``/dir``, ``file:///dir`` → :class:`SharedFSStore`; a registered
    scheme (``register_transport``) → its factory; unregistered ``s3://`` /
    ``gs://`` → the object-store stub (raises on first use)."""
    if "://" not in url:
        return SharedFSStore(url)
    scheme, _, rest = url.partition("://")
    if scheme in _TRANSPORTS:
        return _TRANSPORTS[scheme](url)
    if scheme == "file":
        return SharedFSStore("/" + rest.lstrip("/") if rest else "/")
    if scheme in ("s3", "gs"):
        return _ObjectStoreStub(scheme, url)
    raise ValueError(f"unknown plan-store scheme {scheme!r} in {url!r}")


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of one planning problem.

    ``family`` names the lower-set family ("exact_dp" / "approx_dp" /
    "segment" / a digest of a custom family); ``budget`` is kept in full
    float precision via ``repr`` so distinct budgets never alias.
    """

    graph_digest: str
    budget: float
    family: str
    objective: str
    #: Strategy-lattice token (``StrategyConfig.digest_token()``): the
    #: enabled strategy set + bandwidths.  Empty for the paper's binary —
    #: and *omitted* from the payload then, so every pre-lattice digest is
    #: byte-identical to what it always was.
    strategy: str = ""

    def content_hash(self) -> str:
        parts = [
            f"v{FORMAT_VERSION}",
            MEMORY_FUNCTIONAL,
            self.graph_digest,
            repr(float(self.budget)),
            self.family,
            self.objective,
        ]
        if self.strategy:
            parts.append(self.strategy)
        return hashlib.sha256("|".join(parts).encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class SweepKey:
    """Identity of one budget-*free* planning problem (``core.dp.sweep``).

    Deliberately has no budget: one cached sweep answers every budget query
    on its ``(graph, family, objective)`` by frontier lookup, which is what
    turns the §5.1 binary search and multi-budget trade-off grids into
    cache hits after a single cold solve.
    """

    graph_digest: str
    family: str
    objective: str
    strategy: str = ""  # StrategyConfig.digest_token(); "" keeps legacy bytes

    def content_hash(self) -> str:
        parts = [f"sweep-v{FORMAT_VERSION}", MEMORY_FUNCTIONAL,
                 self.graph_digest, self.family, self.objective]
        if self.strategy:
            parts.append(self.strategy)
        return hashlib.sha256("|".join(parts).encode()).hexdigest()


def _to_canonical(seq: Sequence[NodeSet], to_pos: Dict[int, int]) -> List[List[int]]:
    return [sorted(to_pos[v] for v in L) for L in seq]


def _from_canonical(seq: List[List[int]], from_pos: List[int]) -> List[NodeSet]:
    return [frozenset(from_pos[p] for p in L) for L in seq]


class PlanCache:
    """In-memory LRU over an optional on-disk content-addressed store, over
    an optional fleet-shared :class:`RemoteStore` (read-through + push-
    through).  ``last_tier`` records which tier served the most recent hit
    (``"memory"`` / ``"disk"`` / ``"remote"``; ``None`` after a miss) — the
    user-visible provenance ``examples/plan_explorer.py`` prints."""

    def __init__(self, capacity: int = 512, cache_dir: Optional[str] = None,
                 remote: Optional[Union[RemoteStore, str]] = None):
        self.capacity = capacity
        self.cache_dir = cache_dir
        self.remote = (
            remote_store_from_url(remote) if isinstance(remote, str) else remote
        )
        self._mem: "OrderedDict[str, dict]" = OrderedDict()
        # Decoded-plan LRU: repeat hits skip JSON decode + re-validation
        # (rebuilding a 100k-element lower-set sequence costs ~10 ms on the
        # big nets — too slow for a serving hot path).  Keyed by the entry
        # hash AND the querying graph's relabeling (canonical order), so
        # isomorphic graphs with different node ids never share a decode.
        self._decoded: "OrderedDict[Tuple[str, Tuple[int, ...]], DPResult]" = (
            OrderedDict()
        )
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.remote_hits = 0
        self.invalid_hits = 0  # validation failures (collision/corruption)
        self.disk_errors = 0  # unusable store (permissions, bad path, ENOSPC)
        self.remote_errors = 0  # unusable transport (degrades to a miss)
        self.last_tier: Optional[str] = None

    # ------------------------------------------------------------------ keys

    @staticmethod
    def key_for(
        g: Graph, budget: float, family: str, objective: str,
        strategy: str = "",
    ) -> PlanKey:
        return PlanKey(
            graph_digest(g), float(budget), family, objective, strategy
        )

    # ------------------------------------------------------------------ disk

    def _path(self, content_hash: str) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(
            self.cache_dir, "plans", content_hash[:2], content_hash + ".json"
        )

    def _disk_write(self, content_hash: str, entry: dict) -> None:
        """Best-effort disk write: an unusable store (read-only mount, path
        collision, ENOSPC) must degrade the cache to memory-only, never take
        the planning job down.  Locked (O_EXCL sidecar): the disk tier may be
        a directory shared by co-located processes racing the same digest —
        the loser skips, since content addressing makes both writes
        byte-identical anyway."""
        path = self._path(content_hash)
        if path is None:
            return
        try:
            _locked_write_json(path, entry)
        except OSError:
            self.disk_errors += 1

    # ----------------------------------------------------------------- remote

    def _remote_fetch(self, content_hash: str) -> Optional[dict]:
        """Read-through fetch; transport failures degrade to a miss."""
        if self.remote is None:
            return None
        try:
            entry = self.remote.fetch(content_hash)
        except (OSError, NotImplementedError):
            self.remote_errors += 1
            return None
        return entry if isinstance(entry, dict) else None

    def _remote_push(self, content_hash: str, entry: dict) -> None:
        """Best-effort push-through — a broken transport never fails a put."""
        if self.remote is None:
            return
        try:
            self.remote.push(content_hash, entry)
        except (OSError, NotImplementedError):
            self.remote_errors += 1

    def _lookup(self, content_hash: str) -> Tuple[Optional[dict], str]:
        """memory → disk → remote; returns ``(entry, tier)`` (entry None on
        a full miss).  Tier *accounting* and local back-fill happen in the
        callers, after the entry validates against the querying graph."""
        entry = self._mem_get(content_hash)
        if entry is not None:
            return entry, "memory"
        path = self._path(content_hash)
        if path is not None:
            entry = read_json(path)
            if entry is not None:
                return entry, "disk"
        entry = self._remote_fetch(content_hash)
        if entry is not None:
            return entry, "remote"
        return None, "miss"

    def _record_hit(self, content_hash: str, entry: dict, tier: str) -> None:
        """Validated hit: count it, back-fill the faster tiers, stamp
        ``last_tier``."""
        if tier == "disk":
            self.disk_hits += 1
            self._mem_put(content_hash, entry)
        elif tier == "remote":
            self.remote_hits += 1
            self._mem_put(content_hash, entry)
            self._disk_write(content_hash, entry)
        self.hits += 1
        self.last_tier = tier

    # ------------------------------------------------------------------- LRU

    def _mem_get(self, h: str) -> Optional[dict]:
        with self._lock:
            entry = self._mem.get(h)
            if entry is not None:
                self._mem.move_to_end(h)
            return entry

    def _mem_put(self, h: str, entry: dict) -> None:
        with self._lock:
            self._mem[h] = entry
            self._mem.move_to_end(h)
            while len(self._mem) > self.capacity:
                self._mem.popitem(last=False)

    # ------------------------------------------------------------------- API

    def _decoded_get(self, dk: "Tuple[str, Tuple[int, ...]]") -> Optional[DPResult]:
        with self._lock:
            res = self._decoded.get(dk)
            if res is not None:
                self._decoded.move_to_end(dk)
        if res is None:
            return None
        # fresh sequence list / assignment dict: callers may mutate them
        return dataclasses.replace(
            res, sequence=list(res.sequence),
            assignment=dict(res.assignment) if res.assignment is not None else None,
        )

    def _decoded_put(self, dk: "Tuple[str, Tuple[int, ...]]", res: DPResult) -> None:
        with self._lock:
            self._decoded[dk] = dataclasses.replace(
                res, sequence=list(res.sequence)
            )
            self._decoded.move_to_end(dk)
            while len(self._decoded) > self.capacity:
                self._decoded.popitem(last=False)

    def get(self, g: Graph, key: PlanKey) -> Optional[DPResult]:
        """Cached DPResult for ``key``, re-labeled onto ``g``; None on miss.

        Hits are validated against ``g`` (increasing lower-set sequence); an
        entry that fails validation is treated as a miss and evicted.
        Repeat hits for the same relabeling are served from the decoded LRU
        at memory-lookup latency (they validated when first decoded).
        """
        h = key.content_hash()
        _, from_pos = canonical_maps(g)
        dk = (h, tuple(from_pos))
        cached = self._decoded_get(dk)
        if cached is not None:
            self.hits += 1
            self.last_tier = "memory"
            return cached
        entry, tier = self._lookup(h)
        if entry is None:
            self.misses += 1
            self.last_tier = None
            return None

        result = self._decode(g, entry)
        if result is None:
            self.invalid_hits += 1
            self.misses += 1
            self.last_tier = None
            with self._lock:
                self._mem.pop(h, None)
            return None
        self._record_hit(h, entry, tier)
        self._decoded_put(dk, result)
        return result

    def put(self, g: Graph, key: PlanKey, result: DPResult) -> None:
        to_pos, _ = canonical_maps(g)
        entry = {
            "version": FORMAT_VERSION,
            "key": dataclasses.asdict(key),
            "feasible": bool(result.feasible),
            "sequence": _to_canonical(result.sequence, to_pos),
            "overhead": result.overhead,
            "peak_memory": result.peak_memory,
            "states_visited": int(result.states_visited),
        }
        if result.assignment is not None:
            # canonical node positions, like the sequence; the field is
            # omitted for binary plans, keeping legacy entries byte-identical
            entry["assignment"] = {
                str(to_pos[v]): code for v, code in result.assignment.items()
            }
        h = key.content_hash()
        self._mem_put(h, entry)
        self._decoded_put((h, tuple(canonical_maps(g)[1])), result)
        self._disk_write(h, entry)
        self._remote_push(h, entry)

    def _decode(self, g: Graph, entry: dict) -> Optional[DPResult]:
        try:
            # a foreign/corrupt store file can be any JSON value, not a dict
            if not isinstance(entry, dict) or entry.get("version") != FORMAT_VERSION:
                return None
            if not entry["feasible"]:
                return DPResult(
                    sequence=[],
                    overhead=float("inf"),
                    peak_memory=float("inf"),
                    feasible=False,
                    states_visited=int(entry.get("states_visited", 0)),
                )
            _, from_pos = canonical_maps(g)
            seq = _from_canonical(entry["sequence"], from_pos)
            g.check_increasing_sequence(seq)
            assignment = None
            if "assignment" in entry:
                assignment = {
                    from_pos[int(p)]: str(code)
                    for p, code in entry["assignment"].items()
                }
            return DPResult(
                sequence=seq,
                overhead=float(entry["overhead"]),
                peak_memory=float(entry["peak_memory"]),
                feasible=True,
                states_visited=int(entry.get("states_visited", 0)),
                assignment=assignment,
            )
        except (KeyError, IndexError, TypeError, ValueError):
            return None

    # ------------------------------------------------------------- sweeps

    @staticmethod
    def sweep_key_for(
        g: Graph, family: str, objective: str, strategy: str = ""
    ) -> SweepKey:
        return SweepKey(graph_digest(g), family, objective, strategy)

    def get_sweep(self, key: SweepKey, count_miss: bool = True) -> Optional[Sweep]:
        """Cached sweep in **canonical coordinates**; None on miss.

        Unlike plan entries there is no per-get structural validation
        against a querying graph — a sweep is not a single plan but a whole
        surface.  Callers (``core.planner.Planner``) validate each
        *extracted* sequence instead, so corruption still degrades to a
        miss at the point of use, never a wrong plan.

        ``count_miss=False`` keeps an absent sweep out of the miss stats —
        for opportunistic probes whose fallback lookup (a ``plan`` entry)
        does its own accounting.
        """
        h = key.content_hash()
        entry, tier = self._lookup(h)
        if entry is None:
            if count_miss:
                self.misses += 1
                self.last_tier = None
            return None
        sweep = None
        if isinstance(entry, dict) and entry.get("version") == FORMAT_VERSION \
                and entry.get("kind") == "sweep":
            sweep = decode_sweep(entry)
        if sweep is None:
            self.invalid_hits += 1
            self.misses += 1
            self.last_tier = None
            with self._lock:
                self._mem.pop(h, None)
            return None
        self._record_hit(h, entry, tier)
        return sweep

    def put_sweep(self, key: SweepKey, sweep: Sweep) -> None:
        """Store a sweep (caller must pass it in canonical coordinates)."""
        entry = {"version": FORMAT_VERSION, "kind": "sweep",
                 "key": dataclasses.asdict(key), **sweep.encode()}
        h = key.content_hash()
        self._mem_put(h, entry)
        self._disk_write(h, entry)
        self._remote_push(h, entry)

    # ------------------------------------------------- auxiliary scalar store

    def get_aux(self, namespace: str, key: str) -> Optional[float]:
        """Small keyed scalar store (e.g. min-feasible-budget results)."""
        h = hashlib.sha256(f"aux|{namespace}|{key}".encode()).hexdigest()
        entry, tier = self._lookup(h)
        if not isinstance(entry, dict) or "value" not in entry:
            return None
        if entry.get("version") != FORMAT_VERSION:
            return None  # e.g. a min-budget computed under an old functional
        try:
            value = float(entry["value"])
        except (TypeError, ValueError):
            return None
        if tier != "memory":
            self._mem_put(h, entry)
            if tier == "remote":
                self._disk_write(h, entry)
        return value

    def put_aux(self, namespace: str, key: str, value: float) -> None:
        h = hashlib.sha256(f"aux|{namespace}|{key}".encode()).hexdigest()
        entry = {"version": FORMAT_VERSION, "value": float(value)}
        self._mem_put(h, entry)
        self._disk_write(h, entry)
        self._remote_push(h, entry)

    # ----------------------------------------------------------------- stats

    def stats(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "disk_hits": self.disk_hits,
            "remote_hits": self.remote_hits,
            "invalid_hits": self.invalid_hits,
            "disk_errors": self.disk_errors,
            "remote_errors": self.remote_errors,
            "entries_in_memory": len(self._mem),
        }

    def clear_memory(self) -> None:
        with self._lock:
            self._mem.clear()
            self._decoded.clear()


# ---------------------------------------------------------------------------
# Process-wide default cache (planner front door).
# ---------------------------------------------------------------------------

_DEFAULT = PlanCache()


def default_cache() -> PlanCache:
    return _DEFAULT


def set_default_cache_dir(path: Optional[str]) -> PlanCache:
    """Attach (or detach, with None) the disk tier of the default cache.

    Called by the train loop / serving engine when configured with a
    ``plan_cache_dir``; also respects the ``REPRO_PLAN_CACHE_DIR`` env var
    via ``cache_dir_from_env``.

    The store is deliberately **process-global** (co-located jobs share one
    content-addressed store; entries are keyed by content, so sharing is
    always safe).  Repointing an already-attached store to a *different*
    directory is almost certainly a configuration mistake — two components
    were configured with conflicting dirs — so it warns.
    """
    if (
        path is not None
        and _DEFAULT.cache_dir is not None
        and os.path.abspath(path) != os.path.abspath(_DEFAULT.cache_dir)
    ):
        import warnings

        warnings.warn(
            f"plan cache dir repointed {_DEFAULT.cache_dir!r} -> {path!r}; "
            "the store is process-global and shared by every planner",
            stacklevel=2,
        )
    _DEFAULT.cache_dir = path
    return _DEFAULT


def set_default_remote_store(
    store: Optional[Union[RemoteStore, str]]
) -> PlanCache:
    """Attach (or detach, with None) the fleet tier of the default cache.

    Accepts a :class:`RemoteStore` instance or a URL for
    :func:`remote_store_from_url`.  Like the disk tier, the remote is
    process-global: the serving engine, the launchers and ad-hoc planning
    all read through (and push to) the same fleet store.
    """
    _DEFAULT.remote = (
        remote_store_from_url(store) if isinstance(store, str) else store
    )
    return _DEFAULT


def cache_dir_from_env() -> Optional[str]:
    return os.environ.get("REPRO_PLAN_CACHE_DIR") or None


def remote_from_env() -> Optional[str]:
    return os.environ.get("REPRO_PLAN_REMOTE_DIR") or None


# Pick up the env vars at import so every entry point (benchmarks, examples,
# launchers) shares the store without plumbing.
if cache_dir_from_env():
    set_default_cache_dir(cache_dir_from_env())
if remote_from_env():
    set_default_remote_store(remote_from_env())
