"""``plan_lint`` — run the static checkers over networks and traced functions.

    python -m repro.analysis --network unet
    python -m repro.analysis --network unet --budget 2e9
    python -m repro.analysis --smoke --json lint_report.json

``--network`` lints one of the paper's seven benchmark graphs: plan at the
given budget (default: the exact minimal feasible one) and run the plan
verifier.  ``--traced module:factory`` (or the built-in ``quickstart``)
lints a real JAX function end to end: effect analysis → pinned planning →
plan verification → lowering conformance.  ``--smoke`` runs every
benchmark network plus the quickstart traced function — the CI gate.

Exit codes: 0 all clean, 1 lint errors, 2 infeasible budget (the exact
minimal feasible budget is printed — re-run with at least that).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .report import Report

EXIT_OK, EXIT_LINT, EXIT_INFEASIBLE = 0, 1, 2


def _quickstart_factory() -> Tuple[Callable[..., Any], Tuple[Any, ...]]:
    """The README's quickstart MLP — the traced smoke target."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    dn = (((1,), (0,)), ((), ()))

    def mlp_loss(params: Any, x: Any) -> Any:
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, dn))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
        for i in range(6)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    return mlp_loss, (params, x)


def _resolve_traced(spec: str) -> Tuple[Callable[..., Any], Tuple[Any, ...]]:
    if spec == "quickstart":
        return _quickstart_factory()
    import importlib

    mod_name, _, attr = spec.partition(":")
    if not attr:
        raise SystemExit(
            f"--traced wants 'module:factory' or 'quickstart', got {spec!r}"
        )
    return getattr(importlib.import_module(mod_name), attr)()


def lint_graph(
    g: Any,
    name: str,
    budget: Optional[float],
    method: str,
) -> Tuple[List[Report], bool]:
    """Plan ``g`` and verify; returns (reports, infeasible)."""
    from ..core.planner import get_default_planner

    planner = get_default_planner()
    rep = planner.plan(g, budget, method=method)
    if rep.plan is None:
        needed = planner.min_feasible_budget(g, method)
        r = Report(checker="plan")
        r.add(
            "error",
            "infeasible-budget",
            f"{name}: no feasible strategy under budget {budget:g}; the "
            f"exact minimal feasible budget is {needed:g}",
        )
        return [r], True
    from .verifier import check_plan

    return [check_plan(g, rep.plan, budget=budget)], False


def lint_traced(
    fn: Callable[..., Any],
    args: Sequence[Any],
    budget: Optional[float],
    method: str,
) -> Tuple[List[Report], bool]:
    """Full three-checker lint of a traced function."""
    from ..core.lowering.carriers import TracedCarrier
    from ..core.planner import get_default_planner
    from .conformance import check_lowering
    from .verifier import check_plan

    carrier = TracedCarrier.trace(fn, tuple(args), analyze_effects=True)
    ea = carrier.effects
    g = carrier.to_graph()
    planner = get_default_planner()
    rep = planner.plan(g, budget, method=method)
    if rep.plan is None:
        needed = planner.min_feasible_budget(g, method)
        r = Report(checker="plan")
        r.add(
            "error",
            "infeasible-budget",
            f"no feasible strategy under budget {budget:g}; the exact "
            f"minimal feasible budget is {needed:g}",
        )
        return [ea.report, r], True
    return [
        ea.report,
        check_plan(g, rep.plan, budget=budget, effects=ea, jg=carrier.jg),
        check_lowering(carrier, rep.plan),
    ], False


def _run_target(
    name: str,
    run: Callable[[], Tuple[List[Report], bool]],
    results: List[Dict[str, Any]],
) -> Tuple[bool, bool]:
    """Execute one lint target; returns (had_errors, infeasible)."""
    t0 = time.perf_counter()
    reports, infeasible = run()
    dt = time.perf_counter() - t0
    ok = all(r.ok for r in reports)
    n_warn = sum(len(r.warnings()) for r in reports)
    print(f"{name:>16s}  {'OK' if ok else 'FAIL'}  "
          f"({len(reports)} checker(s), {n_warn} warning(s), {dt:.2f}s)")
    for r in reports:
        for f in r.findings:
            if f.severity != "info":
                where = f" @node {f.node}" if f.node is not None else ""
                print(f"    {f.severity}: [{r.checker}] {f.code}{where}: "
                      f"{f.message}")
    results.append({
        "target": name,
        "ok": ok,
        "seconds": dt,
        "reports": [r.to_dict() for r in reports],
    })
    return (not ok), infeasible


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="plan_lint: static soundness checks over plans",
    )
    ap.add_argument("--network", default=None,
                    help="one benchmark network (benchmarks.networks)")
    ap.add_argument("--traced", default=None,
                    help="'quickstart' or 'module:factory' returning "
                         "(fn, example_args)")
    ap.add_argument("--smoke", action="store_true",
                    help="lint every benchmark network plus the quickstart "
                         "traced function")
    ap.add_argument("--budget", type=float, default=None,
                    help="byte budget (default: exact minimal feasible)")
    ap.add_argument("--method", default="approx_dp",
                    choices=("approx_dp", "exact_dp"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the merged findings as a JSON artifact")
    args = ap.parse_args(argv)

    if not (args.network or args.traced or args.smoke):
        ap.error("pick one of --network / --traced / --smoke")

    targets: List[Tuple[str, Callable[[], Tuple[List[Report], bool]]]] = []
    if args.network or args.smoke:
        try:
            from benchmarks.networks import NETWORKS
        except ImportError as e:
            raise SystemExit(
                "benchmarks.networks not importable — run from the repo "
                f"root with PYTHONPATH=src:. ({e})"
            ) from e
        names = [args.network] if args.network else sorted(NETWORKS)
        for name in names:
            if name not in NETWORKS:
                raise SystemExit(
                    f"unknown network {name!r}; pick from {sorted(NETWORKS)}"
                )
            targets.append((
                name,
                lambda name=name: lint_graph(
                    NETWORKS[name](), name, args.budget, args.method
                ),
            ))
    if args.traced or args.smoke:
        spec = args.traced or "quickstart"
        fn, ex_args = _resolve_traced(spec)
        targets.append((
            spec,
            lambda: lint_traced(fn, ex_args, args.budget, args.method),
        ))

    results: List[Dict[str, Any]] = []
    any_errors = False
    any_infeasible = False
    for name, run in targets:
        had_errors, infeasible = _run_target(name, run, results)
        any_errors |= had_errors
        any_infeasible |= infeasible

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"ok": not any_errors, "targets": results}, fh,
                      indent=2)
        print(f"report written to {args.json}")

    if any_infeasible:
        return EXIT_INFEASIBLE
    return EXIT_LINT if any_errors else EXIT_OK


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
