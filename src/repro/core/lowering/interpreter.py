"""Paper-faithful interpreter backend (§3) for both graph carriers.

Interprets the canonical strategy step by step — forward caching only the
boundary values ∂(L_i), backward recomputing each V_i from the caches — so
tests can assert that a strategy's gradients match vanilla backpropagation,
and so the per-step live set can be audited against ``core.liveness`` and
the plan's analytic peak (the liveness-tight functional,
``dp.peak_memory_live``; the audit counts forward intermediates only, a
strict subset of the f+g buffers the functional charges, so measured live
bytes ≤ ``plan.peak_memory`` holds per segment window).

Two granularities, one semantics:

* ``planned_value_and_grad`` — block granularity over a ``BlockGraph``
  (the seed repo's ``core.executor``, moved here verbatim);
* ``traced_planned_value_and_grad`` — equation granularity over a traced
  JAX function (``TracedCarrier``): each segment is recomputed from the
  cached boundary values and pulled back through one ``jax.vjp``.

``track_live=True`` appends a ``[(tag, live_bytes), ...]`` trace counting
the *intermediate forward values* held at each step (function inputs and
parameters are excluded, as in §2), which the tests assert stays within
the plan's ``peak_memory``.

Strategy plans (``plan.strategy``): the traced path boxes each cached
value per its storage strategy — offloaded residuals are host-placed and
audit at **0 device bytes**, quantized ones hold the int8 payload + block
scales and audit at the compressed size — so the live-byte trace charges
exactly what the joint DP priced.  The block-granularity path rejects
strategy plans (use ``backend="segment"`` for BlockGraphs).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..schedule import ExecutionPlan
from .base import Lowering, register_lowering
from .carriers import BlockGraphCarrier, TracedCarrier, is_drop_var as _is_drop


def _nbytes(x) -> int:
    return int(x.size * x.dtype.itemsize) if hasattr(x, "dtype") else 0


# ---------------------------------------------------------------------------
# Storage-strategy boxes (joint memory-strategy plans, traced path)
# ---------------------------------------------------------------------------


class _QuantizedBox:
    """A cached residual held as int8 payload + per-block scales."""

    __slots__ = ("c", "dtype")

    def __init__(self, c, dtype):
        self.c = c
        self.dtype = dtype

    def device_bytes(self) -> int:
        return _nbytes(self.c.q) + _nbytes(self.c.scale)


class _HostBox:
    """A cached residual placed in host memory (zero device bytes)."""

    __slots__ = ("x",)

    def __init__(self, x):
        self.x = x

    def device_bytes(self) -> int:
        return 0


def _box(val, code):
    """Box one cached array per its storage strategy (raw for ``store``)."""
    if code == "quantize" and hasattr(val, "dtype") and jnp.issubdtype(
        val.dtype, jnp.inexact
    ):
        from repro.optim.compression import compress

        return _QuantizedBox(compress(val), val.dtype)
    if code == "offload":
        from .segment import _memory_kind_put

        return _HostBox(_memory_kind_put(val, "pinned_host"))
    return val


def _unbox(val):
    if isinstance(val, _QuantizedBox):
        from repro.optim.compression import decompress

        return decompress(val.c).astype(val.dtype)
    if isinstance(val, _HostBox):
        from .segment import _memory_kind_put

        return _memory_kind_put(val.x, "device")
    return val


def _stored_nbytes(val) -> int:
    if isinstance(val, (_QuantizedBox, _HostBox)):
        return val.device_bytes()
    return _nbytes(val)


# ---------------------------------------------------------------------------
# Block granularity (BlockGraph)
# ---------------------------------------------------------------------------


def planned_value_and_grad(
    bg,
    plan: ExecutionPlan,
    loss_fn: Callable[..., jax.Array],
    track_live: bool = False,
):
    """Return f(params, inputs) -> (loss, grads_params[, live_trace]).

    loss_fn consumes the BlockGraph outputs and returns a scalar.
    Gradients are produced by interpreting the canonical strategy:

      forward : run segments in order; after segment i discard every value of
                V_i not in U_k (the union of boundaries).
      backward: for i = k…1, recompute the discarded values of V_i from the
                caches, then run per-block VJPs in reverse topological order.
    """
    name_of = {i: b.name for i, b in enumerate(bg.blocks)}

    def run(params: Dict[str, Any], inputs: Dict[str, Any]):
        live_trace: List[Tuple[str, int]] = []
        cached_names = {name_of[v] for v in plan.cached}

        def snapshot(tag: str, store: Dict[str, Any]) -> None:
            # graph inputs are excluded from the accounting, as in §2 (the
            # paper's budget covers intermediate values only)
            if track_live:
                nbytes = sum(
                    sum(leaf.size * leaf.dtype.itemsize
                        for leaf in jax.tree_util.tree_leaves(v))
                    for name, v in store.items()
                    if name not in inputs
                )
                live_trace.append((tag, int(nbytes)))

        # ---------------- forward ----------------
        cache: Dict[str, Any] = dict(inputs)
        for seg in plan.segments:
            local: Dict[str, Any] = {}
            for v in seg.nodes:
                b = bg.by_name[name_of[v]]
                args = [
                    local[i] if i in local else cache[i] for i in b.inputs
                ]
                local[b.name] = b.apply(params[b.name], *args)
            # canonical rule: keep only boundary values (and model outputs)
            for name, val in local.items():
                if name in cached_names or name in bg.outputs:
                    cache[name] = val
            snapshot(f"fwd_seg{seg.index}", cache)

        outs = tuple(cache[o] for o in bg.outputs)
        loss, loss_vjp = jax.vjp(
            lambda *o: loss_fn(*o) if len(o) > 1 else loss_fn(o[0]), *outs
        )
        out_grads = loss_vjp(jnp.ones_like(loss))

        # ---------------- backward ----------------
        grad_of: Dict[str, Any] = {}
        for o, g in zip(bg.outputs, out_grads):
            grad_of[o] = g
        param_grads: Dict[str, Any] = {}

        for seg in reversed(plan.segments):
            # recompute discarded values of V_i from live caches
            local: Dict[str, Any] = {}
            for v in seg.nodes:
                b = bg.by_name[name_of[v]]
                if b.name in cache:
                    local[b.name] = cache[b.name]
                    continue
                args = [local[i] if i in local else cache[i] for i in b.inputs]
                local[b.name] = b.apply(params[b.name], *args)
            snapshot(f"bwd_recompute_seg{seg.index}", {**cache, **local})

            # VJP sweep, reverse topological order within the segment
            for v in reversed(seg.nodes):
                b = bg.by_name[name_of[v]]
                g_out = grad_of.pop(b.name, None)
                if g_out is None:
                    continue  # value unused by the loss
                args = [local[i] if i in local else cache[i] for i in b.inputs]
                _out, vjp = jax.vjp(b.apply, params[b.name], *args)
                pulls = vjp(g_out)
                g_param, g_args = pulls[0], pulls[1:]
                param_grads[b.name] = (
                    jax.tree_util.tree_map(jnp.add, param_grads[b.name], g_param)
                    if b.name in param_grads
                    else g_param
                )
                for i_name, g_arg in zip(b.inputs, g_args):
                    if i_name in inputs:
                        continue  # no grads w.r.t. graph inputs requested
                    grad_of[i_name] = (
                        grad_of[i_name] + g_arg if i_name in grad_of else g_arg
                    )
            # discard this segment's forward values (canonical rule); its
            # cached boundary values are no longer needed either once the
            # earlier-segment gradients that flow *through* them are queued.
            for v in seg.nodes:
                cache.pop(name_of[v], None)
            snapshot(f"bwd_done_seg{seg.index}", cache)

        # blocks with no params still get an empty-grads entry for tree-match
        for b in bg.blocks:
            if b.name not in param_grads:
                param_grads[b.name] = jax.tree_util.tree_map(
                    jnp.zeros_like, params[b.name]
                )
        if track_live:
            return loss, param_grads, live_trace
        return loss, param_grads

    return run


def vanilla_value_and_grad(
    bg, loss_fn: Callable[..., jax.Array]
):
    """Reference: jax.value_and_grad over the vanilla executor."""

    def f(params, inputs):
        out = bg.apply(params, inputs)
        return loss_fn(*out) if isinstance(out, tuple) else loss_fn(out)

    return jax.value_and_grad(f)


# ---------------------------------------------------------------------------
# Equation granularity (traced JAX functions)
# ---------------------------------------------------------------------------


def _eval_eqn(eqn, invals):
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    ans = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    return list(ans) if eqn.primitive.multiple_results else [ans]


def _is_inexact_var(v) -> bool:
    aval = getattr(v, "aval", None)
    return aval is not None and jnp.issubdtype(
        getattr(aval, "dtype", jnp.float32), jnp.inexact
    )


def traced_planned_value_and_grad(
    carrier: TracedCarrier,
    plan: ExecutionPlan,
    track_live: bool = False,
):
    """Interpret the canonical strategy over a traced function's jaxpr.

    Returns ``f(*args) -> (value, grads[, live_trace])`` with grads w.r.t.
    ``carrier.argnums``, matching ``jax.value_and_grad(fn, argnums)``.

    Forward: evaluate each segment's equations in order and keep only the
    values of the plan's cache set U_k (and the output).  Backward: for
    each segment in reverse, ``jax.vjp`` through the segment function —
    whose primal evaluation recomputes the discarded interior from the
    cached boundary values, exactly §3's canonical strategy.
    """
    from jax.extend import core as jcore

    closed = carrier.closed
    jaxpr = closed.jaxpr
    eqns = jaxpr.eqns
    outvar = jaxpr.outvars[0]
    cached = plan.cached
    # joint memory-strategy plans: cached residuals of quantize/offload
    # nodes are *boxed* in the env (int8+scale / host placement) and
    # decompressed / brought back on every read — forward cross-segment
    # consumers and backward recomputes both see the replay-from-storage
    # value, and the live-byte audit charges only the stored footprint
    strategy = plan.strategy or {}

    def read(v, local, env):
        if isinstance(v, jcore.Literal):
            return v.val
        return local[v] if v in local else _unbox(env[v])

    # ---- static per-segment structure -------------------------------------
    consumer_segs: Dict[Any, set] = {}  # var -> segment indices reading it
    for seg in plan.segments:
        for v_idx in seg.nodes:
            for iv in eqns[v_idx].invars:
                if not isinstance(iv, jcore.Literal):
                    consumer_segs.setdefault(iv, set()).add(seg.index)

    ext_vars: List[List[Any]] = []  # per segment: external vars it reads
    out_vars: List[List[Any]] = []  # per segment: produced vars needed later
    for seg in plan.segments:
        ext: List[Any] = []
        seen = set()
        produced = set()
        for v_idx in seg.nodes:
            for iv in eqns[v_idx].invars:
                if isinstance(iv, jcore.Literal) or iv in produced or iv in seen:
                    continue
                seen.add(iv)
                ext.append(iv)
            for ov in eqns[v_idx].outvars:
                produced.add(ov)
        outs: List[Any] = []
        for v_idx in seg.nodes:
            for ov in eqns[v_idx].outvars:
                if _is_drop(ov) or not _is_inexact_var(ov):
                    continue
                read_later = any(
                    j > seg.index for j in consumer_segs.get(ov, ())
                )
                if read_later or ov is outvar:
                    outs.append(ov)
        ext_vars.append(ext)
        out_vars.append(outs)

    def run(*args):
        flat = carrier.flatten_args(args)
        env: Dict[Any, Any] = {}
        base: set = set()
        for v, c in zip(jaxpr.constvars, closed.consts):
            env[v] = c
            base.add(v)
        for v, a in zip(jaxpr.invars, flat):
            env[v] = a
            base.add(v)

        live_trace: List[Tuple[str, int]] = []

        def snapshot(tag: str, *stores: Dict[Any, Any]) -> None:
            if not track_live:
                return
            seen_vars = set()
            nbytes = 0
            for store in stores:
                for v, val in store.items():
                    if v in base or v in seen_vars:
                        continue
                    seen_vars.add(v)
                    nbytes += _stored_nbytes(val)
            live_trace.append((tag, nbytes))

        def eval_segment(seg, env_like):
            """All values of V_i from ``env_like`` (canonical recompute)."""
            local: Dict[Any, Any] = {}
            for v_idx in seg.nodes:
                eqn = eqns[v_idx]
                invals = [read(iv, local, env_like) for iv in eqn.invars]
                for ov, o in zip(eqn.outvars, _eval_eqn(eqn, invals)):
                    if not _is_drop(ov):
                        local[ov] = o
            return local

        # ---------------- forward ----------------
        for seg in plan.segments:
            local = eval_segment(seg, env)
            for v_idx in seg.nodes:
                keep = v_idx in cached
                code = strategy.get(v_idx)
                for ov in eqns[v_idx].outvars:
                    if _is_drop(ov):
                        continue
                    if ov is outvar:
                        env[ov] = local[ov]  # the loss is never boxed
                    elif keep:
                        env[ov] = (
                            _box(local[ov], code) if code else local[ov]
                        )
            snapshot(f"fwd_seg{seg.index}", env)

        if isinstance(outvar, jcore.Literal):
            loss = outvar.val
        else:
            loss = env[outvar]

        # ---------------- backward ----------------
        ct_env: Dict[Any, Any] = {}
        if not isinstance(outvar, jcore.Literal):
            ct_env[outvar] = jnp.ones_like(loss)
        invar_set = set(jaxpr.invars)

        for seg in reversed(plan.segments):
            ext = ext_vars[seg.index]
            outs = out_vars[seg.index]
            if track_live:
                # accounting-only eager recompute: the canonical strategy's
                # backward working set is caches + this segment's interior
                snapshot(f"bwd_recompute_seg{seg.index}", env,
                         eval_segment(seg, env))
            if outs:

                def seg_fn(*ext_vals, _seg=seg, _ext=tuple(ext), _outs=tuple(outs)):
                    # primal = recompute V_i from the cached boundary values;
                    # the vjp then sums output cotangents (from later
                    # segments) with the in-segment uses, §3's VJP sweep
                    inner = eval_segment(_seg, dict(zip(_ext, ext_vals)))
                    return tuple(inner[o] for o in _outs)

                ext_vals = [_unbox(env[v]) for v in ext]
                _primals, vjp = jax.vjp(seg_fn, *ext_vals)
                cts = tuple(
                    ct_env.pop(o)
                    if o in ct_env
                    else jnp.zeros(o.aval.shape, o.aval.dtype)
                    for o in outs
                )
                ext_cts = vjp(cts)
                for v, ct in zip(ext, ext_cts):
                    if v in base and v not in invar_set:
                        continue  # constvars: no gradients requested
                    if not (
                        hasattr(ct, "dtype")
                        and jnp.issubdtype(ct.dtype, jnp.inexact)
                    ):
                        continue  # float0 cotangent of an integer value
                    ct_env[v] = ct_env[v] + ct if v in ct_env else ct
            # canonical rule: this segment's caches and cotangents are dead
            for v_idx in seg.nodes:
                for ov in eqns[v_idx].outvars:
                    env.pop(ov, None)
                    ct_env.pop(ov, None)
            snapshot(f"bwd_done_seg{seg.index}", env)

        def zeros_for(v):
            return jnp.zeros(v.aval.shape, v.aval.dtype)

        flat_cts = [
            ct_env.get(v, zeros_for(v) if _is_inexact_var(v) else None)
            for v in jaxpr.invars
        ]
        argnums = carrier.argnums
        single = isinstance(argnums, int)
        nums = (argnums,) if single else tuple(argnums)
        grads = []
        for a_idx in nums:
            lo, hi = carrier.arg_slices[a_idx]
            leaves, _ = jax.tree_util.tree_flatten(args[a_idx])
            treedef = jax.tree_util.tree_structure(args[a_idx])
            grads.append(
                jax.tree_util.tree_unflatten(treedef, flat_cts[lo:hi])
            )
        grad_out = grads[0] if single else tuple(grads)
        if track_live:
            return loss, grad_out, live_trace
        return loss, grad_out

    return run


# ---------------------------------------------------------------------------
# Registry glue
# ---------------------------------------------------------------------------


class InterpreterLowering(Lowering):
    """§3's canonical strategy, interpreted (validation / audit backend)."""

    name = "interpreter"

    def supports(self, carrier) -> bool:
        return isinstance(carrier, (BlockGraphCarrier, TracedCarrier))

    def lower(self, carrier, plan: ExecutionPlan, track_live: bool = False,
              donate: bool = False):
        if donate:
            from .base import reject_donate

            reject_donate(self.name)
        if isinstance(carrier, BlockGraphCarrier):
            if plan.strategy:
                raise NotImplementedError(
                    "the block-granularity interpreter does not realize "
                    "storage strategies; lower strategy plans over "
                    "BlockGraphs with backend='segment', or trace the "
                    "function (backend='interpreter'/'jaxpr' on a "
                    "TracedCarrier)"
                )
            return planned_value_and_grad(
                carrier.bg, plan, carrier.loss_fn, track_live=track_live
            )
        return traced_planned_value_and_grad(
            carrier, plan, track_live=track_live
        )


register_lowering(InterpreterLowering())
