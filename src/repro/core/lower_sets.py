"""Lower-set families for the DP (§4.2 exact, §4.3 pruned).

* ``all_lower_sets``     — enumerate 𝓛_G exactly (exponential in the antichain
                           width; used by the *exact* DP and the tests).
* ``pruned_lower_sets``  — 𝓛_G^Pruned = {L^v | v ∈ V} ∪ {∅, V}; ``#`` ≤ #V + 2
                           (§4.3: the approximate DP's key family).

The exact enumeration walks the lattice of lower sets as an ideal lattice of
the DAG's partial order: a lower set is uniquely determined by its maximal
elements (an antichain), and we enumerate by repeatedly adding any node whose
predecessors are all present.  To avoid duplicates we only add nodes with id
greater than the last-added "frontier" id along each DFS branch — the standard
ideal-enumeration trick.
"""

from __future__ import annotations

from typing import List, Set

from .graph import EMPTY, Graph, NodeSet

# Single source of truth for "how many lower sets is too many".  Shared by
# ``all_lower_sets`` / ``count_lower_sets``, ``dp.exact_dp``, and
# ``planner._family`` so the same graph can never plan through one entry
# point and blow past the limit through another.
DEFAULT_LOWER_SET_LIMIT = 2_000_000


def all_lower_sets(g: Graph, limit: int = DEFAULT_LOWER_SET_LIMIT) -> List[NodeSet]:
    """Enumerate every lower set of ``g`` (including ∅ and V).

    Raises ``RuntimeError`` if more than ``limit`` lower sets exist — the
    caller should fall back to the pruned family (that is the paper's whole
    point for §4.3).
    """
    n = g.n
    results: List[NodeSet] = []

    # The increasing-id DFS below enumerates each ideal exactly once *iff*
    # ids form a linear extension of the DAG (every ideal is then buildable
    # by adding its elements in increasing id order).  Node ids are arbitrary,
    # so work in topological *rank* space and map back at the end.
    topo = g.topological_order()
    rank_of = {v: r for r, v in enumerate(topo)}  # node id -> rank
    succ_r: List[List[int]] = [[] for _ in range(n)]
    indeg = [0] * n
    for v, w in g.edges:
        succ_r[rank_of[v]].append(rank_of[w])
        indeg[rank_of[w]] += 1
    init_candidates = sorted(r for r in range(n) if indeg[r] == 0)

    cur: Set[int] = set()  # ranks
    results.append(EMPTY)

    def dfs(candidates: List[int], min_rank: int, indeg_now: List[int]) -> None:
        for i, r in enumerate(candidates):
            if r < min_rank:
                continue
            # add rank r
            cur.add(r)
            results.append(frozenset(topo[x] for x in cur))
            if len(results) > limit:
                raise RuntimeError(
                    f"more than {limit} lower sets; use pruned_lower_sets"
                )
            new_cands = list(candidates[:i]) + list(candidates[i + 1 :])
            opened = []
            for w in succ_r[r]:
                indeg_now[w] -= 1
                if indeg_now[w] == 0:
                    opened.append(w)  # w > r since ranks are topological
            new_cands.extend(opened)
            dfs(new_cands, r + 1, indeg_now)
            for w in succ_r[r]:
                indeg_now[w] += 1
            cur.discard(r)

    import sys

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, n * 4 + 1000))
    try:
        dfs(init_candidates, -1, list(indeg))
    finally:
        sys.setrecursionlimit(old_limit)
    # Deduplicate (the frontier trick above makes them unique already, but a
    # frozenset pass is cheap insurance) and sort by size for the DP sweep.
    uniq = sorted(set(results), key=lambda s: (len(s), sorted(s)))
    return uniq


def pruned_lower_sets(g: Graph) -> List[NodeSet]:
    """𝓛_G^Pruned = {L^v | v ∈ V} with L^v = {w | v reachable from w} (§4.3).

    ∅ and V are always included so the DP has its start/terminal states
    (L^v for a sink v already equals... not necessarily V, so V is added
    explicitly; the paper's DP needs L_k = V).
    """
    fam: Set[NodeSet] = {EMPTY, frozenset(range(g.n))}
    for v in range(g.n):
        fam.add(g.ancestors_of(v))
    return sorted(fam, key=lambda s: (len(s), sorted(s)))


def segment_lower_sets(g: Graph, order: List[int] | None = None) -> List[NodeSet]:
    """Beyond-paper helper: prefix lower sets along a topological order.

    For chain-like graphs this equals 𝓛_G; for general graphs it is a cheap
    family (size #V+1) complementary to 𝓛^Pruned.  Every prefix of a
    topological order is a lower set.
    """
    order = order if order is not None else g.topological_order()
    fam: Set[NodeSet] = {EMPTY}
    cur: Set[int] = set()
    for v in order:
        cur.add(v)
        fam.add(frozenset(cur))
    return sorted(fam, key=lambda s: (len(s), sorted(s)))


def count_lower_sets(g: Graph, limit: int = DEFAULT_LOWER_SET_LIMIT) -> int:
    """#𝓛_G (for reporting; paper notes #V ≤ #𝓛_G ≤ 2^#V)."""
    return len(all_lower_sets(g, limit=limit))
