"""``plan_lint`` — run the static checkers over networks and traced functions.

    python -m repro.analysis --network unet
    python -m repro.analysis --network unet --budget 2e9
    python -m repro.analysis --smoke --json lint_report.json
    python -m repro.analysis --hlo --drift-json BENCH_hlo_drift.json

``--network`` lints one of the paper's seven benchmark graphs: plan at the
given budget (default: the exact minimal feasible one) and run the plan
verifier.  ``--traced module:factory`` (or the built-in ``quickstart``)
lints a real JAX function end to end: effect analysis → pinned planning →
plan verification → lowering conformance.  ``--smoke`` runs every
benchmark network plus the quickstart traced function — the CI gate.

``--hlo`` adds the compiler-truth checkers (``analysis.hlo``): each
network's plan is lowered onto its executable twin
(``benchmarks.networks.executable_twin``), compiled, and the optimized HLO
is checked for eq. (1) heavy-op multiplicity, cached-residual
materialization and memory drift; traced targets get the same treatment
through their carrier.  Per-target drift records land in
``--drift-json`` (default ``BENCH_hlo_drift.json``) — the CI drift-gate
artifact.  ``--hlo`` alone runs every network plus the quickstart.

Exit codes: 0 all clean, 1 lint errors, 2 infeasible budget (the exact
minimal feasible budget is printed — re-run with at least that).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .report import Report

EXIT_OK, EXIT_LINT, EXIT_INFEASIBLE = 0, 1, 2


def _quickstart_factory() -> Tuple[Callable[..., Any], Tuple[Any, ...]]:
    """The README's quickstart MLP — the traced smoke target."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    dn = (((1,), (0,)), ((), ()))

    def mlp_loss(params: Any, x: Any) -> Any:
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, dn))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
        for i in range(6)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    return mlp_loss, (params, x)


def _resolve_traced(spec: str) -> Tuple[Callable[..., Any], Tuple[Any, ...]]:
    if spec == "quickstart":
        return _quickstart_factory()
    import importlib

    mod_name, _, attr = spec.partition(":")
    if not attr:
        raise SystemExit(
            f"--traced wants 'module:factory' or 'quickstart', got {spec!r}"
        )
    return getattr(importlib.import_module(mod_name), attr)()


def lint_graph(
    g: Any,
    name: str,
    budget: Optional[float],
    method: str,
    hlo_records: Optional[List[Dict[str, Any]]] = None,
) -> Tuple[List[Report], bool]:
    """Plan ``g`` and verify; returns (reports, infeasible).

    With ``hlo_records`` (a list to append drift records to) the compiler
    -truth checkers also run: the abstract plan is lowered onto the
    network's executable twin (``benchmarks.networks.executable_twin``)
    through ``save_only_these_names`` and the compiled HLO is checked for
    heavy-op multiplicity, residual materialization and memory drift.
    """
    from ..core.planner import get_default_planner

    planner = get_default_planner()
    rep = planner.plan(g, budget, method=method)
    if rep.plan is None:
        needed = planner.min_feasible_budget(g, method)
        r = Report(checker="plan")
        r.add(
            "error",
            "infeasible-budget",
            f"{name}: no feasible strategy under budget {budget:g}; the "
            f"exact minimal feasible budget is {needed:g}",
        )
        return [r], True
    from .verifier import check_plan

    reports = [check_plan(g, rep.plan, budget=budget)]
    if hlo_records is not None:
        import jax

        from benchmarks.networks import executable_twin

        from ..core import dp
        from .hlo import HEAVY_NODE_KINDS, analyze_twin

        plan = rep.plan
        fwd, ex_args, byte_graph = executable_twin(g)
        # analytic peak in the *twin's* byte units: same lower-set sequence,
        # per-node activation bytes of the toy shapes
        analytic_peak = dp.peak_memory_live(
            byte_graph, [s.lower_set for s in plan.segments]
        )
        cached = set(plan.cached)
        recompute = set(range(g.n)) - cached
        cached_tags = {g.nodes[v].name for v in cached}
        recompute_tags = {g.nodes[v].name for v in recompute}
        plan_heavy = sum(
            1 for v in recompute if g.nodes[v].kind in HEAVY_NODE_KINDS
        )
        policy = jax.checkpoint_policies.save_only_these_names(
            *sorted(cached_tags)
        )
        fn_grad = jax.value_and_grad(jax.checkpoint(fwd, policy=policy))
        res = analyze_twin(
            fn_grad,
            ex_args,
            cached_tags=cached_tags,
            recompute_tags=recompute_tags,
            plan_heavy_recompute=plan_heavy,
            analytic_peak=analytic_peak,
            vanilla_grad=jax.value_and_grad(fwd),
        )
        res.drift.update(
            target=name, nodes=g.n, segments=len(plan.segments)
        )
        hlo_records.append(res.drift)
        reports.append(res.report)
    return reports, False


def lint_traced(
    fn: Callable[..., Any],
    args: Sequence[Any],
    budget: Optional[float],
    method: str,
    hlo_records: Optional[List[Dict[str, Any]]] = None,
    target: str = "traced",
) -> Tuple[List[Report], bool]:
    """Full three-checker lint of a traced function.

    With ``hlo_records`` the compiler-truth checkers (``analysis.hlo``)
    run as a fourth stage on the compiled planned twin.
    """
    from ..core.lowering.carriers import TracedCarrier
    from ..core.planner import get_default_planner
    from .conformance import check_lowering
    from .verifier import check_plan

    carrier = TracedCarrier.trace(fn, tuple(args), analyze_effects=True)
    ea = carrier.effects
    g = carrier.to_graph()
    planner = get_default_planner()
    rep = planner.plan(g, budget, method=method)
    if rep.plan is None:
        needed = planner.min_feasible_budget(g, method)
        r = Report(checker="plan")
        r.add(
            "error",
            "infeasible-budget",
            f"no feasible strategy under budget {budget:g}; the exact "
            f"minimal feasible budget is {needed:g}",
        )
        return [ea.report, r], True
    reports = [
        ea.report,
        check_plan(g, rep.plan, budget=budget, effects=ea, jg=carrier.jg),
        check_lowering(carrier, rep.plan),
    ]
    if hlo_records is not None:
        from .hlo import analyze_hlo

        res = analyze_hlo(carrier, rep.plan)
        res.drift.update(target=target, nodes=g.n,
                         segments=len(rep.plan.segments))
        hlo_records.append(res.drift)
        reports.append(res.report)
    return reports, False


def _run_target(
    name: str,
    run: Callable[[], Tuple[List[Report], bool]],
    results: List[Dict[str, Any]],
) -> Tuple[bool, bool]:
    """Execute one lint target; returns (had_errors, infeasible)."""
    t0 = time.perf_counter()
    reports, infeasible = run()
    dt = time.perf_counter() - t0
    ok = all(r.ok for r in reports)
    n_warn = sum(len(r.warnings()) for r in reports)
    print(f"{name:>16s}  {'OK' if ok else 'FAIL'}  "
          f"({len(reports)} checker(s), {n_warn} warning(s), {dt:.2f}s)")
    for r in reports:
        for f in r.findings:
            if f.severity != "info":
                where = f" @node {f.node}" if f.node is not None else ""
                print(f"    {f.severity}: [{r.checker}] {f.code}{where}: "
                      f"{f.message}")
    results.append({
        "target": name,
        "ok": ok,
        "seconds": dt,
        "reports": [r.to_dict() for r in reports],
    })
    return (not ok), infeasible


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="plan_lint: static soundness checks over plans",
    )
    ap.add_argument("--network", default=None,
                    help="one benchmark network (benchmarks.networks)")
    ap.add_argument("--traced", default=None,
                    help="'quickstart' or 'module:factory' returning "
                         "(fn, example_args)")
    ap.add_argument("--smoke", action="store_true",
                    help="lint every benchmark network plus the quickstart "
                         "traced function")
    ap.add_argument("--hlo", action="store_true",
                    help="compiler-truth checks: compile each target's "
                         "planned twin and verify heavy-op multiplicity, "
                         "residual materialization and memory drift against "
                         "the plan (alone, runs every network + quickstart)")
    ap.add_argument("--drift-json", default=None, metavar="PATH",
                    help="where --hlo writes its drift records "
                         "(default BENCH_hlo_drift.json)")
    ap.add_argument("--budget", type=float, default=None,
                    help="byte budget (default: exact minimal feasible)")
    ap.add_argument("--method", default="approx_dp",
                    choices=("approx_dp", "exact_dp"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the merged findings as a JSON artifact")
    args = ap.parse_args(argv)

    if not (args.network or args.traced or args.smoke or args.hlo):
        ap.error("pick one of --network / --traced / --smoke / --hlo")

    run_all = args.smoke or (
        args.hlo and not (args.network or args.traced)
    )
    drift_records: List[Dict[str, Any]] = []
    hlo_records = drift_records if args.hlo else None

    targets: List[Tuple[str, Callable[[], Tuple[List[Report], bool]]]] = []
    if args.network or run_all:
        try:
            from benchmarks.networks import NETWORKS
        except ImportError as e:
            raise SystemExit(
                "benchmarks.networks not importable — run from the repo "
                f"root with PYTHONPATH=src:. ({e})"
            ) from e
        names = [args.network] if args.network else sorted(NETWORKS)
        for name in names:
            if name not in NETWORKS:
                raise SystemExit(
                    f"unknown network {name!r}; pick from {sorted(NETWORKS)}"
                )
            targets.append((
                name,
                lambda name=name: lint_graph(
                    NETWORKS[name](), name, args.budget, args.method,
                    hlo_records=hlo_records,
                ),
            ))
    if args.traced or run_all:
        spec = args.traced or "quickstart"
        fn, ex_args = _resolve_traced(spec)
        targets.append((
            spec,
            lambda: lint_traced(fn, ex_args, args.budget, args.method,
                                hlo_records=hlo_records, target=spec),
        ))

    results: List[Dict[str, Any]] = []
    any_errors = False
    any_infeasible = False
    for name, run in targets:
        had_errors, infeasible = _run_target(name, run, results)
        any_errors |= had_errors
        any_infeasible |= infeasible

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"ok": not any_errors, "targets": results}, fh,
                      indent=2)
        print(f"report written to {args.json}")

    if args.hlo:
        drift_path = args.drift_json or "BENCH_hlo_drift.json"
        with open(drift_path, "w") as fh:
            json.dump({"ok": not any_errors, "records": drift_records}, fh,
                      indent=2)
        print(f"drift records written to {drift_path}")

    if any_infeasible:
        return EXIT_INFEASIBLE
    return EXIT_LINT if any_errors else EXIT_OK


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
