"""Checkpoint store: commit protocol, retention, torn-write recovery."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (
    AsyncCheckpointer,
    latest_step,
    restore,
    retain,
    save,
)


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
    }


def test_roundtrip(tree):
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, tree)
        assert latest_step(d) == 3
        out = restore(d, 3, tree)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            tree,
            out,
        )
        # dtypes preserved
        assert np.asarray(out["nested"]["b"]).dtype == np.dtype("bfloat16") or \
            str(np.asarray(out["nested"]["b"]).dtype) == "bfloat16"


def test_torn_checkpoint_ignored(tree):
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        # fake a torn step-2: directory without COMMITTED
        torn = os.path.join(d, "step_000000002")
        os.makedirs(torn)
        with open(os.path.join(torn, "MANIFEST.json"), "w") as f:
            f.write("{}")
        assert latest_step(d) == 1


def test_retention(tree):
    with tempfile.TemporaryDirectory() as d:
        for s in range(5):
            save(d, s, tree)
        retain(d, keep=2)
        kept = sorted(os.listdir(d))
        assert kept == ["step_000000003", "step_000000004"]


def test_missing_leaf_raises(tree):
    with tempfile.TemporaryDirectory() as d:
        save(d, 0, {"a": tree["a"]})
        with pytest.raises(KeyError):
            restore(d, 0, tree)


def test_async_checkpointer(tree):
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            ck.save_async(s, tree)
        ck.close()
        assert latest_step(d) == 3
        out = restore(d, 3, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_overwrite_same_step(tree):
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        t2 = {**tree, "a": tree["a"] * 2}
        save(d, 1, t2)
        out = restore(d, 1, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t2["a"]))
