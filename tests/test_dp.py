"""Algorithm 1: exact/approx DP vs the exhaustive-search oracle (§4.1–4.4)."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    approx_dp,
    exact_dp,
    exhaustive_search,
    min_feasible_budget,
    overhead,
    peak_memory_live,
)
from repro.core.dp import quantize_times, solve
from repro.core.graph import chain
from repro.core.lower_sets import all_lower_sets

from conftest import random_dag


def _feasible_budget(g, slack):
    return min_feasible_budget(g, "exact_dp") * slack


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.floats(1.0, 2.5))
def test_exact_dp_matches_exhaustive_time_centric(seed, n, slack):
    r = random.Random(seed)
    g = random_dag(r, n)
    B = _feasible_budget(g, slack)
    d = exact_dp(g, B)
    e = exhaustive_search(g, B)
    assert d.feasible == e.feasible
    if d.feasible:
        assert d.overhead == pytest.approx(e.overhead)
        g.check_increasing_sequence(d.sequence)
        assert overhead(g, d.sequence) == pytest.approx(d.overhead)
        # the budget bound holds under the planner's liveness functional
        assert peak_memory_live(g, d.sequence) <= B + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.floats(1.0, 2.5))
def test_exact_dp_matches_exhaustive_memory_centric(seed, n, slack):
    r = random.Random(seed)
    g = random_dag(r, n)
    B = _feasible_budget(g, slack)
    d = exact_dp(g, B, objective="memory_centric")
    e = exhaustive_search(g, B, objective="memory_centric")
    assert d.feasible == e.feasible
    if d.feasible:
        # §4.4: memory-centric = MAXIMAL overhead within budget
        assert d.overhead == pytest.approx(e.overhead)


def test_memory_centric_not_pareto_pruned():
    # regression: MC keeps dominated (t↑, m↑) states the TC pruning drops
    r = random.Random(7)
    for _ in range(30):
        g = random_dag(r, 5)
        B = _feasible_budget(g, 1.4)
        d = exact_dp(g, B, objective="memory_centric")
        e = exhaustive_search(g, B, objective="memory_centric")
        assert d.overhead == pytest.approx(e.overhead)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 7))
def test_budget_monotonicity(seed, n):
    """More memory can never force more recomputation."""
    r = random.Random(seed)
    g = random_dag(r, n)
    B0 = min_feasible_budget(g, "exact_dp")
    t_prev = None
    for slack in (1.0, 1.3, 1.8, 3.0, 10.0):
        res = exact_dp(g, B0 * slack)
        assert res.feasible
        if t_prev is not None:
            assert res.overhead <= t_prev + 1e-9
        t_prev = res.overhead


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_approx_never_beats_exact(seed, n):
    """𝓛^Pruned ⊆ 𝓛_G ⇒ approx overhead ≥ exact overhead (at same budget)."""
    r = random.Random(seed)
    g = random_dag(r, n)
    B = _feasible_budget(g, 1.5)
    ex = exact_dp(g, B)
    ap = approx_dp(g, B)
    if ap.feasible:
        assert ex.feasible
        assert ap.overhead >= ex.overhead - 1e-9


def test_infeasible_budget_reports_impossible(rng):
    g = random_dag(rng, 5)
    res = exact_dp(g, 1e-6)
    assert not res.feasible and res.sequence == []


def test_ample_budget_minimal_overhead_is_sinks(rng):
    """With unlimited memory the finest strategy caches every node that has a
    successor; sink nodes are never in any boundary ∂(L) (eq. 1), so the
    paper-model minimum overhead is exactly T(sinks)."""
    for _ in range(20):
        g = random_dag(rng, 6)
        res = exact_dp(g, 1e9)
        assert res.feasible
        sinks = [v for v in range(g.n) if not g.succ[v]]
        assert res.overhead == pytest.approx(g.T(sinks))


def test_chain_sqrt_shape():
    """On a uniform chain the tight-budget plan recomputes interior nodes."""
    g = chain(16, time=1.0, memory=1.0)
    B = min_feasible_budget(g, "exact_dp")
    res = exact_dp(g, B)
    assert res.feasible and res.overhead > 0


def test_quantize_times_preserves_paper_costs():
    g = chain(5, time=10.0)
    q = quantize_times(g, levels=32)
    assert all(t == 32.0 for t in q.time_v)
    r = random.Random(3)
    g2 = random_dag(r, 6)
    q2 = quantize_times(g2, levels=64)
    assert all(t >= 1 and float(t).is_integer() for t in q2.time_v)


def test_family_must_contain_empty_and_full(rng):
    g = random_dag(rng, 4)
    fam = [L for L in all_lower_sets(g) if L]  # drop ∅
    with pytest.raises(ValueError):
        solve(g, 100.0, fam)


def test_states_visited_reported(rng):
    g = random_dag(rng, 5)
    res = exact_dp(g, _feasible_budget(g, 1.5))
    assert res.states_visited > 0
