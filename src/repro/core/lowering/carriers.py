"""Graph carriers: the model representations the planning pipeline accepts.

A *carrier* pairs a computation with enough structure to (a) extract the
paper's ``core.Graph`` for the Planner and (b) be re-executed under a plan
by the lowering backends.  Two carriers cover the framework:

* :class:`BlockGraphCarrier` — the layer-granularity model DAG
  (``core.blockgraph.BlockGraph``) plus a loss over its outputs.  Node =
  block; the production lowering is the checkpoint-policy backend.
* :class:`TracedCarrier` — **any JAX callable**, traced to a jaxpr on
  example arguments (``core.jaxpr_graph``).  Node = jaxpr equation; the
  production lowering tags equation outputs with ``checkpoint_name`` and
  saves exactly the plan's cache set.

Both expose the same minimal surface: ``to_graph()`` (planner input),
``node_names()`` (checkpoint names, index-aligned with graph nodes) and
``default_backend``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax

from ..graph import Graph
from ..jaxpr_graph import JaxprGraph, from_jaxpr


@dataclasses.dataclass
class BlockGraphCarrier:
    """A ``BlockGraph`` bound to concrete params/inputs and a loss.

    The lowered callables take ``(params, inputs)`` — fresh values of the
    same shapes — and return ``(loss, param_grads)``.
    """

    bg: Any  # core.blockgraph.BlockGraph (kept untyped to avoid a cycle)
    loss_fn: Callable[..., jax.Array]
    params: Any
    inputs: Dict[str, Any]
    cost_model: str = "paper"

    default_backend = "policy"

    def to_graph(self) -> Graph:
        return self.bg.to_graph(self.params, self.inputs,
                                cost_model=self.cost_model)

    def node_names(self) -> List[str]:
        return [b.name for b in self.bg.blocks]


def _tree_flatten(args):
    return jax.tree_util.tree_flatten(args)


def is_drop_var(v) -> bool:
    """True for jaxpr DropVar outputs (placeholders with no uses)."""
    return type(v).__name__ == "DropVar"


@dataclasses.dataclass
class TracedCarrier:
    """Any JAX callable, traced on example arguments.

    ``fn`` must return a scalar (``jax.value_and_grad`` semantics); the
    lowered callables take the same positional arguments (same pytree
    structure and avals) and return ``(value, grads)`` w.r.t. ``argnums``.
    """

    fn: Callable[..., jax.Array]
    argnums: Union[int, Tuple[int, ...]]
    cost_model: str
    closed: Any  # ClosedJaxpr of the flattened function
    in_tree: Any  # treedef of the args tuple
    flat_avals: Tuple[jax.ShapeDtypeStruct, ...]
    arg_slices: Tuple[Tuple[int, int], ...]  # flat-leaf span per position arg
    jg: JaxprGraph

    default_backend = "jaxpr"

    @classmethod
    def trace(
        cls,
        fn: Callable[..., jax.Array],
        args: Sequence[Any],
        argnums: Union[int, Tuple[int, ...]] = 0,
        cost_model: str = "paper",
    ) -> "TracedCarrier":
        flat, in_tree = _tree_flatten(tuple(args))
        # flat-leaf span of each positional argument (interpreter backward)
        slices = []
        start = 0
        for a in args:
            leaves, _ = _tree_flatten(a)
            slices.append((start, start + len(leaves)))
            start += len(leaves)

        def flat_fn(*flat_args):
            return fn(*jax.tree_util.tree_unflatten(in_tree, flat_args))

        closed = jax.make_jaxpr(flat_fn)(*flat)
        outvars = closed.jaxpr.outvars
        if len(outvars) != 1 or getattr(outvars[0].aval, "shape", ()) != ():
            raise TypeError(
                "plan_function requires a scalar-output function "
                "(jax.value_and_grad semantics); got "
                f"{len(outvars)} outputs"
            )
        return cls(
            fn=fn,
            argnums=argnums,
            cost_model=cost_model,
            closed=closed,
            in_tree=in_tree,
            flat_avals=tuple(
                jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype)
                for v in closed.jaxpr.invars
            ),
            arg_slices=tuple(slices),
            jg=from_jaxpr(closed, cost_model=cost_model),
        )

    def to_graph(self) -> Graph:
        return self.jg.graph

    def node_names(self) -> List[str]:
        return [nd.name for nd in self.jg.graph.nodes]

    def flatten_args(self, args: Sequence[Any]) -> List[Any]:
        """Flatten call-time args, checking the traced structure."""
        flat, tree = _tree_flatten(tuple(args))
        if tree != self.in_tree:
            raise TypeError(
                "argument structure differs from the traced example "
                f"({tree} != {self.in_tree})"
            )
        return flat


def abstract_signature(args: Sequence[Any]) -> Tuple:
    """Hashable (treedef, avals) key of a call's arguments — the memo key
    under which ``plan_function`` caches one traced/planned lowering."""
    flat, tree = _tree_flatten(tuple(args))
    avals = tuple(
        (tuple(getattr(x, "shape", ())), str(getattr(x, "dtype", type(x))))
        for x in flat
    )
    return (tree, avals)
