"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One shared-attention block (single param set) applied every 6 Mamba2 blocks;
its input is concat(h, h0) — the skip edges from the embedding make the
layer graph non-chain, the paper's target case.
"""

from .base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64, chunk=256),
        hybrid_shared_attn_every=6,
    )
