"""Launchers: production mesh, dry-run, training and serving CLIs.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
fresh process (python -m repro.launch.dryrun).  Everything else here is
import-safe.
"""
