"""Effect/determinism analysis: which equations are safe to recompute?

The paper's framework assumes every node is pure and replayable.  Real
traced workloads are not: PRNG draws, side-effecting equations, opaque
``custom_vjp`` calls and donation-aliased buffers all change meaning when
re-executed during the backward pass.  This pass classifies every jaxpr
equation into a small taint lattice

    pure  <  donated  <  prng  <  opaque  <  effectful

by walking the ``core.prims`` tables plus JAX's own effect metadata
(recursing into higher-order equations — ``scan`` / ``while`` / ``cond`` /
``pjit`` / ``custom_vjp`` bodies), then propagates taint forward through
the graph to the first *storable* frontier (outputs the checkpoint-policy
lowering can actually save, i.e. inexact dtypes) and emits ``must_store``
pins there.  ``core.dp`` / ``core.planner`` consume the pins as hard
constraints: pinned nodes are priced store-only and never recomputed, and
the pin marker enters the graph digest so safe and unsafe plan-cache
variants can never collide.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, FrozenSet, List, Tuple

from ..core.graph import Graph, Node
from ..core.jaxpr_graph import JaxprGraph
from ..core.prims import (
    EFFECT_INNER_JAXPR_KEYS,
    HIGHER_ORDER_PRIMS,
    OPAQUE_PRIMS,
    PRNG_PRIMS,
)
from .report import Report

#: Taint lattice, least to greatest.  ``max()`` over a higher-order body
#: bubbles the worst inner class up to the enclosing equation.
CLASSES = ("pure", "donated", "prng", "opaque", "effectful")
_RANK = {c: i for i, c in enumerate(CLASSES)}


@dataclasses.dataclass(frozen=True)
class EqnEffect:
    """Classification of one (top-level) jaxpr equation."""

    index: int
    primitive: str
    klass: str  # one of CLASSES
    reason: str
    storable: bool  # every used output has an inexact dtype (taggable)

    @property
    def pure(self) -> bool:
        return self.klass == "pure"


def _is_drop(v: Any) -> bool:
    return type(v).__name__ == "DropVar"


def _storable(eqn: Any) -> bool:
    """True iff the checkpoint-policy lowering can save this equation.

    ``save_only_these_names`` keys on ``checkpoint_name`` tags, and the
    tagger only wraps inexact-dtype outputs — integer / bool / PRNG-key
    values pass through untagged and therefore cannot be residuals.
    """
    import jax.numpy as jnp

    outs = [ov for ov in eqn.outvars if not _is_drop(ov)]
    if not outs:
        return False
    for ov in outs:
        aval = getattr(ov, "aval", None)
        dtype = getattr(aval, "dtype", None)
        if dtype is None or not jnp.issubdtype(dtype, jnp.inexact):
            return False
    return True


def _inner_jaxprs(eqn: Any) -> Any:
    for key in EFFECT_INNER_JAXPR_KEYS:
        sub = eqn.params.get(key)
        if sub is None:
            continue
        subs = sub if isinstance(sub, (list, tuple)) else [sub]
        for s in subs:
            if callable(s) and not hasattr(s, "jaxpr") and not hasattr(s, "eqns"):
                continue  # thunks (e.g. fwd_jaxpr_thunk) — not traced yet
            inner = s.jaxpr if hasattr(s, "jaxpr") else s
            if hasattr(inner, "eqns"):
                yield inner


def _classify(eqn: Any) -> Tuple[str, str]:
    """(class, reason) of one equation, recursing into inner jaxprs."""
    name = eqn.primitive.name
    if name in PRNG_PRIMS:
        return "prng", f"PRNG primitive '{name}'"
    if name == "pallas_call":
        return "opaque", (
            "'pallas_call' runs a hand-written kernel the taint walker "
            "cannot see into (scratch buffers, input aliasing, reduction "
            "order); its outputs must be stored, not recomputed"
        )
    if name in OPAQUE_PRIMS:
        return "opaque", (
            f"'{name}' has a user-defined VJP; replaying its forward is not "
            "provably consistent with the residuals the custom rule expects"
        )
    effects = getattr(eqn, "effects", None)
    if effects:
        kinds = ", ".join(sorted(str(e) for e in effects))
        return "effectful", f"'{name}' carries effects: {kinds}"
    donated = eqn.params.get("donated_invars")
    if donated is not None and any(donated):
        return "donated", (
            f"'{name}' donates operand buffers; recomputation would re-read "
            "invalidated storage"
        )
    if name in HIGHER_ORDER_PRIMS:
        worst = ("pure", "")
        for inner in _inner_jaxprs(eqn):
            for ieqn in inner.eqns:
                k, r = _classify(ieqn)
                if _RANK[k] > _RANK[worst[0]]:
                    worst = (k, f"'{name}' body: {r}")
        return worst
    return "pure", ""


def classify_eqns(jaxpr: Any) -> List[EqnEffect]:
    """Per-equation classification of a (closed or open) jaxpr.

    Index-aligned with ``JaxprGraph`` nodes — one entry per top-level
    equation.
    """
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    out = []
    for idx, eqn in enumerate(inner.eqns):
        klass, reason = _classify(eqn)
        out.append(
            EqnEffect(
                index=idx,
                primitive=eqn.primitive.name,
                klass=klass,
                reason=reason,
                storable=_storable(eqn),
            )
        )
    return out


@dataclasses.dataclass
class EffectAnalysis:
    """Result of the effect pass over one traced graph.

    ``tainted`` holds every non-pure equation index; ``pins`` the
    ``must_store`` constraints — the storable forward frontier of the taint
    (a tainted storable equation pins itself; unstorable taint flows to
    successors until the policy lowering can save something).
    """

    effects: List[EqnEffect]
    tainted: FrozenSet[int]
    pins: FrozenSet[int]
    report: Report

    @property
    def pure(self) -> bool:
        return not self.tainted


def analyze_effects(jg: JaxprGraph) -> EffectAnalysis:
    """Classify ``jg``'s equations and derive ``must_store`` pins."""
    g = jg.graph
    effects = classify_eqns(jg.jaxpr)
    report = Report(checker="effects")
    tainted = frozenset(e.index for e in effects if not e.pure)

    for e in effects:
        if e.pure:
            continue
        report.add(
            "warning",
            f"{e.klass}-taint",
            f"{g.nodes[e.index].name}: {e.reason}",
            node=e.index,
        )

    # Forward taint propagation to the storable frontier.  A storable
    # tainted node pins itself; an unstorable one (uint32 PRNG bits, key
    # arrays, bool masks) cannot be a residual, so its taint flows to every
    # successor until a storable node absorbs it.
    pins: set = set()
    seen: set = set()
    queue = deque(sorted(tainted))
    while queue:
        v = queue.popleft()
        if v in seen:
            continue
        seen.add(v)
        if effects[v].storable:
            pins.add(v)
            continue
        if not g.succ[v]:
            report.add(
                "warning",
                "unstorable-taint-sink",
                f"{g.nodes[v].name}: tainted, unstorable and without "
                "successors — nothing downstream can be pinned for it",
                node=v,
            )
            continue
        for w in g.succ[v]:
            queue.append(w)

    for v in sorted(pins):
        report.add(
            "info",
            "must-store-pin",
            f"{g.nodes[v].name} pinned must_store (storable frontier of "
            "tainted equations)",
            node=v,
        )
    return EffectAnalysis(
        effects=effects,
        tainted=tainted,
        pins=frozenset(pins),
        report=report,
    )


def pin_graph(g: Graph, pins: FrozenSet[int]) -> Graph:
    """New graph with ``must_store=True`` on ``pins`` (existing pins kept).

    The pin marker enters WL colors and the canonical digest
    (``core.graph``), so pinned and unpinned variants of the same topology
    never share plan-cache entries.
    """
    if not pins and not g.store_pins_mask:
        return g
    nodes = [
        Node(
            nd.idx,
            nd.name,
            nd.time,
            nd.memory,
            nd.kind,
            must_store=nd.must_store or (nd.idx in pins),
        )
        for nd in g.nodes
    ]
    return Graph(nodes, g.edges, cost_source=getattr(g, "cost_source", ""))
