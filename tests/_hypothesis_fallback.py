"""Minimal, dependency-free stand-in for the ``hypothesis`` API this suite uses.

The container has no network access, so ``pip install hypothesis`` is not
always possible.  ``conftest.py`` installs this module under the name
``hypothesis`` *only when the real package is missing*, so the test modules
keep their ordinary ``from hypothesis import given, settings, strategies``
imports and transparently upgrade to real property-based testing wherever
hypothesis is installed (CI does install it via the ``dev`` extra).

Supported surface (exactly what the suite needs):

* ``given(*strategies)`` — deterministic example-based fallback: draws
  ``max_examples`` pseudo-random examples from each strategy (seeded by the
  test name, so failures reproduce) and runs the test body once per example.
* ``settings(max_examples=..., deadline=...)`` — records ``max_examples``;
  ``deadline`` is ignored.
* ``strategies.integers / floats / lists / data / sampled_from / booleans``.

No shrinking, no example database — this is a fallback, not a replacement.
"""

from __future__ import annotations

import hashlib
import random
from typing import Any, Callable, List, Optional

DEFAULT_MAX_EXAMPLES = 50

__version__ = "0.0-fallback"


class Strategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn

    def example_from(self, rnd: random.Random) -> Any:
        return self._draw(rnd)


class DataObject:
    """The object handed to tests using ``st.data()``."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: Strategy, label: Optional[str] = None) -> Any:
        return strategy.example_from(self._rnd)


class _DataStrategy(Strategy):
    def __init__(self):
        super().__init__(lambda rnd: DataObject(rnd))


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = -(2**31), max_value: int = 2**31) -> Strategy:
        return Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> Strategy:
        return Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rnd: rnd.random() < 0.5)

    @staticmethod
    def sampled_from(options) -> Strategy:
        opts = list(options)
        return Strategy(lambda rnd: rnd.choice(opts))

    @staticmethod
    def lists(
        elements: Strategy,
        min_size: int = 0,
        max_size: Optional[int] = None,
        unique: bool = False,
    ) -> Strategy:
        def draw(rnd: random.Random):
            hi = max_size if max_size is not None else min_size + 8
            size = rnd.randint(min_size, max(min_size, hi))
            out: List[Any] = []
            attempts = 0
            while len(out) < size and attempts < 50 * (size + 1):
                x = elements.example_from(rnd)
                attempts += 1
                if unique and x in out:
                    continue
                out.append(x)
            return out

        return Strategy(draw)

    @staticmethod
    def data() -> Strategy:
        return _DataStrategy()


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_kw):
    """Decorator recording ``max_examples`` on the (given-wrapped) test."""

    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return apply


def given(*strats: Strategy, **kw_strats: Strategy):
    def decorate(test_fn):
        def runner(*fixture_args, **fixture_kw):
            n = getattr(runner, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = int.from_bytes(
                hashlib.sha256(
                    f"{test_fn.__module__}.{test_fn.__qualname__}".encode()
                ).digest()[:8],
                "big",
            )
            rnd = random.Random(seed)
            for _ in range(n):
                args = [s.example_from(rnd) for s in strats]
                kwargs = {k: s.example_from(rnd) for k, s in kw_strats.items()}
                test_fn(*fixture_args, *args, **fixture_kw, **kwargs)

        # NOTE: no functools.wraps — pytest follows __wrapped__ to the original
        # signature and would try to inject the strategy params as fixtures.
        runner.__name__ = test_fn.__name__
        runner.__qualname__ = test_fn.__qualname__
        runner.__module__ = test_fn.__module__
        runner.__doc__ = test_fn.__doc__
        runner.hypothesis_fallback = True
        return runner

    return decorate
