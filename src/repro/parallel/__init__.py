"""repro.parallel — mesh/axis-type compatibility shims and sharding rules."""
