"""Static plan verifier: re-derive schedule soundness from first principles.

Given any :class:`~repro.core.schedule.ExecutionPlan`, re-check everything
the plan claims **without** going through the DP that produced it — a
verifier bug and a solver bug can't cancel:

* topological validity — each ``L_i`` is a lower set, strictly increasing,
  terminating at ``V``; segments partition ``V`` as ``L_i \\ L_{i-1}``;
* cache-set consistency — ``cached`` equals the re-derived
  ``∪_i (∂(L_i) ∪ (pins ∩ L_i))``, per-segment ``keep`` / ``recompute``
  agree, and no ``must_store`` pin is ever scheduled for recomputation;
* replay soundness — every segment's external inputs ``δ⁻(V_i) \\ V_i``
  are cached *before* the segment replays (members of the effective cache
  of ``L_{i-1}``);
* analytic peak — recomputed via the **event-level simulator**
  (``liveness.simulate``, independent of the DP's closed-form transition
  pricing) and compared against ``plan.peak_memory`` and the budget;
* overhead — eq. (1)'s ``T(V \\ U_k)`` re-summed directly;
* per-device ``M_v`` — when the carrier was traced under a mesh, each
  node's bytes re-derived from its equation's output avals and propagated
  shardings.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, FrozenSet, Optional

from ..core.graph import Graph
from ..core.jaxpr_graph import JaxprGraph
from ..core.schedule import ExecutionPlan
from .report import Report

if TYPE_CHECKING:  # pragma: no cover
    from .effects import EffectAnalysis

_REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= _REL_TOL * max(1.0, abs(a), abs(b))


def check_plan(
    g: Graph,
    plan: ExecutionPlan,
    budget: Optional[float] = None,
    effects: Optional["EffectAnalysis"] = None,
    jg: Optional[JaxprGraph] = None,
    strategies: Optional[object] = None,
) -> Report:
    """Statically verify ``plan`` against ``g`` (see module docstring).

    ``budget``: enforce ``peak ≤ budget`` when given.  ``effects``: the
    graph's :class:`~repro.analysis.effects.EffectAnalysis` — every
    *storable* tainted equation and every derived ``must_store`` pin must be
    in the plan's cache set (unstorable taint — key plumbing, counter bits —
    replays deterministically once its storable frontier is cached, so it is
    not flagged).  ``jg``: the traced carrier's jaxpr graph, enabling the
    per-device ``M_v`` consistency check.

    Strategy-annotated plans (``plan.strategy`` non-empty) additionally
    check: every assigned node is cached, codes are known, ``must_store``
    pins / storable-tainted nodes are never quantized (the round-trip is
    lossy, so a pinned node's replay would not be bit-identical; offload is
    legal — host copies are exact), the simulated peak prices offloaded
    residuals at zero device bytes and quantized ones at int8+scale bytes,
    and — when the pricing ``strategies``
    :class:`~repro.core.strategies.StrategyConfig` is supplied — the
    declared overhead equals eq. (1) plus the assignment's transfer/codec
    taxes.  Without the config the tax term cannot be re-derived and only
    ``overhead ≥ T(V \\ U_k)`` is enforced.
    """
    from ..core import liveness

    report = Report(checker="plan")
    n = g.n
    full = frozenset(range(n))

    # ---- 1. sequence validity ------------------------------------------
    seq = [s.lower_set for s in plan.segments]
    if not seq:
        report.add("error", "empty-plan", "plan has no segments")
        return report
    try:
        g.check_increasing_sequence(seq)
    except ValueError as e:
        report.add("error", "invalid-sequence", str(e))
        return report

    pins = g.store_pins
    prev: FrozenSet[int] = frozenset()
    derived_cached: set = set()
    for seg in plan.segments:
        Vi = seg.lower_set - prev
        if frozenset(seg.nodes) != Vi:
            report.add(
                "error",
                "segment-partition",
                f"segment {seg.index}: nodes {sorted(seg.nodes)} != "
                f"L_{seg.index} \\ L_{seg.index - 1} = {sorted(Vi)}",
            )
        # ---- 2. per-segment cache decisions ----------------------------
        b_eff = g.boundary(seg.lower_set) | (pins & seg.lower_set)
        if seg.boundary != b_eff:
            report.add(
                "error",
                "boundary-mismatch",
                f"segment {seg.index}: declared boundary "
                f"{sorted(seg.boundary)} != derived ∂(L)∪pins {sorted(b_eff)}",
            )
        if seg.keep != (b_eff & Vi):
            report.add(
                "error",
                "keep-mismatch",
                f"segment {seg.index}: keep {sorted(seg.keep)} != "
                f"{sorted(b_eff & Vi)}",
            )
        derived_cached |= b_eff
        prev = seg.lower_set

    U_k = frozenset(derived_cached)
    if plan.cached != U_k:
        extra = sorted(plan.cached - U_k)
        missing = sorted(U_k - plan.cached)
        report.add(
            "error",
            "cache-set-mismatch",
            f"plan.cached disagrees with the re-derived U_k "
            f"(extra={extra}, missing={missing}); residuals saved by the "
            "lowering would not match the schedule's replay assumptions",
        )

    # recompute sets + pins never recomputed
    for seg in plan.segments:
        Vi = frozenset(seg.nodes)
        want = Vi - U_k
        if seg.recompute != want:
            report.add(
                "error",
                "recompute-mismatch",
                f"segment {seg.index}: recompute {sorted(seg.recompute)} != "
                f"V_i \\ U_k = {sorted(want)}",
            )
        hit = sorted(pins & seg.recompute)
        if hit:
            report.add(
                "error",
                "pinned-node-recomputed",
                f"segment {seg.index} recomputes must_store node(s) "
                f"{[g.nodes[v].name for v in hit]}",
                node=hit[0],
            )

    # ---- 3. replay soundness -------------------------------------------
    prev = frozenset()
    avail: set = set()  # effective cache of L_{i-1}
    for seg in plan.segments:
        Vi = frozenset(seg.nodes)
        ext = g.delta_minus(Vi) - Vi
        missing = sorted(ext - avail) if seg.index > 0 else sorted(ext)
        if missing:
            report.add(
                "error",
                "replay-missing-input",
                f"segment {seg.index} reads {[g.nodes[v].name for v in missing]} "
                "which are neither recomputed in-segment nor cached by an "
                "earlier segment",
                node=missing[0],
            )
        avail |= g.boundary(seg.lower_set) | (pins & seg.lower_set)
        prev = seg.lower_set

    # ---- 4. recomputed taint -------------------------------------------
    if effects is not None:
        must_cache = frozenset(
            v for v in effects.tainted if effects.effects[v].storable
        ) | effects.pins
        for v in sorted(must_cache - U_k):
            report.add(
                "error",
                "tainted-recompute",
                f"{g.nodes[v].name} absorbs non-pure effects "
                "(effect analysis) but is not in the plan's cache set — "
                "replaying it in the backward pass is not provably "
                "bit-identical; re-plan with its must_store pin applied "
                "(pin_graph)",
                node=v,
            )

    # ---- 4b. storage-strategy validity ---------------------------------
    strategy = dict(plan.strategy or {})
    if strategy:
        from ..core.strategies import OFFLOAD, QUANTIZE, STORE

        known = {STORE, OFFLOAD, QUANTIZE}
        for v in sorted(strategy):
            code = strategy[v]
            if code not in known:
                report.add(
                    "error",
                    "unknown-strategy",
                    f"node {g.nodes[v].name} carries unknown storage "
                    f"strategy {code!r}",
                    node=v,
                )
            if v not in plan.cached:
                report.add(
                    "error",
                    "strategy-uncached-node",
                    f"node {g.nodes[v].name} has strategy {code!r} but is "
                    "not in the plan's cache set — strategies only apply to "
                    "cached residuals",
                    node=v,
                )
        lossy = frozenset(
            v for v, code in strategy.items() if code == QUANTIZE
        )
        no_quantize = pins
        if effects is not None:
            no_quantize = no_quantize | frozenset(
                v for v in effects.tainted if effects.effects[v].storable
            ) | effects.pins
        for v in sorted(lossy & no_quantize):
            report.add(
                "error",
                "pinned-node-quantized",
                f"must_store / effect-tainted node {g.nodes[v].name} is "
                "quantized — the int8 round-trip is lossy, so its replayed "
                "value would not be bit-identical (offload it instead)",
                node=v,
            )

    # stop before the quantitative checks if the schedule itself is broken —
    # the simulator requires a structurally valid plan
    if not report.ok:
        return report

    # ---- 5. analytic peak (event-level, DP-independent) ----------------
    # For strategy plans the simulator reprices cached residuals at their
    # device footprint — offloaded bytes never count against the device
    # peak, quantized ones count at int8+scale bytes.
    sim = liveness.simulate(g, seq, liveness=True,
                            assignment=strategy or None)
    if not _close(sim.peak_memory, plan.peak_memory):
        report.add(
            "error",
            "peak-mismatch",
            f"declared peak {plan.peak_memory:.6g} != simulated last-use "
            f"liveness peak {sim.peak_memory:.6g}",
        )
    if budget is not None and sim.peak_memory > budget * (1 + _REL_TOL):
        report.add(
            "error",
            "over-budget",
            f"simulated peak {sim.peak_memory:.6g} exceeds the budget "
            f"{budget:.6g}",
        )

    # ---- 6. overhead (eq. 1, plus strategy taxes) ----------------------
    want_overhead = g.T(full - U_k)
    if strategy and strategies is not None:
        from ..core.strategies import assignment_taxes

        try:
            want_overhead += assignment_taxes(g, strategy, strategies)
        except ValueError as e:
            report.add("error", "illegal-assignment", str(e))
            return report
    if strategy and strategies is None:
        # without the pricing config the transfer/codec tax term can't be
        # re-derived; the declared overhead must still dominate eq. (1)
        if plan.overhead < want_overhead * (1 - _REL_TOL):
            report.add(
                "error",
                "overhead-mismatch",
                f"declared overhead {plan.overhead:.6g} is below eq. (1)'s "
                f"T(V \\ U_k) = {want_overhead:.6g} — strategy taxes can "
                "only add time",
            )
    elif not _close(want_overhead, plan.overhead):
        report.add(
            "error",
            "overhead-mismatch",
            f"declared overhead {plan.overhead:.6g} != T(V \\ U_k) "
            + ("+ strategy taxes " if strategy else "")
            + f"= {want_overhead:.6g}",
        )

    # ---- 7. per-device M_v vs the declared mesh ------------------------
    if jg is not None:
        report.extend(check_graph_memory(jg).findings)

    return report


def check_graph_memory(jg: JaxprGraph) -> Report:
    """Re-derive every node's ``M_v`` from its equation's output avals.

    For a mesh-traced carrier the bytes must be the ceil-divided shard
    sizes under the propagated PartitionSpecs; unsharded traces must carry
    whole-aval bytes.  Catches stale graphs (edited costs, mismatched
    specs) before a per-device budget is trusted.
    """
    from ..core.jaxpr_graph import aval_bytes

    report = Report(checker="graph-memory")
    g = jg.graph
    axis_sizes = jg.axis_sizes if jg.eqn_specs is not None else {}

    for idx, eqn in enumerate(jg.eqns):
        if jg.eqn_specs is not None and axis_sizes:
            from repro.parallel import sharding as _sh

            specs = jg.eqn_specs[idx]
            mem = 0
            for ov, sp in zip(eqn.outvars, specs):
                if hasattr(ov, "aval"):
                    mem += _sh.sharded_aval_bytes(ov.aval, sp, axis_sizes)
        else:
            mem = sum(
                aval_bytes(ov.aval)
                for ov in eqn.outvars
                if hasattr(ov, "aval")
            )
        mem = max(float(mem), 1.0)
        if mem != g.mem_v[idx]:
            report.add(
                "error",
                "memory-mismatch",
                f"{g.nodes[idx].name}: graph M_v={g.mem_v[idx]:.6g} but the "
                f"equation's output avals give {mem:.6g} bytes"
                + (" per device" if axis_sizes else ""),
                node=idx,
            )
    return report
