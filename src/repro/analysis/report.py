"""Finding/Report containers shared by the three checkers.

A *finding* is one diagnostic (severity, stable code, optional node index,
message); a *report* is an ordered list of findings with an ``ok`` verdict
(no error-severity findings).  All three checkers — effects, plan verifier,
lowering conformance — speak this type, so ``plan_lint`` can merge their
output into one JSON artifact.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

SEVERITIES = ("error", "warning", "info")


class PlanVerificationError(RuntimeError):
    """A plan failed static verification (``repro.analysis``).

    Raised by ``plan_function(..., verify=True)`` and the launch-time
    ``REPRO_VERIFY_PLANS=1`` hook; the message is the failing report's
    rendered findings.
    """


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from a checker.

    Attributes:
      severity: "error" (plan is unsound), "warning" (needs attention),
        "info" (context worth surfacing).
      code: stable kebab-case identifier, e.g. ``"tainted-recompute"``.
      message: human-readable, actionable description.
      node: graph node / equation index the finding anchors to, if any.
    """

    severity: str
    code: str
    message: str
    node: Optional[int] = None

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_dict(self) -> dict:
        return {
            "severity": self.severity,
            "code": self.code,
            "message": self.message,
            "node": self.node,
        }


@dataclasses.dataclass
class Report:
    """Outcome of one checker run over one target."""

    checker: str
    findings: List[Finding] = dataclasses.field(default_factory=list)

    def add(self, severity: str, code: str, message: str,
            node: Optional[int] = None) -> None:
        self.findings.append(Finding(severity, code, message, node))

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def merge(self, other: "Report") -> "Report":
        """New report holding both checkers' findings."""
        out = Report(checker=f"{self.checker}+{other.checker}")
        out.findings = list(self.findings) + list(other.findings)
        return out

    def to_dict(self) -> dict:
        return {
            "checker": self.checker,
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
        }

    def __str__(self) -> str:
        lines = [f"[{self.checker}] {'OK' if self.ok else 'FAIL'}"]
        for f in self.findings:
            where = f" @node {f.node}" if f.node is not None else ""
            lines.append(f"  {f.severity}: {f.code}{where}: {f.message}")
        return "\n".join(lines)
