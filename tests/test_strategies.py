"""Joint memory-strategy DP (strategy lattice): oracle equality, lowering
realizations, and device-byte audits.

Covers the PR-10 satellite contracts:

* differential oracle — the multi-strategy DP's optimum equals the
  brute-force optimum of ``core.dfs.exhaustive_search`` over *all*
  strategy assignments, bit-for-bit, at ulp-adjacent budgets;
* lowering semantics — offload-only plans are bit-identical to vanilla
  ``jax.value_and_grad`` (host placement never changes a value); quantized
  plans stay inside the documented relative gradient bound
  (``docs/architecture.md``, "Strategy lattice") and plans that select
  zero quantized nodes stay bit-identical;
* interpreter audit — the live-byte trace prices offloaded residuals at
  zero device bytes and quantized ones at int8+scale bytes, so the
  measured peak of a strategy plan sits under its analytic peak while the
  same sequence all-store measures strictly higher;
* verifier + plan-cache guards for strategy-annotated plans.
"""

import dataclasses
import random

import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dp
from repro.core.dfs import exhaustive_search
from repro.core.dp import min_feasible_budget_exact, solve
from repro.core.lower_sets import all_lower_sets
from repro.core.schedule import make_plan
from repro.core.strategies import (
    LEGACY,
    OFFLOAD,
    QUANTIZE,
    QUANTIZE_BYTES_RATIO,
    STORE,
    StrategyConfig,
    device_bytes,
)

from conftest import random_dag

# Artificially slow strategy bandwidths (bytes/time-unit) so taxes are the
# same order as the T ∈ {1, 10} node times and the DP must genuinely trade
# them off; offload twice as expensive per byte as the int8 codec.
CFG = StrategyConfig(
    strategies=("store", "recompute", "offload", "quantize"),
    offload_bytes_per_sec=4.0,
    quantize_bytes_per_sec=16.0,
)
OFFLOAD_ONLY = dataclasses.replace(CFG, strategies=("store", "recompute", "offload"))
QUANTIZE_ONLY = dataclasses.replace(CFG, strategies=("store", "recompute", "quantize"))


# ---------------------------------------------------------------------------
# Satellite 1 — differential oracle: joint DP == exhaustive search
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=0, max_value=10**6),
    st.integers(min_value=4, max_value=7),
    st.sampled_from(["time_centric", "memory_centric"]),
    st.sampled_from([CFG, OFFLOAD_ONLY, QUANTIZE_ONLY]),
)
def test_joint_dp_matches_exhaustive(seed, n, objective, cfg):
    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=cfg)
    assert b < dp.INF
    for budget in (
        b,
        float(np.nextafter(b, -np.inf)),  # one ulp below: both infeasible
        float(np.nextafter(b, np.inf)),
        b * 1.5,
    ):
        rd = solve(g, budget, fam, objective=objective, strategies=cfg)
        ro = exhaustive_search(g, budget, objective, fam, strategies=cfg)
        assert rd.feasible == ro.feasible, budget
        if not rd.feasible:
            continue
        # bitwise equality of the optimum — same float folds on both sides
        assert rd.overhead == ro.overhead, budget
        # the DP's own assignment must replay at its claimed objective
        assert rd.assignment is not None
        plan = make_plan(g, rd.sequence, assignment=rd.assignment, strategies=cfg)
        assert plan.peak_memory <= budget


@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=0, max_value=10**6),
    st.integers(min_value=4, max_value=7),
)
def test_strategy_mfb_is_exact_threshold(seed, n):
    """feasible exactly at the joint mfb, infeasible one ulp below, and
    never above the legacy (all-store) mfb."""
    r = random.Random(seed)
    g = random_dag(r, n)
    fam = all_lower_sets(g)
    b_leg = min_feasible_budget_exact(g, fam)
    b_str = min_feasible_budget_exact(g, fam, strategies=CFG)
    assert b_str <= b_leg
    assert dp.feasible(g, b_str, fam, strategies=CFG)
    assert not dp.feasible(g, float(np.nextafter(b_str, -np.inf)), fam,
                           strategies=CFG)


# ---------------------------------------------------------------------------
# Satellite 3 — lowering semantics (offload exact, quantize bounded)
# ---------------------------------------------------------------------------


def _net(params, x):
    import jax.numpy as jnp

    h = x
    for W in params:
        h = jnp.tanh(h @ W)
    return jnp.mean(h ** 2)


def _net_args():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    params = [
        jnp.asarray(rng.normal(size=(16, 16)) / 4.0, jnp.float32)
        for _ in range(4)
    ]
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    return params, x


def _fresh_plan_function(**kw):
    from repro.core.lowering.front_door import plan_function
    from repro.core.plan_cache import PlanCache
    from repro.core.planner import Planner

    planner = Planner(cache=PlanCache())  # in-memory, test-isolated
    return plan_function(planner=planner, **kw)


def test_offload_plan_bit_identical_to_vanilla():
    import jax

    params, x = _net_args()
    v_ref, g_ref = jax.jit(jax.value_and_grad(_net))(params, x)

    pf = _fresh_plan_function(
        fn=_net, budget=None, backend="jaxpr", method="exact_dp",
        objective="memory_centric", cost_model="paper", argnums=0,
        loss_fn=None, track_live=False, strategies=OFFLOAD_ONLY, verify=True,
    )
    low = pf.lowered_for(params, x)
    assert any(c == OFFLOAD for c in low.plan.strategy.values())
    v, grads = pf(params, x)
    assert bool(v == v_ref)
    for a, b in zip(grads, g_ref):
        assert bool((a == b).all())


def test_quantized_plan_within_documented_bound():
    import jax
    import jax.numpy as jnp

    params, x = _net_args()
    v_ref, g_ref = jax.jit(jax.value_and_grad(_net))(params, x)

    pf = _fresh_plan_function(
        fn=_net, budget=None, backend="jaxpr", method="exact_dp",
        objective="memory_centric", cost_model="paper", argnums=0,
        loss_fn=None, track_live=False, strategies=QUANTIZE_ONLY, verify=True,
    )
    low = pf.lowered_for(params, x)
    assert any(c == QUANTIZE for c in low.plan.strategy.values())
    v, grads = pf(params, x)
    # documented bound (docs/architecture.md, "Strategy lattice"): ≤ 5e-2
    # relative l2 gradient error on a well-conditioned net
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(grads, g_ref))
    den = sum(float(jnp.sum(b ** 2)) for b in g_ref)
    assert num ** 0.5 <= 5e-2 * den ** 0.5
    assert abs(float(v - v_ref)) <= 5e-2 * abs(float(v_ref))


def test_zero_quantized_nodes_bit_identical():
    """Quantize enabled but never selected (loose budget → all-store plan):
    the lowered twin must stay bit-identical to the legacy lowering of the
    same function at the same budget."""
    params, x = _net_args()

    pf_leg = _fresh_plan_function(
        fn=_net, budget=1e18, backend="jaxpr", method="exact_dp",
        objective="time_centric", cost_model="paper", argnums=0,
        loss_fn=None, track_live=False, strategies=None, verify=True,
    )
    v_ref, g_ref = pf_leg(params, x)

    pf = _fresh_plan_function(
        fn=_net, budget=1e18, backend="jaxpr", method="exact_dp",
        objective="time_centric", cost_model="paper", argnums=0,
        loss_fn=None, track_live=False, strategies=QUANTIZE_ONLY, verify=True,
    )
    low = pf.lowered_for(params, x)
    assert low.plan.strategy == {}  # store is tax-free: never quantize
    v, grads = pf(params, x)
    assert bool(v == v_ref)
    for a, b in zip(grads, g_ref):
        assert bool((a == b).all())


def test_interpreter_audit_excludes_offloaded_bytes():
    """The live-byte audit prices offloaded residuals at zero device bytes:
    the same sequence measures strictly lower with the offload assignment
    than all-store, and stays under the strategy plan's analytic peak."""
    from repro.core.lowering.carriers import TracedCarrier
    from repro.core.lowering.interpreter import traced_planned_value_and_grad

    params, x = _net_args()
    carrier = TracedCarrier.trace(_net, (params, x), argnums=0,
                                  cost_model="paper")
    g = carrier.to_graph()
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=OFFLOAD_ONLY)
    res = solve(g, b, fam, objective="memory_centric", strategies=OFFLOAD_ONLY)
    assert res.feasible and res.assignment
    plan = make_plan(g, res.sequence, assignment=res.assignment,
                     strategies=OFFLOAD_ONLY)
    assert any(c == OFFLOAD for c in plan.strategy.values())
    plan_store = make_plan(g, res.sequence)

    _, _, trace = traced_planned_value_and_grad(carrier, plan,
                                                track_live=True)(params, x)
    _, _, trace_store = traced_planned_value_and_grad(
        carrier, plan_store, track_live=True)(params, x)
    peak = max(nb for _, nb in trace)
    peak_store = max(nb for _, nb in trace_store)
    assert peak <= plan.peak_memory * (1 + 1e-9)
    assert peak < peak_store
    # every forward snapshot after a segment that kept an offloaded node
    # must be cheaper than its all-store twin at the same step
    for (tag, nb), (tag2, nb2) in zip(trace, trace_store):
        assert tag == tag2
        assert nb <= nb2


def test_interpreter_quantized_bytes_accounting():
    from repro.core.lowering.carriers import TracedCarrier
    from repro.core.lowering.interpreter import traced_planned_value_and_grad

    params, x = _net_args()
    carrier = TracedCarrier.trace(_net, (params, x), argnums=0,
                                  cost_model="paper")
    g = carrier.to_graph()
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=QUANTIZE_ONLY)
    res = solve(g, b, fam, objective="memory_centric",
                strategies=QUANTIZE_ONLY)
    assert res.feasible and res.assignment
    plan = make_plan(g, res.sequence, assignment=res.assignment,
                     strategies=QUANTIZE_ONLY)
    if not any(c == QUANTIZE for c in plan.strategy.values()):
        pytest.skip("no quantized node selected at this mfb")
    _, _, trace = traced_planned_value_and_grad(carrier, plan,
                                                track_live=True)(params, x)
    assert max(nb for _, nb in trace) <= plan.peak_memory * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Verifier + schedule + cache guards
# ---------------------------------------------------------------------------


def test_verifier_accepts_and_rejects_strategy_plans(rng):
    from repro.analysis import check_plan

    g = random_dag(rng, 8)
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=CFG)
    res = solve(g, b, fam, objective="time_centric", strategies=CFG)
    plan = make_plan(g, res.sequence, assignment=res.assignment, strategies=CFG)
    assert check_plan(g, plan, budget=b, strategies=CFG).ok
    assert check_plan(g, plan, budget=b).ok  # config-less: inequality check

    v0 = next(iter(plan.cached))
    bad = dataclasses.replace(plan, strategy={**plan.strategy, v0: "teleport"})
    rep = check_plan(g, bad, strategies=CFG)
    assert any(f.code == "unknown-strategy" for f in rep.findings)

    uncached = sorted(frozenset(range(g.n)) - plan.cached)
    if uncached:
        bad = dataclasses.replace(
            plan, strategy={**plan.strategy, uncached[0]: OFFLOAD}
        )
        rep = check_plan(g, bad, strategies=CFG)
        assert any(f.code == "strategy-uncached-node" for f in rep.findings)


def test_verifier_rejects_quantized_pin(rng):
    from repro.analysis import check_plan
    from repro.analysis.effects import pin_graph

    g0 = random_dag(rng, 8)
    fam0 = all_lower_sets(g0)
    b0 = min_feasible_budget_exact(g0, fam0, strategies=CFG)
    res0 = solve(g0, b0, fam0, objective="time_centric", strategies=CFG)
    plan0 = make_plan(g0, res0.sequence, assignment=res0.assignment,
                      strategies=CFG)
    pin = next(iter(plan0.cached))
    g = pin_graph(g0, frozenset({pin}))
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=CFG)
    res = solve(g, b, fam, objective="time_centric", strategies=CFG)
    plan = make_plan(g, res.sequence, assignment=res.assignment, strategies=CFG)
    # the DP itself never quantizes a pin (offload stays legal — exact)
    assert plan.strategy.get(pin) != QUANTIZE
    bad = dataclasses.replace(plan, strategy={**plan.strategy, pin: QUANTIZE})
    rep = check_plan(g, bad, strategies=CFG)
    assert any(f.code == "pinned-node-quantized" for f in rep.findings)


def test_make_plan_prices_strategy(rng):
    from repro.core.strategies import assignment_taxes

    g = random_dag(rng, 8)
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=CFG)
    res = solve(g, b, fam, objective="time_centric", strategies=CFG)
    plan = make_plan(g, res.sequence, assignment=res.assignment, strategies=CFG)
    legacy = make_plan(g, res.sequence)
    assert plan.cached == legacy.cached
    assert plan.overhead == legacy.overhead + assignment_taxes(
        g, plan.strategy, CFG
    )
    if plan.strategy:
        assert plan.peak_memory <= legacy.peak_memory
        w = device_bytes(g, plan.strategy)
        for v, code in plan.strategy.items():
            if code == OFFLOAD:
                assert w[v] == 0.0
            elif code == QUANTIZE:
                assert w[v] == g.mem_v[v] * QUANTIZE_BYTES_RATIO


def test_plan_cache_digests_and_roundtrip(rng, tmp_path):
    from repro.core.plan_cache import PlanCache

    g = random_dag(rng, 8)
    fam = all_lower_sets(g)
    cache = PlanCache(cache_dir=str(tmp_path))
    key_plain = cache.key_for(g, 10.0, "exact", "time_centric")
    key_legacy = cache.key_for(g, 10.0, "exact", "time_centric",
                               strategy=LEGACY.digest_token())
    # {store, recompute} must not perturb legacy content addresses
    assert LEGACY.digest_token() == ""
    assert key_plain.content_hash() == key_legacy.content_hash()
    key_strat = cache.key_for(g, 10.0, "exact", "time_centric",
                              strategy=CFG.digest_token())
    assert key_strat.content_hash() != key_plain.content_hash()
    # distinct bandwidths → distinct addresses
    cfg2 = dataclasses.replace(CFG, offload_bytes_per_sec=8.0)
    key_strat2 = cache.key_for(g, 10.0, "exact", "time_centric",
                               strategy=cfg2.digest_token())
    assert key_strat2.content_hash() != key_strat.content_hash()

    # assignment round-trips through the store (memory + disk tiers)
    b = min_feasible_budget_exact(g, fam, strategies=CFG)
    res = solve(g, b, fam, objective="time_centric", strategies=CFG)
    key = cache.key_for(g, b, "exact", "time_centric",
                        strategy=CFG.digest_token())
    cache.put(g, key, res)
    got = cache.get(g, key)
    assert got is not None
    assert got.sequence == res.sequence
    assert got.assignment == res.assignment
    assert got.overhead == res.overhead
    # cold read (disk tier only)
    cold = PlanCache(cache_dir=str(tmp_path))
    got2 = cold.get(g, key)
    assert got2 is not None and got2.assignment == res.assignment


def test_planner_strategy_plans_end_to_end(rng, tmp_path):
    from repro.core.plan_cache import PlanCache
    from repro.core.planner import Planner

    g = random_dag(rng, 8)
    pl_leg = Planner(cache=PlanCache(cache_dir=str(tmp_path / "a")))
    pl_str = Planner(cache=PlanCache(cache_dir=str(tmp_path / "b")),
                     strategies=CFG)
    b_leg = pl_leg.min_feasible_budget(g, "exact_dp")
    b_str = pl_str.min_feasible_budget(g, "exact_dp")
    assert b_str <= b_leg
    for objective in ("time_centric", "memory_centric", "wallclock"):
        rep = pl_str.plan(g, b_str, "exact_dp", objective)
        assert rep.plan is not None
        assert rep.plan.peak_memory <= b_str * (1 + 1e-12)
    # a names-only spec of {store, recompute} normalizes to legacy planning
    pl_norm = Planner(cache=PlanCache(str(tmp_path / "c")),
                      strategies=("store", "recompute"))
    assert pl_norm.strategies is None


def test_wallclock_joint_pool_never_slower(rng):
    """Extended wallclock ranks legacy + strategy terminals jointly, so the
    winner's replayed seconds are ≤ the legacy winner's."""
    from repro.core.dp import solve_wallclock
    from repro.core.replay import replay

    for _ in range(5):
        g = random_dag(rng, rng.randint(5, 9))
        fam = all_lower_sets(g)
        b = min_feasible_budget_exact(g, fam)  # legacy-feasible budget
        if b == dp.INF:
            continue
        for budget in (b, b * 1.5):
            r_leg = solve_wallclock(g, budget, fam)
            r_ext = solve_wallclock(g, budget, fam, strategies=CFG)
            p_leg = make_plan(g, r_leg.sequence)
            p_ext = make_plan(g, r_ext.sequence, assignment=r_ext.assignment,
                              strategies=CFG)
            s_leg = replay(g, p_leg, budget=budget).seconds
            s_ext = replay(g, p_ext, budget=budget, strategies=CFG).seconds
            assert s_ext <= s_leg


def test_blockgraph_backends_reject_strategy_plans(rng):
    from repro.core.lowering.interpreter import InterpreterLowering
    from repro.core.lowering.policy import PolicyLowering

    g = random_dag(rng, 6)
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, fam, strategies=CFG)
    res = solve(g, b, fam, objective="time_centric", strategies=CFG)
    plan = make_plan(g, res.sequence, assignment=res.assignment, strategies=CFG)
    if not plan.strategy:
        pytest.skip("no strategy node selected at this mfb")

    class _FakeBlockCarrier:
        pass

    from repro.core.lowering import carriers

    fake = carriers.BlockGraphCarrier.__new__(carriers.BlockGraphCarrier)
    with pytest.raises(NotImplementedError):
        PolicyLowering().lower(fake, plan)
    with pytest.raises(NotImplementedError):
        InterpreterLowering().lower(fake, plan)
