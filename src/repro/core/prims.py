"""Single source of truth for primitive classification tables.

The jaxpr extractor (``core.jaxpr_graph``) and the measured cost model
(``core.cost_model``) both need to answer "what kind of work is this node?"
— heavy vs light for the paper's 10/1 cost model, compute- vs memory-bound
for calibration, call-like vs leaf for recursion.  These tables used to be
duplicated across the two modules; they live here once, and both import
them (the old module attributes remain as aliases for compatibility).
"""

from __future__ import annotations

#: Primitives whose cost dominates a graph under the paper's 10/1 model:
#: the dot/conv family plus the call-like wrappers that may contain them.
HEAVY_PRIMS = frozenset({
    "dot_general",
    "conv_general_dilated",
    "ragged_dot",
    "scan",
    "while",
    "pjit",
    "closed_call",
    "custom_vjp_call",
    "custom_jvp_call",
    "remat",
    "checkpoint",
})

#: The dot/conv leaf primitives themselves (heavy without looking inside).
MATMUL_PRIMS = frozenset({"dot_general", "conv_general_dilated", "ragged_dot"})

#: Layout/view primitives that move no FLOPs worth modelling.
ELEMENTWISE_FREE = frozenset({
    "broadcast_in_dim",
    "reshape",
    "squeeze",
    "transpose",
    "convert_element_type",
    "slice",
    "dynamic_slice",
    "concatenate",
})

#: Call-like primitives whose cost lives in an inner jaxpr; FLOP/byte
#: accounting recurses into these (scan multiplies by trip count).
HIGHER_ORDER_PRIMS = frozenset({
    "pjit",
    "closed_call",
    "custom_vjp_call",
    "custom_jvp_call",
    "remat",
    "remat2",
    "checkpoint",
    "scan",
    "while",
    "cond",
})

#: ``eqn.params`` keys under which an inner (closed) jaxpr may hide.
INNER_JAXPR_KEYS = (
    "jaxpr",
    "call_jaxpr",
    "cond_jaxpr",
    "body_jaxpr",
    "branches",
)

#: PRNG-consuming primitives.  JAX's functional PRNG makes them
#: deterministic given the same key operand, but a plan that *recomputes*
#: one re-derives random bits during the backward pass — a silent numerics
#: hazard the effect analysis (``repro.analysis``) pins out of plans.
PRNG_PRIMS = frozenset({
    "threefry2x32",
    "random_seed",
    "random_wrap",
    "random_unwrap",
    "random_bits",
    "random_fold_in",
    "random_split",
    "random_gamma",
    "random_clone",
    "rng_bit_generator",
    "rng_uniform",
})

#: Primitives whose backward rule is user-defined: the remat twin replays
#: their forward, but nothing structural proves the replay agrees with the
#: residuals the custom VJP expects — effect analysis treats them as opaque
#: and pins their (storable) outputs.  ``pallas_call`` belongs here too:
#: a hand-written kernel (e.g. ``kernels/flash_attention.py``) is a black
#: box to the taint walker — it may carry scratch semantics, input aliasing
#: or nondeterministic reductions the jaxpr does not expose, so its outputs
#: must be pinned rather than silently treated as pure.
OPAQUE_PRIMS = frozenset({
    "custom_vjp_call",
    "custom_vjp_call_jaxpr",
    "custom_lin",
    "pallas_call",
})

#: ``eqn.params`` keys the *effect walker* recurses into — the FLOP
#: accounting's keys plus ``fun_jaxpr`` (where ``custom_vjp_call_jaxpr``
#: hides its primal body).
EFFECT_INNER_JAXPR_KEYS = INNER_JAXPR_KEYS + ("fun_jaxpr",)

#: Node kinds priced as compute-bound matmul-class work by the measured
#: cost model (``time`` field = FLOPs).
MATMUL_KINDS = frozenset({
    "dot_general",
    "conv_general_dilated",
    "ragged_dot",
    "unit",  # launch.plan.chain_graph interior nodes (FLOPs in `time`)
    "matmul",
    "conv",
})

#: Node kinds priced at the attention kernel's achieved rate.
ATTENTION_KINDS = frozenset({"attention", "flash_attention", "custom_vjp_call"})
