"""Graph carriers: the model representations the planning pipeline accepts.

A *carrier* pairs a computation with enough structure to (a) extract the
paper's ``core.Graph`` for the Planner and (b) be re-executed under a plan
by the lowering backends.  Two carriers cover the framework:

* :class:`BlockGraphCarrier` — the layer-granularity model DAG
  (``core.blockgraph.BlockGraph``) plus a loss over its outputs.  Node =
  block; the production lowering is the checkpoint-policy backend.
* :class:`TracedCarrier` — **any JAX callable**, traced to a jaxpr on
  example arguments (``core.jaxpr_graph``).  Node = jaxpr equation; the
  production lowering tags equation outputs with ``checkpoint_name`` and
  saves exactly the plan's cache set.

Both expose the same minimal surface: ``to_graph()`` (planner input),
``node_names()`` (checkpoint names, index-aligned with graph nodes) and
``default_backend``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax

from ..graph import Graph
from ..jaxpr_graph import JaxprGraph, from_jaxpr


@dataclasses.dataclass
class BlockGraphCarrier:
    """A ``BlockGraph`` bound to concrete params/inputs and a loss.

    The lowered callables take ``(params, inputs)`` — fresh values of the
    same shapes — and return ``(loss, param_grads)``.  With ``mesh`` (a
    ``Mesh`` or a plain ``{axis: size}`` dict) blocks carrying an
    ``out_sharding`` annotation are budgeted at per-device bytes.
    """

    bg: Any  # core.blockgraph.BlockGraph (kept untyped to avoid a cycle)
    loss_fn: Callable[..., jax.Array]
    params: Any
    inputs: Dict[str, Any]
    cost_model: str = "paper"
    mesh: Any = None

    default_backend = "policy"

    def to_graph(self) -> Graph:
        return self.bg.to_graph(self.params, self.inputs,
                                cost_model=self.cost_model, mesh=self.mesh)

    def node_names(self) -> List[str]:
        return [b.name for b in self.bg.blocks]


def _tree_flatten(args):
    return jax.tree_util.tree_flatten(args)


def is_drop_var(v) -> bool:
    """True for jaxpr DropVar outputs (placeholders with no uses)."""
    return type(v).__name__ == "DropVar"


def _flat_arg_specs(args: Sequence[Any], in_shardings) -> Tuple:
    """Flatten a per-positional-arg sharding description to per-leaf specs.

    ``in_shardings`` is None (all replicated) or a sequence aligned with the
    positional args; each entry is None, a single PartitionSpec /
    NamedSharding applied to every leaf of that argument, or a pytree of
    specs matching the argument's structure exactly.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def is_spec(x):
        return x is None or isinstance(x, (PartitionSpec, NamedSharding))

    def norm(s):
        from repro.parallel.sharding import normalize_spec

        return normalize_spec(s)

    if in_shardings is None:
        n = sum(len(_tree_flatten(a)[0]) for a in args)
        return (PartitionSpec(),) * n
    if len(in_shardings) != len(args):
        raise ValueError(
            f"in_shardings has {len(in_shardings)} entries for "
            f"{len(args)} positional arguments"
        )
    out: List[Any] = []
    for a, sh in zip(args, in_shardings):
        leaves, tree = _tree_flatten(a)
        if is_spec(sh):
            out.extend([norm(sh)] * len(leaves))
            continue
        sh_leaves, sh_tree = jax.tree_util.tree_flatten(sh, is_leaf=is_spec)
        if sh_tree != tree:
            raise ValueError(
                "in_shardings entry does not match the argument's pytree "
                f"structure ({sh_tree} != {tree})"
            )
        out.extend(norm(s) for s in sh_leaves)
    return tuple(out)


@dataclasses.dataclass
class TracedCarrier:
    """Any JAX callable, traced on example arguments.

    ``fn`` must return a scalar (``jax.value_and_grad`` semantics); the
    lowered callables take the same positional arguments (same pytree
    structure and avals) and return ``(value, grads)`` w.r.t. ``argnums``.

    With ``mesh`` + ``in_shardings`` the trace is **sharding-aware**: node
    ``M_v`` is per-device bytes (shardings propagated through the jaxpr,
    conservative replicated fallback), the budget the planner enforces is
    per-device, and the lowered twin re-applies the caller's shardings so
    it stays pjit-composable.
    """

    fn: Callable[..., jax.Array]
    argnums: Union[int, Tuple[int, ...]]
    cost_model: str
    closed: Any  # ClosedJaxpr of the flattened function
    in_tree: Any  # treedef of the args tuple
    flat_avals: Tuple[jax.ShapeDtypeStruct, ...]
    arg_slices: Tuple[Tuple[int, int], ...]  # flat-leaf span per position arg
    jg: JaxprGraph
    mesh: Any = None  # jax.sharding.Mesh | {axis: size} dict | None
    in_specs: Optional[Tuple] = None  # flat per-leaf PartitionSpecs
    #: repro.analysis.effects.EffectAnalysis when traced with
    #: ``analyze_effects=True`` (None otherwise)
    effects: Any = None

    default_backend = "jaxpr"

    @classmethod
    def trace(
        cls,
        fn: Callable[..., jax.Array],
        args: Sequence[Any],
        argnums: Union[int, Tuple[int, ...]] = 0,
        cost_model: str = "paper",
        mesh: Any = None,
        in_shardings: Optional[Sequence[Any]] = None,
        analyze_effects: bool = False,
    ) -> "TracedCarrier":
        flat, in_tree = _tree_flatten(tuple(args))
        # flat-leaf span of each positional argument (interpreter backward)
        slices = []
        start = 0
        for a in args:
            leaves, _ = _tree_flatten(a)
            slices.append((start, start + len(leaves)))
            start += len(leaves)

        def flat_fn(*flat_args):
            return fn(*jax.tree_util.tree_unflatten(in_tree, flat_args))

        closed = jax.make_jaxpr(flat_fn)(*flat)
        outvars = closed.jaxpr.outvars
        if len(outvars) != 1 or getattr(outvars[0].aval, "shape", ()) != ():
            raise TypeError(
                "plan_function requires a scalar-output function "
                "(jax.value_and_grad semantics); got "
                f"{len(outvars)} outputs"
            )
        in_specs = None
        if mesh is not None:
            in_specs = _flat_arg_specs(args, in_shardings)
        jg = from_jaxpr(closed, cost_model=cost_model, mesh=mesh,
                        in_shardings=in_specs)
        effects = None
        if analyze_effects:
            # effect/determinism pass: classify equations, derive must_store
            # pins on the storable frontier of any taint, and rebuild the
            # graph with the pins applied so the planner treats them as hard
            # store-only constraints (and plan-cache digests diverge from
            # the unpinned variant)
            from repro.analysis.effects import (
                analyze_effects as _analyze,
                pin_graph,
            )

            effects = _analyze(jg)
            if effects.pins:
                jg = dataclasses.replace(
                    jg, graph=pin_graph(jg.graph, effects.pins)
                )
        return cls(
            fn=fn,
            argnums=argnums,
            cost_model=cost_model,
            closed=closed,
            in_tree=in_tree,
            flat_avals=tuple(
                jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype)
                for v in closed.jaxpr.invars
            ),
            arg_slices=tuple(slices),
            jg=jg,
            mesh=mesh,
            in_specs=in_specs,
            effects=effects,
        )

    def to_graph(self) -> Graph:
        return self.jg.graph

    def node_names(self) -> List[str]:
        return [nd.name for nd in self.jg.graph.nodes]

    def flatten_args(self, args: Sequence[Any]) -> List[Any]:
        """Flatten call-time args, checking the traced structure."""
        flat, tree = _tree_flatten(tuple(args))
        if tree != self.in_tree:
            raise TypeError(
                "argument structure differs from the traced example "
                f"({tree} != {self.in_tree})"
            )
        return flat

    def constrain(self, flat: Sequence[Any]) -> List[Any]:
        """Pin flat args to the caller's shardings (identity when untraced
        without a concrete Mesh — a plain axis-size dict carries no devices,
        so it informs the *accounting* but cannot constrain execution)."""
        from jax.sharding import Mesh, NamedSharding

        if self.mesh is None or self.in_specs is None or not isinstance(
            self.mesh, Mesh
        ):
            return list(flat)
        return [
            jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, sp)
            )
            for x, sp in zip(flat, self.in_specs)
        ]


def abstract_signature(args: Sequence[Any]) -> Tuple:
    """Hashable (treedef, avals) key of a call's arguments — the memo key
    under which ``plan_function`` caches one traced/planned lowering."""
    flat, tree = _tree_flatten(tuple(args))
    avals = tuple(
        (tuple(getattr(x, "shape", ())), str(getattr(x, "dtype", type(x))))
        for x in flat
    )
    return (tree, avals)
