"""The ``Lowering`` interface: one plan, many executable forms.

A *lowering backend* turns ``(carrier, ExecutionPlan)`` into a runnable
``value_and_grad`` twin of the carried computation.  The three execution
paths the framework grew historically — the paper-faithful segment
interpreter (old ``core.executor``), the ``jax.checkpoint`` +
``save_only_these_names`` policy lowering and the per-segment checkpoint
grouping (old ``core.remat`` / ``BlockGraph.apply_planned``) — are
registered backends of this one interface, joined by the jaxpr-level
backend that lowers plans for *traced* functions.

Backends register under a short name (``"interpreter"``, ``"policy"``,
``"segment"``, ``"jaxpr"``); ``resolve_backend(name, carrier)`` picks the
right one, with ``"auto"`` selecting each carrier's production path.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, Dict, List

from ..schedule import ExecutionPlan


class InfeasibleBudgetError(ValueError):
    """No canonical strategy fits the requested budget (typed, so callers
    can distinguish infeasibility from configuration errors)."""


def reject_track_live(backend_name: str) -> None:
    """Shared guard for the XLA-owned backends (no host-visible buffers)."""
    raise ValueError(
        f"track_live is interpreter-only (XLA owns the buffers under the "
        f"{backend_name!r} backend)"
    )


def blockgraph_value_and_grad(fwd: Callable[..., Any],
                              loss_fn: Callable[..., Any]):
    """``jax.value_and_grad`` of ``loss_fn`` over a BlockGraph forward.

    Shared by the checkpoint-based BlockGraph backends: ``fwd(params,
    inputs)`` returns the model outputs (tuple or single value).
    """
    import jax

    def f(p, x):
        out = fwd(p, x)
        return loss_fn(*out) if isinstance(out, tuple) else loss_fn(out)

    return jax.value_and_grad(f)


class Lowering(abc.ABC):
    """One way of executing an :class:`ExecutionPlan`.

    ``lower`` returns a callable with the carrier's calling convention:

    * BlockGraph carrier — ``f(params, inputs) -> (loss, param_grads)``;
    * traced carrier     — ``f(*args) -> (value, grads)`` (like
      ``jax.value_and_grad(fn, argnums)``).

    ``track_live=True`` (interpreter only) appends a live-byte trace:
    ``f(...) -> (loss, grads, [(tag, bytes), ...])``.

    ``donate=True`` (XLA backends that support it: ``"jaxpr"``,
    ``"segment"``) jits the twin with donation hints for the
    non-differentiated arguments and attaches the per-segment
    dead-at-peak hints (see ``lowering.donation``); values and gradients
    are unchanged.
    """

    #: registry name, e.g. "interpreter"
    name: str = "?"

    @abc.abstractmethod
    def supports(self, carrier: Any) -> bool:
        """Whether this backend can lower plans for ``carrier``."""

    @abc.abstractmethod
    def lower(
        self, carrier: Any, plan: ExecutionPlan, track_live: bool = False,
        donate: bool = False,
    ) -> Callable[..., Any]:
        """Lower ``plan`` over ``carrier`` into a value_and_grad callable."""


def reject_donate(backend_name: str) -> None:
    """Shared guard for backends without an XLA jit boundary to hint."""
    raise ValueError(
        f"donate=True needs an XLA jit boundary; the {backend_name!r} "
        f"backend has none (use 'jaxpr' or 'segment')"
    )


_REGISTRY: Dict[str, Lowering] = {}


def register_lowering(backend: Lowering) -> Lowering:
    """Register a backend instance under ``backend.name`` (last wins)."""
    _REGISTRY[backend.name] = backend
    return backend


def get_lowering(name: str) -> Lowering:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown lowering backend {name!r}; "
            f"available: {sorted(_REGISTRY)}"
        ) from None


def available_backends(carrier: Any = None) -> List[str]:
    """Registered backend names (optionally those supporting ``carrier``)."""
    if carrier is None:
        return sorted(_REGISTRY)
    return sorted(n for n, b in _REGISTRY.items() if b.supports(carrier))


def resolve_backend(name: str, carrier: Any) -> Lowering:
    """``name`` or the carrier's production default for ``"auto"``."""
    if name == "auto":
        name = carrier.default_backend
    backend = get_lowering(name)
    if not backend.supports(carrier):
        raise ValueError(
            f"backend {name!r} does not support {type(carrier).__name__}; "
            f"use one of {available_backends(carrier)}"
        )
    return backend
