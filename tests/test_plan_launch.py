"""Launch-layer planning: the DP plan on the unit chain, its lowering to
scan segments, and the invariance of the loss/grads under any plan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.plan import (
    SegmentPlan,
    chain_graph,
    plan_inputs,
    plan_unit_segments,
    plan_with_microbatching,
    segments_from_result,
)
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


def test_plan_covers_all_units():
    for arch in ("stablelm-3b", "mistral-large-123b", "zamba2-2.7b", "xlstm-1.3b"):
        cfg = get_config(arch)
        sp, res = plan_with_microbatching(cfg, SHAPES["train_4k"], 16,
                                          model_shards=16)
        from repro.models.transformer import unit_pattern

        _, n_units = unit_pattern(cfg)
        assert sum(sp.sizes) == n_units
        assert len(sp.sizes) == len(sp.remat)
        assert res.feasible


def test_budget_monotone_in_microbatches():
    """More microbatches → smaller per-microbatch working set → feasibility."""
    cfg = get_config("mistral-large-123b")
    sp, res = plan_with_microbatching(cfg, SHAPES["train_4k"], 16, model_shards=16)
    assert res.feasible
    assert sp.n_micro >= 1


def test_ample_budget_means_no_remat():
    """With a huge budget the time-centric plan caches everything; only the
    chain's sink boundary node (never in any ∂(L), eq. 1) is recomputed."""
    cfg = get_config("stablelm-3b")
    sp, res = plan_unit_segments(
        cfg, SHAPES["train_4k"], 16, model_shards=16, budget=1e18
    )
    assert res.feasible and res.overhead <= 1.0  # ≤ one boundary T
    assert not any(sp.remat)


def test_tight_budget_means_remat():
    cfg = get_config("stablelm-3b")
    pi = plan_inputs(cfg, SHAPES["train_4k"], 16, model_shards=16)
    sp, res = plan_unit_segments(
        cfg, SHAPES["train_4k"], 16, model_shards=16,
        budget=pi.bytes_interior * 3.0,
    )
    if res.feasible:
        assert any(sp.remat)


def test_segments_from_result_roundtrip():
    """Sequence → (sizes, remat) is consistent with the chain structure."""
    cfg = get_config("phi4-mini-3.8b")
    pi = plan_inputs(cfg, SHAPES["train_4k"], 16, model_shards=16)
    g = chain_graph(pi)
    from repro.core import exact_dp, min_feasible_budget
    from repro.core.dp import quantize_times

    q = quantize_times(g, 32)
    B = min_feasible_budget(q, "exact_dp") * 1.5
    res = exact_dp(q, B)
    sizes, remat = segments_from_result(res, pi.n_units)
    assert sum(sizes) == pi.n_units
    assert all(s >= 1 for s in sizes)


@pytest.mark.parametrize(
    "plans",
    [
        [(None, None)],  # default √n
        [((2, 2, 2, 2), (True, True, True, True)),
         ((4, 4), (True, False)),
         ((1,) * 8, (False,) * 8),
         ((8,), (False,)),
         ((3, 3, 2), (True, False, True))],
    ],
)
def test_loss_invariant_under_any_plan(plans):
    """The paper's guarantee, end to end on the production model: every
    canonical strategy computes the SAME loss and gradients."""
    cfg = reduced(get_config("stablelm-3b"), n_layers=8)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = {
        "tokens": jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size),
    }
    ref = None
    for sizes, remat in plans:
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, segment_sizes=sizes,
                                 segment_remat=remat)
        )(params)
        flat = jnp.concatenate(
            [g.astype(jnp.float32).ravel() for g in jax.tree_util.tree_leaves(grads)]
        )
        if ref is None:
            ref = (loss, flat)
        else:
            np.testing.assert_allclose(loss, ref[0], rtol=1e-5)
            np.testing.assert_allclose(flat, ref[1], rtol=1e-4, atol=1e-6)


def test_long_context_uses_seq_shards():
    cfg = get_config("zamba2-2.7b")
    pi_local = plan_inputs(cfg, SHAPES["long_500k"], dp_shards=1, seq_shards=16,
                           model_shards=16)
    pi_full = plan_inputs(cfg, SHAPES["long_500k"], dp_shards=1, seq_shards=1,
                          model_shards=16)
    assert pi_local.bytes_boundary * 15 < pi_full.bytes_boundary
