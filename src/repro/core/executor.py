"""Deprecated shim — the interpreter now lives in ``core.lowering``.

The paper-faithful segment interpreter moved to
``core.lowering.interpreter`` as the ``"interpreter"`` backend of the
unified planning pipeline; ``planned_value_and_grad_under_budget`` is a
wrapper over ``repro.plan_function``.  This module re-exports the old
entry points for existing callers — new code should use::

    from repro.core.lowering import plan_function

    planned = plan_function(bg, budget, backend="interpreter", loss_fn=...)
"""

from __future__ import annotations

from .lowering.front_door import planned_value_and_grad_under_budget
from .lowering.interpreter import planned_value_and_grad, vanilla_value_and_grad

__all__ = [
    "planned_value_and_grad",
    "vanilla_value_and_grad",
    "planned_value_and_grad_under_budget",
]
