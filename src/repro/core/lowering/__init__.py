"""repro.core.lowering — one planning pipeline, many executable forms.

The unified execution subsystem of the framework:

    graph carriers (BlockGraph | traced JAX fn)
        → core.planner.Planner (plan cache + budget sweep)
        → ExecutionPlan
        → a registered Lowering backend
        → runnable value_and_grad

Backends (``base.register_lowering``):

* ``"interpreter"`` — §3 interpreted step by step; validation + live-byte
  audit (both carriers);
* ``"policy"``      — one ``jax.checkpoint`` + ``save_only_these_names``
  over named block outputs (BlockGraph production path);
* ``"segment"``     — per-segment ``jax.checkpoint`` (BlockGraph), whose
  layer-chain projection (``segment_groups``) drives the scan models;
* ``"jaxpr"``       — equation-level ``checkpoint_name`` tagging for any
  traced function (the trace-anything production path).

``plan_function`` is the front door; ``core.executor`` / ``core.remat``
remain as thin deprecation shims over this package.
"""

from .base import (
    InfeasibleBudgetError,
    Lowering,
    available_backends,
    get_lowering,
    register_lowering,
    resolve_backend,
)
from .carriers import BlockGraphCarrier, TracedCarrier, abstract_signature
from .front_door import (
    LoweredPlan,
    PlannedFunction,
    plan_function,
    planned_value_and_grad_under_budget,
)
from .interpreter import (
    planned_value_and_grad,
    traced_planned_value_and_grad,
    vanilla_value_and_grad,
)
from .policy import (
    apply_with_policy,
    plan_policy,
    tagged_eval,
    traced_value_and_grad,
)
from .segment import apply_segmented, even_groups, segment_groups

__all__ = [
    "InfeasibleBudgetError",
    "Lowering",
    "register_lowering",
    "get_lowering",
    "available_backends",
    "resolve_backend",
    "BlockGraphCarrier",
    "TracedCarrier",
    "abstract_signature",
    "plan_function",
    "PlannedFunction",
    "LoweredPlan",
    "planned_value_and_grad_under_budget",
    "planned_value_and_grad",
    "traced_planned_value_and_grad",
    "vanilla_value_and_grad",
    "apply_with_policy",
    "plan_policy",
    "tagged_eval",
    "traced_value_and_grad",
    "apply_segmented",
    "segment_groups",
    "even_groups",
]
