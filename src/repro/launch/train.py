"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full production stack end to end on whatever devices the host has:
config → DP remat plan (the unified pipeline: chain carrier → Planner →
segment lowering) → sharded train step → fault-tolerant loop
(checkpoint/restart, NaN guard, straggler hooks) over the synthetic
pipeline.  On a real TPU pod the same script runs under
``jax.distributed.initialize()`` with the production mesh; here the mesh is
host-sized.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import SHAPES, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import segment_plan
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.parallel.compat import set_mesh
from repro.train import TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config of the same family (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--plan-cache-dir", default=None,
                    help="on-disk recomputation-plan cache (restart = lookup)")
    ap.add_argument("--objective", default="time_centric",
                    choices=["time_centric", "memory_centric"])
    ap.add_argument("--no-plan", action="store_true",
                    help="disable the DP remat plan (vanilla remat fallback)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    if args.plan_cache_dir:
        from repro.core.plan_cache import set_default_cache_dir

        set_default_cache_dir(args.plan_cache_dir)

    segment_sizes = segment_remat = None
    if not args.no_plan:
        sp, res = segment_plan(cfg, shape, mesh, objective=args.objective)
        if sp is not None:
            segment_sizes, segment_remat = sp.sizes, sp.remat
            print(f"plan: {sp.n_segments} segments, remat "
                  f"{sum(s for s, r in zip(sp.sizes, sp.remat) if r)}/{sum(sp.sizes)}"
                  f" units, micro={sp.n_micro}, feasible={res.feasible}")

    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(p, batch):
        return model.loss(p, batch, segment_sizes=segment_sizes,
                          segment_remat=segment_remat)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    ))
    tc = TrainConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        plan_cache_dir=args.plan_cache_dir,
        log_every=max(1, args.steps // 20),
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                              total_steps=args.steps),
    )
    with set_mesh(mesh):
        tr = Trainer(loss_fn, params, tc, mesh=mesh)
        if tr.maybe_restore():
            print(f"restored from step {tr.step}")
        out = tr.run(iter(data))
        tr.close()
    print(f"done: step={out['step']} final_loss={out['final_loss']:.4f} "
          f"skipped={out['skipped']} stragglers={out['straggler_steps']}")
    return out


if __name__ == "__main__":
    main()
