"""Lowering conformance: does the lowered twin save exactly ``U_k``?

The ``"jaxpr"`` backend lowers a plan by tagging every equation's (inexact)
outputs with ``checkpoint_name`` and running the forward under one
``jax.checkpoint`` whose policy is ``save_only_these_names(U_k)``.  This
checker traces the *lowered* twin's own jaxpr and statically recovers the
set of residuals it will really save, two independent ways:

* **structurally** — every ``name`` equation in the differentiated trace is
  a tag; a tag that reappears *inside* the ``remat2`` equation is
  rematerialized, so a cached tag found there is a hard conformance error
  (the twin recomputes what the plan claims to save).  The converse is
  deliberately **not** an error: a tag absent from the remat body is either
  saved *or* dead for the backward (DCE), and the trace cannot tell those
  apart;
* **by policy** — the plan's ``save_only_these_names`` predicate applied to
  each tag directly must admit exactly the cached storable tags;
* **by reference** — when a deployed callable is passed in, its remat
  body's tag set must equal that of a freshly lowered twin of the *same*
  plan; a stale lowering (built from a different plan) rematerializes a
  different set and is caught in both directions.

Any drift between planner and lowering — a renamed node, a tag lost
through a transform, a policy built from a stale plan — shows up here
statically, before a single FLOP runs.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Set, Tuple

import jax

from ..core.schedule import ExecutionPlan
from .report import Report


def _tag_names(jaxpr: Any, out: Set[str]) -> None:
    """Collect ``checkpoint_name`` tags in ``jaxpr`` (recursively)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "name":
            out.add(eqn.params["name"])
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else (val,)
            for v in vals:
                inner = getattr(v, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _tag_names(inner, out)
                elif hasattr(v, "eqns"):
                    _tag_names(v, out)


def _remat_eqns(jaxpr: Any) -> Any:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("remat2", "remat") and eqn.params.get(
            "differentiated"
        ):
            yield eqn


def _trace_tags(
    carrier: Any, fn: Callable[..., Any], report: Report
) -> Optional[Tuple[Set[str], Set[str]]]:
    """Trace ``fn`` on the carrier's abstract signature.

    Returns ``(all_tags, recomputed_tags)`` — every ``checkpoint_name`` in
    the differentiated trace, and the subset appearing inside its ``remat``
    bodies.  Adds an error finding and returns None if the trace fails or
    contains no remat equation.
    """
    flat = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carrier.flat_avals]
    args = jax.tree_util.tree_unflatten(carrier.in_tree, flat)
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:  # trace failure is itself a finding
        report.add(
            "error",
            "lowering-untraceable",
            f"could not trace the lowered twin: {type(e).__name__}: {e}",
        )
        return None

    jaxpr = closed.jaxpr
    all_tags: Set[str] = set()
    _tag_names(jaxpr, all_tags)
    remats = list(_remat_eqns(jaxpr))
    if not remats:
        report.add(
            "error",
            "no-remat",
            "the differentiated trace contains no remat equation — the plan "
            "was not lowered through jax.checkpoint at all",
        )
        return None

    recomputed: Set[str] = set()
    for eqn in remats:
        inner = eqn.params.get("jaxpr")
        body = getattr(inner, "jaxpr", inner)
        if body is not None and hasattr(body, "eqns"):
            _tag_names(body, recomputed)
    return all_tags, recomputed


def check_lowering(
    carrier: Any,
    plan: ExecutionPlan,
    lowered: Optional[Callable[..., Any]] = None,
) -> Report:
    """Statically verify the lowered twin's save-set against ``plan``.

    ``carrier`` must be a traced carrier (``TracedCarrier``); for other
    carriers the check is not applicable and the report says so.
    ``lowered`` overrides the callable to inspect (default: the ``"jaxpr"``
    backend's ``traced_value_and_grad(carrier, plan)``) — pass the actual
    deployed callable to detect drift between it and the plan.
    """
    from ..core.lowering.carriers import TracedCarrier
    from ..core.lowering.policy import plan_policy, traced_value_and_grad

    report = Report(checker="lowering")
    if not isinstance(carrier, TracedCarrier):
        report.add(
            "info",
            "not-applicable",
            f"conformance checking needs a traced carrier "
            f"(got {type(carrier).__name__}); the interpreter backend "
            "validates itself at runtime instead",
        )
        return report

    names = carrier.node_names()
    user_lowered = lowered is not None
    if lowered is None:
        lowered = traced_value_and_grad(carrier, plan)

    traced = _trace_tags(carrier, lowered, report)
    if traced is None:
        return report
    all_tags, recomputed = traced

    # Expected save-set: cached nodes whose outputs the tagger can name.
    from .effects import _storable

    expected: Set[str] = set()
    for v in sorted(plan.cached):
        if _storable(carrier.jg.eqns[v]):
            expected.add(names[v])
        else:
            report.add(
                "warning",
                "cached-untaggable",
                f"{names[v]} is in the plan's cache set but its outputs are "
                "not inexact-dtype — the policy lowering cannot save it, so "
                "it will be rematerialized despite the plan",
                node=v,
            )

    # The sound structural direction: a cached tag found inside the remat
    # body is rematerialized by the twin — a direct plan violation.  (A tag
    # *absent* from the body may be saved or simply dead for the backward;
    # the reference comparison below disambiguates when it matters.)
    remade = sorted(expected & recomputed)
    if remade:
        report.add(
            "error",
            "residual-not-saved",
            f"plan caches {remade} but the lowered twin rematerializes "
            "them inside its remat body — planner↔lowering drift",
        )

    if user_lowered:
        # Reference comparison: lower the *same* plan freshly and demand the
        # deployed callable rematerializes exactly the same tag set.  JAX's
        # DCE is applied identically to both traces, so any difference means
        # the callable was built from a different plan.
        ref = _trace_tags(carrier, traced_value_and_grad(carrier, plan), report)
        if ref is not None:
            _, ref_recomputed = ref
            if recomputed != ref_recomputed:
                report.add(
                    "error",
                    "remat-set-mismatch",
                    "the deployed callable rematerializes "
                    f"{sorted(recomputed - ref_recomputed)} beyond and omits "
                    f"{sorted(ref_recomputed - recomputed)} of what this "
                    "plan's own lowering rematerializes — it was lowered "
                    "from a different (stale?) plan",
                )

    # Independent cross-check: apply the plan's policy predicate directly.
    try:
        from jax._src.ad_checkpoint import name_p  # noqa: PLC2701

        policy = plan_policy(plan, names)
        saved_policy = {
            t for t in all_tags if policy(name_p, name=t)
        }
        if saved_policy != (expected & all_tags):
            report.add(
                "error",
                "policy-mismatch",
                f"save_only_these_names admits {sorted(saved_policy)} but "
                f"the plan expects {sorted(expected & all_tags)}",
            )
    except ImportError:  # pragma: no cover — private JAX surface moved
        report.add(
            "info",
            "policy-check-skipped",
            "jax._src.ad_checkpoint.name_p unavailable; structural check "
            "only",
        )

    if report.ok and not report.findings:
        report.add(
            "info",
            "conformant",
            f"lowered twin saves exactly the plan's {len(expected)} "
            "storable cached residuals",
        )
    return report
