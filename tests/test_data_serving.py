"""Data pipeline determinism/partition properties + serving engine."""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM, global_batch_for_test
from repro.models import build_model
from repro.serving import Engine


# ------------------------------------------------------------------- data


def test_determinism():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    a = SyntheticLM(cfg).batch(7)
    b = SyntheticLM(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    # labels[t] == tokens[t+1] within the shared underlying stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 50))
def test_host_partition_property(num_hosts, step):
    """Host slices partition the global batch; different hosts differ."""
    gb = 4 * num_hosts
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=gb,
                     num_hosts=num_hosts)
    full = global_batch_for_test(cfg, step)
    assert full["tokens"].shape == (gb, 8)
    if num_hosts > 1:
        h0 = SyntheticLM(dataclasses.replace(cfg, host_id=0)).batch(step)
        h1 = SyntheticLM(dataclasses.replace(cfg, host_id=1)).batch(step)
        assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_different_steps_differ():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    ds = SyntheticLM(cfg)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


def test_indivisible_hosts_rejected():
    with pytest.raises(ValueError):
        SyntheticLM(DataConfig(vocab_size=8, seq_len=4, global_batch=3,
                               num_hosts=2))


# ---------------------------------------------------------------- serving


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("stablelm-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_all_requests_complete(engine_setup):
    cfg, model, params = engine_setup
    eng = Engine(model, params, max_slots=3, max_seq=64)
    uids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(7)]
    done = eng.run()
    assert sorted(r.uid for r in done) == sorted(uids)
    assert all(len(r.output) == 4 for r in done)


def test_greedy_decode_deterministic(engine_setup):
    cfg, model, params = engine_setup
    outs = []
    for _ in range(2):
        eng = Engine(model, params, max_slots=2, max_seq=64)
        eng.submit([5, 6, 7, 8], max_new_tokens=6)
        done = eng.run()
        outs.append(done[0].output)
    assert outs[0] == outs[1]


def test_slot_reuse_isolated(engine_setup):
    """The same prompt served before/after other traffic must produce the
    same greedy output — slot state (KV + recurrent) is fully reset."""
    cfg, model, params = engine_setup
    eng = Engine(model, params, max_slots=1, max_seq=64)
    eng.submit([9, 9, 9], max_new_tokens=5)
    eng.submit([1, 2, 3, 4, 5], max_new_tokens=5)
    eng.submit([9, 9, 9], max_new_tokens=5)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert done[0].output == done[2].output


def test_ssm_slot_reuse_isolated():
    cfg = reduced(get_config("zamba2-2.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params, max_slots=1, max_seq=32)
    eng.submit([3, 1, 4], max_new_tokens=4)
    eng.submit([2, 7, 1, 8], max_new_tokens=4)
    eng.submit([3, 1, 4], max_new_tokens=4)
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert done[0].output == done[2].output


def test_eos_terminates(engine_setup):
    cfg, model, params = engine_setup
    eng = Engine(model, params, max_slots=1, max_seq=64)
    # find greedy first token, then use it as eos
    eng.submit([1, 2], max_new_tokens=8)
    first = eng.run()[0].output[0]
    eng2 = Engine(model, params, max_slots=1, max_seq=64)
    eng2.submit([1, 2], max_new_tokens=8, eos_id=first)
    out = eng2.run()[0]
    assert out.output == [first]


def test_engine_prewarm_makes_first_planned_step_warm(engine_setup):
    """ISSUE-8 acceptance: boot-time sweep pre-warm means the engine's
    first planned call at an expected signature re-runs no DP."""
    from repro.configs import SHAPES
    from repro.core import get_default_planner
    from repro.launch.plan import plan_unit_segments

    cfg, model, params = engine_setup
    shape = SHAPES["decode_32k"]
    planner = get_default_planner()
    eng = Engine(model, params, max_slots=1, max_seq=32,
                 prewarm_shapes=[shape])
    # warmed at boot → the first planned step at this signature is a
    # frontier lookup: zero new plan-cache misses
    before = planner.cache.stats()["misses"]
    sp, res = plan_unit_segments(cfg, shape, dp_shards=1, model_shards=1,
                                 budget=1e18)  # full sweep covers any B
    assert res.feasible
    assert planner.cache.stats()["misses"] == before
    # and a replica pre-warming the same signature reports already-warm
    assert eng.prewarm_plans([shape]) == {shape.name: True}
