"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the slot-based continuous-batching engine on a (reduced) model and
drives a batch of synthetic requests through it, reporting throughput.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import Engine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-cache-dir", default=None,
                    help="shared on-disk recomputation-plan cache")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    kw = {}
    if cfg.encoder_decoder:
        kw["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.slots, cfg.frontend_seq, cfg.d_model)
        )
    eng = Engine(model, params, max_slots=args.slots, max_seq=args.max_seq,
                 plan_cache_dir=args.plan_cache_dir, **kw)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 32)).tolist()
        eng.submit(prompt, max_new_tokens=args.new_tokens,
                   temperature=args.temperature)

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    return done


if __name__ == "__main__":
    main()
