"""Textual HLO / StableHLO parsing shared by the dry-run and the HLO checkers.

XLA's compiled artifacts are exposed to Python as *text* (``lowered.as_text()``
is StableHLO, ``compiled.as_text()`` is post-optimization HLO); this module is
the one place that text is parsed.  It grew out of ``launch/dryrun.py``'s
collective-bytes accounting and now also serves ``analysis.hlo``:

* :func:`split_computations` — module text → per-computation instruction lines
  (plus the ``"__entry__"`` marker);
* :func:`computation_multipliers` — trip-count-aware execution multiplier per
  computation: a while body (``jax.lax.scan`` lowers to while) executes once
  per iteration, read from its condition's compare constant, and the caller
  chain (``calls=`` / ``to_apply=`` / ``condition=`` / ``body=`` /
  ``branch_computations=``) propagates multipliers into fusions and nested
  loops;
* :func:`collective_bytes` — per-chip collective byte totals (the dry-run's
  roofline input);
* :func:`count_ops` / :func:`count_heavy_ops` — trip-aware instruction counts
  (the remat-conformance checker's heavy-op multiplicity);
* :func:`reduce_precision_count` — identity-format ``reduce-precision`` ops,
  the marker ``jax.checkpoint``'s ``save_only_these_names`` policy leaves on
  every saved residual (both HLO and StableHLO spellings).

Pure stdlib — no jax import — so it stays cheap to unit-test and safe to use
from the lint CLI before any backend initializes.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Set, Tuple

DTYPE_BYTES: Dict[str, int] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES: Tuple[str, ...] = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

#: dot/conv instruction spellings in post-optimization HLO.  ``custom-call``
#: is matched only when its target names a matmul/conv library routine (see
#: ``_HEAVY_TARGET``), so plain host callbacks never count as heavy.
HEAVY_OPCODES: Tuple[str, ...] = ("dot", "convolution")

_HEAVY_TARGET = re.compile(r"(dot|conv|gemm|matmul)", re.IGNORECASE)

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLSITE_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

# reduce-precision spellings.  HLO text puts the attributes after the operand
# list; StableHLO encodes them as ``format = e<exp>m<man>``.
_RP_HLO_RE = re.compile(
    r"reduce-precision\(.*?\),.*?exponent_bits=(\d+),\s*mantissa_bits=(\d+)"
)
_RP_STABLE_RE = re.compile(r"stablehlo\.reduce_precision.*?e(\d+)m(\d+)")

#: (exponent_bits, mantissa_bits) pairs that change no bits for their dtype —
#: the identity ``reduce_precision`` jax's checkpoint policy uses as a
#: save-this-residual marker (f32, f16, bf16, f64).
IDENTITY_EM: Set[Tuple[int, int]] = {(8, 23), (5, 10), (8, 7), (11, 52)}


def shape_bytes(tok: str) -> int:
    """Byte size of one HLO shape token like ``f32[8,128]`` (0 if unparsable)."""
    m = SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Module text → {computation name: instruction lines}.

    The entry computation's name is additionally stored under the
    ``"__entry__"`` key (as a single-element list), matching the historical
    dry-run contract.
    """
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m and (line.startswith("%") or line.startswith("ENTRY")):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    comps["__entry__"] = [entry]  # type: ignore[list-item]
    return comps


def _body_trips(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """while body name → trip count (from the condition's compare constant)."""
    trips: Dict[str, int] = {}
    for lines in comps.values():
        for s in lines:
            m = _WHILE_RE.search(s)
            if m:
                cond, body = m.groups()
                consts = [
                    int(c)
                    for c in _CONST_RE.findall("\n".join(comps.get(cond, [])))
                ]
                trips[body] = max(consts) if consts else 1
    return trips


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Execution-count multiplier per computation.

    A while body runs ``trip`` times per execution of its caller; every other
    callee (fusion ``calls=``, reducer ``to_apply=``, loop ``condition=``,
    ``branch_computations=``) runs once per caller execution.  Multipliers
    compose down the (acyclic) caller chain, so a fusion inside a scan body
    inherits the trip count — the piece a flat instruction sum drops.
    """
    trips = _body_trips(comps)
    parents: Dict[str, str] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for s in lines:
            for callee in _CALLSITE_RE.findall(s):
                if callee in comps:
                    parents.setdefault(callee, name)
            m = _BRANCHES_RE.search(s)
            if m:
                for tok in m.group(1).split(","):
                    callee = tok.strip().lstrip("%")
                    if callee in comps:
                        parents.setdefault(callee, name)

    def multiplier(name: str, seen: Optional[Set[str]] = None) -> int:
        seen = seen or set()
        if name in seen:
            return 1
        seen.add(name)
        parent = parents.get(name)
        if parent is None:
            return trips.get(name, 1)
        return trips.get(name, 1) * multiplier(parent, seen)

    return {
        name: multiplier(name) for name in comps if name != "__entry__"
    }


def count_ops(hlo_text: str, opcode: str) -> int:
    """Trip-count-aware occurrences of `` opcode(`` across all computations."""
    comps = split_computations(hlo_text)
    comps.pop("__entry__", None)
    mults = computation_multipliers(comps)
    total = 0
    needle = f" {opcode}("
    for name, lines in comps.items():
        mult = mults.get(name, 1)
        for s in lines:
            if needle in s:
                total += mult
    return total


def count_heavy_ops(hlo_text: str) -> int:
    """Trip-aware count of dot/conv work in an HLO module.

    ``dot`` + ``convolution`` instructions, plus ``custom-call``s whose
    target names a matmul/conv library routine (oneDNN, cuBLAS, cuDNN
    spellings all match ``_HEAVY_TARGET``).
    """
    comps = split_computations(hlo_text)
    comps.pop("__entry__", None)
    mults = computation_multipliers(comps)
    total = 0
    needles = tuple(f" {op}(" for op in HEAVY_OPCODES)
    for name, lines in comps.items():
        mult = mults.get(name, 1)
        for s in lines:
            if any(nd in s for nd in needles):
                total += mult
            elif " custom-call(" in s and "custom_call_target=" in s:
                target = s.split("custom_call_target=", 1)[1]
                if _HEAVY_TARGET.search(target.split(",", 1)[0]):
                    total += mult
    return total


def reduce_precision_count(text: str) -> int:
    """Identity-format ``reduce_precision`` ops in HLO or StableHLO text.

    jax's ``save_only_these_names`` checkpoint policy marks every saved
    residual with a bit-identical ``reduce_precision`` (e.g. f32 → e8m23);
    counting only :data:`IDENTITY_EM` formats keeps genuine user-requested
    precision reductions out of the materialization census.  HLO counts are
    trip-aware; StableHLO modules are flat single functions and counted flat.
    """
    total = 0
    if "stablehlo" in text:
        for m in _RP_STABLE_RE.finditer(text):
            if (int(m.group(1)), int(m.group(2))) in IDENTITY_EM:
                total += 1
        return total
    comps = split_computations(text)
    comps.pop("__entry__", None)
    mults = computation_multipliers(comps)
    for name, lines in comps.items():
        mult = mults.get(name, 1)
        for s in lines:
            m = _RP_HLO_RE.search(s)
            if m and (int(m.group(1)), int(m.group(2))) in IDENTITY_EM:
                total += mult
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-chip collective bytes from the post-SPMD HLO, **trip-count aware**.

    Collectives inside while bodies (jax.lax.scan lowers to while) execute
    once per iteration; a flat instruction sum undercounts them by the trip
    count.  Shapes in the partitioned module are already per-device.
    """
    comps = split_computations(hlo_text)
    comps.pop("__entry__", None)
    mults = computation_multipliers(comps)

    per_op: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    static_counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for name, lines in comps.items():
        mult = mults.get(name, 1)
        for s in lines:
            for coll in COLLECTIVES:
                if f" {coll}(" not in s and f" {coll}-start(" not in s:
                    continue
                head = s.split(f" {coll}", 1)[0]
                nbytes = sum(
                    shape_bytes(m.group(0)) for m in SHAPE_RE.finditer(head)
                )
                per_op[coll] += nbytes * mult
                counts[coll] += mult
                static_counts[coll] += 1
                break
    total = sum(per_op.values())
    return {
        "bytes_per_chip": per_op,
        "dynamic_counts": counts,
        "static_counts": static_counts,
        "total_bytes_per_chip": total,
    }
