"""Vectorized DP hot path vs the scalar oracles: bit-identity properties.

The vectorized paths (``liveness._excess_row``, ``dp._mfb_vec``,
``dp._solve_vec``, ``dp._sweep_vec``) must return *bit-identical* results
to the scalar loops retained behind ``REPRO_DP_SCALAR=1`` — same float
expressions, just batched.  These tests drive both paths over random DAGs
and compare every observable field, including ulp-adjacent budgets around
the exact feasibility threshold where a single-ulp drift flips a plan.
"""

import random

import numpy as np
import pytest

from repro.core import dp, liveness
from repro.core.dp import (
    Sweep,
    SweepOverflow,
    min_feasible_budget_exact,
    solve,
    sweep,
)
from repro.core.graph import to_mask
from repro.core.lower_sets import all_lower_sets

from conftest import random_dag

OBJECTIVES = ("time_centric", "memory_centric")


@pytest.fixture
def scalar_mode(monkeypatch):
    """Context toggles: run a callable under the scalar oracles."""

    def run(fn, *args, **kwargs):
        monkeypatch.setenv("REPRO_DP_SCALAR", "1")
        try:
            return fn(*args, **kwargs)
        finally:
            monkeypatch.delenv("REPRO_DP_SCALAR", raising=False)

    return run


def _fresh(g):
    """Drop per-graph memo state so each path prices from scratch."""
    liveness._EXCESS_MEMO.pop(g, None)
    dp._VEC_PREP.pop(g, None)


def _budget_grid(g, fam):
    """mfb plus ulp-adjacent probes around it and a loose budget."""
    b = min_feasible_budget_exact(g, family=fam)
    if b == dp.INF:
        return []
    return [
        b,
        np.nextafter(b, -np.inf),
        np.nextafter(b, np.inf),
        b * 1.5,
        b * 4.0,
    ]


def _dp_fields(r):
    return (r.sequence, r.overhead, r.peak_memory, r.feasible, r.states_visited)


@pytest.mark.parametrize("seed", range(12))
def test_excess_row_matches_scalar_walk(seed):
    r = random.Random(seed)
    g = random_dag(r, r.randint(3, 12))
    fam = all_lower_sets(g)
    infos = {i.mask: i for i in dp._prepare(g, fam)}
    masks = list(infos)
    for mask_L in masks:
        pairs = [
            (mp, infos[mp].boundary_mask)
            for mp in masks
            if mp != mask_L and (mask_L & mp) == mask_L
        ]
        if not pairs:
            continue
        want = [
            liveness._excess_scalar(g, mask_L, mp, bd) for mp, bd in pairs
        ]
        got = liveness._excess_row(g, mask_L, pairs).tolist()
        assert got == want  # bitwise: == on floats, no tolerance


@pytest.mark.parametrize("objective", OBJECTIVES)
@pytest.mark.parametrize("seed", range(8))
def test_solve_and_mfb_bit_identical(seed, objective, scalar_mode):
    r = random.Random(seed * 7 + 1)
    g = random_dag(r, r.randint(3, 10))
    fam = all_lower_sets(g)

    _fresh(g)
    b_vec = min_feasible_budget_exact(g, family=fam)
    _fresh(g)
    b_sca = scalar_mode(min_feasible_budget_exact, g, family=fam)
    assert b_vec == b_sca

    for budget in _budget_grid(g, fam):
        _fresh(g)
        rv = solve(g, budget, fam, objective=objective)
        _fresh(g)
        rs = scalar_mode(solve, g, budget, fam, objective=objective)
        assert _dp_fields(rv) == _dp_fields(rs)


@pytest.mark.parametrize("seed", range(8))
def test_feasible_bit_identical(seed, scalar_mode):
    r = random.Random(seed * 13 + 5)
    g = random_dag(r, r.randint(3, 10))
    fam = all_lower_sets(g)
    for budget in _budget_grid(g, fam):
        _fresh(g)
        fv = dp.feasible(g, budget, fam)
        _fresh(g)
        fs = scalar_mode(dp.feasible, g, budget, fam)
        assert fv == fs


@pytest.mark.parametrize("objective", OBJECTIVES)
@pytest.mark.parametrize("seed", range(6))
def test_sweep_encoding_bit_identical(seed, objective, scalar_mode):
    r = random.Random(seed * 31 + 2)
    g = random_dag(r, r.randint(3, 9))
    fam = all_lower_sets(g)
    _fresh(g)
    sv = sweep(g, fam, objective=objective)
    _fresh(g)
    ss = scalar_mode(sweep, g, fam, objective=objective)
    assert sv.encode() == ss.encode()

    # capped sweep + lazy extension, scalar and vectorized interleaved
    b = min_feasible_budget_exact(g, family=fam)
    if b == dp.INF:
        return
    cap = b * 1.25
    _fresh(g)
    cv = sweep(g, fam, objective=objective, cap=cap)
    _fresh(g)
    cs = scalar_mode(sweep, g, fam, objective=objective, cap=cap)
    assert cv.encode() == cs.encode()
    ev = cv.extend(g, cap=b * 3.0)
    es = scalar_mode(cs.extend, g, cap=b * 3.0)
    assert ev.encode() == es.encode()
    # mixed provenance: scalar base extended by the vectorized path
    em = cs.extend(g, cap=b * 3.0)
    assert em.encode() == ev.encode()


@pytest.mark.parametrize("seed", range(6))
def test_sweep_extract_matches_solve(seed):
    r = random.Random(seed * 5 + 3)
    g = random_dag(r, r.randint(3, 9))
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, family=fam)
    if b == dp.INF:
        return
    sw = sweep(g, fam)
    for budget in (b, np.nextafter(b, np.inf), b * 2.0):
        rv = sw.solve(g, budget)
        rd = solve(g, budget, fam)
        assert rv.sequence == rd.sequence
        assert rv.overhead == rd.overhead
        assert rv.peak_memory == rd.peak_memory


def test_sweep_overflow_message_parity(scalar_mode):
    r = random.Random(99)
    g = random_dag(r, 8)
    fam = all_lower_sets(g)
    msgs = []
    for runner in (
        lambda: sweep(g, fam, max_states=7),
        lambda: scalar_mode(sweep, g, fam, max_states=7),
    ):
        _fresh(g)
        with pytest.raises(SweepOverflow) as ei:
            runner()
        msgs.append(str(ei.value))
    assert msgs[0] == msgs[1]


def test_solve_seeds_memo_for_returned_plan():
    # the traceback must record the exact floats the budget filter used,
    # so peak_memory_live prices the returned plan with the same values
    r = random.Random(17)
    g = random_dag(r, 8)
    fam = all_lower_sets(g)
    _fresh(g)
    b = min_feasible_budget_exact(g, family=fam)
    res = solve(g, b, fam)
    assert res.feasible
    memo = liveness._EXCESS_MEMO.get(g)
    assert memo is not None
    prev = 0
    for L in res.sequence:
        mk = to_mask(L)
        assert (prev, mk) in memo
        prev = mk
    assert res.peak_memory <= b


@pytest.mark.parametrize("seed", range(6))
def test_wallclock_solve_bit_identical(seed, scalar_mode):
    """Close the scalar-oracle gap for objective='wallclock': the sweep +
    replay-ranked extraction must agree bit-for-bit across both paths."""
    r = random.Random(seed * 11 + 7)
    g = random_dag(r, r.randint(3, 9))
    fam = all_lower_sets(g)
    b = min_feasible_budget_exact(g, family=fam)
    if b == dp.INF:
        return
    for budget in (b, np.nextafter(b, np.inf), b * 1.5, b * 4.0):
        _fresh(g)
        rv = solve(g, budget, fam, objective="wallclock")
        _fresh(g)
        rs = scalar_mode(solve, g, budget, fam, objective="wallclock")
        assert _dp_fields(rv) == _dp_fields(rs)


@pytest.mark.parametrize("objective", OBJECTIVES + ("wallclock",))
@pytest.mark.parametrize("seed", range(6))
def test_store_recompute_restriction_is_legacy(seed, objective, scalar_mode):
    """A {store, recompute} strategy set is the paper's binary problem: the
    lattice entry points must return bit-identical results to the
    pre-lattice calls, vectorized and scalar alike (regression guard for
    the joint-DP refactor)."""
    from repro.core.strategies import StrategyConfig

    cfg = StrategyConfig(strategies=("store", "recompute"))
    assert not cfg.extended
    r = random.Random(seed * 17 + 3)
    g = random_dag(r, r.randint(3, 9))
    fam = all_lower_sets(g)

    _fresh(g)
    b_plain = min_feasible_budget_exact(g, family=fam)
    _fresh(g)
    b_cfg = min_feasible_budget_exact(g, family=fam, strategies=cfg)
    _fresh(g)
    b_sca = scalar_mode(min_feasible_budget_exact, g, family=fam,
                        strategies=cfg)
    assert b_plain == b_cfg == b_sca
    if b_plain == dp.INF:
        return

    for budget in (b_plain, np.nextafter(b_plain, np.inf), b_plain * 2.0):
        _fresh(g)
        r_plain = solve(g, budget, fam, objective=objective)
        _fresh(g)
        r_cfg = solve(g, budget, fam, objective=objective, strategies=cfg)
        _fresh(g)
        r_sca = scalar_mode(solve, g, budget, fam, objective=objective,
                            strategies=cfg)
        assert _dp_fields(r_plain) == _dp_fields(r_cfg) == _dp_fields(r_sca)
        assert r_cfg.assignment is None  # legacy results carry no lattice

        _fresh(g)
        assert dp.feasible(g, budget, fam) == dp.feasible(
            g, budget, fam, strategies=cfg
        )

    if objective == "wallclock":
        return  # sweeps below share the TC surface; nothing new to check
    _fresh(g)
    sw_plain = sweep(g, fam, objective=objective)
    _fresh(g)
    sw_cfg = sweep(g, fam, objective=objective, strategies=cfg)
    assert sw_plain.encode() == sw_cfg.encode()


def test_scalar_env_forces_oracle(monkeypatch):
    # REPRO_DP_SCALAR=1 must actually bypass the vectorized paths
    monkeypatch.setenv("REPRO_DP_SCALAR", "1")
    called = {"row": 0}
    orig = liveness._excess_row

    def spy(*a, **k):
        called["row"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(liveness, "_excess_row", spy)
    r = random.Random(3)
    g = random_dag(r, 6)
    fam = all_lower_sets(g)
    _fresh(g)
    solve(g, min_feasible_budget_exact(g, family=fam), fam)
    assert called["row"] == 0
