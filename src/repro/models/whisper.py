"""Whisper-style encoder-decoder backbone (audio frontend is a stub:
``input_specs`` supplies precomputed frame embeddings, per the assignment).

Encoder: bidirectional self-attention blocks over frames.
Decoder: causal self-attention + cross-attention over encoder output.
Learned positional embeddings on both sides (as Whisper).  The recomputation
plan applies jointly across encoder and decoder — cross-attention edges make
the graph non-chain, the paper's target case (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import shard
from . import attention as attn
from .layers import (
    _init_normal,
    embed,
    embedding_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    softmax_xent,
    unembed,
    unembed_init,
)
from .transformer import default_segments, scan_over_segments


def _enc_block_init(rng, cfg: ModelConfig):
    r1, r2 = jax.random.split(rng)
    d = cfg.d_model
    return {
        "ln1": layernorm_init(d),
        "attn": attn.attention_init(r1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "ln2": layernorm_init(d),
        "mlp": gelu_mlp_init(r2, d, cfg.d_ff),
    }


def _dec_block_init(rng, cfg: ModelConfig):
    r1, r2, r3 = jax.random.split(rng, 3)
    d = cfg.d_model
    return {
        "ln1": layernorm_init(d),
        "self_attn": attn.attention_init(
            r1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ),
        "ln_x": layernorm_init(d),
        "cross_attn": attn.attention_init(
            r2, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ),
        "ln2": layernorm_init(d),
        "mlp": gelu_mlp_init(r3, d, cfg.d_ff),
    }


def _cross_attention(p, x, enc_k, enc_v, cfg: ModelConfig):
    """x (B,S,D) queries against precomputed encoder K/V (B,T,KV,Dh)."""
    B, S, D = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt)).reshape(
        B, S, cfg.n_heads, cfg.head_dim
    )
    ctx = attn.dense_attention(q, enc_k, enc_v, causal=False)
    out = jnp.einsum(
        "bsz,zd->bsd",
        ctx.reshape(B, S, cfg.n_heads * cfg.head_dim),
        p["wo"].astype(dt),
    )
    return out


def _enc_kv(p, enc_out, cfg: ModelConfig):
    B, T, D = enc_out.shape
    dt = enc_out.dtype
    k = jnp.einsum("btd,dh->bth", enc_out, p["wk"].astype(dt)).reshape(
        B, T, cfg.n_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("btd,dh->bth", enc_out, p["wv"].astype(dt)).reshape(
        B, T, cfg.n_kv_heads, cfg.head_dim
    )
    return k, v


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.max_dec_pos = 65_536

    def init(self, rng: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        L = cfg.n_layers
        rngs = jax.random.split(rng, 2 * L + 4)
        enc = [_enc_block_init(rngs[i], cfg) for i in range(L)]
        dec = [_dec_block_init(rngs[L + i], cfg) for i in range(L)]
        return {
            "enc_pos": _init_normal(rngs[-1], (cfg.frontend_seq or 1500, cfg.d_model), 0.02),
            "encoder": jax.tree_util.tree_map(lambda *x: jnp.stack(x), *enc),
            "enc_norm": layernorm_init(cfg.d_model),
            "embedding": embedding_init(rngs[-2], cfg.vocab_size, cfg.d_model),
            "dec_pos": _init_normal(rngs[-3], (self.max_dec_pos, cfg.d_model), 0.02),
            "decoder": jax.tree_util.tree_map(lambda *x: jnp.stack(x), *dec),
            "dec_norm": layernorm_init(cfg.d_model),
            "head": unembed_init(rngs[-4], cfg.d_model, cfg.vocab_size),
        }

    # ----------------------------------------------------------- encoder

    def encode(self, params, frames: jax.Array, segment_sizes=None,
               segment_remat=None) -> jax.Array:
        """frames (B, T, D): precomputed conv-frontend output (stub)."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        T = frames.shape[1]
        h = frames.astype(dt) + params["enc_pos"][:T].astype(dt)[None]
        h = shard(h, "batch", None, "model")
        positions = jnp.arange(T)[None, :]

        def body(h, blk):
            a = attn.attention(
                blk["attn"],
                layernorm(blk["ln1"], h),
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim,
                rope_theta=0.0,
                positions=positions,
                causal=False,
            )
            h = h + a
            h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln2"], h))
            return shard(h, "batch", "seq_act", None), None

        h = scan_over_segments(
            h, params["encoder"], body, cfg.n_layers, segment_sizes, segment_remat
        )
        return layernorm(params["enc_norm"], h)

    # ----------------------------------------------------------- decoder

    def decode_train(
        self, params, tokens: jax.Array, enc_out: jax.Array, segment_sizes=None,
        segment_remat=None,
    ) -> jax.Array:
        cfg = self.cfg
        dt = cfg.activation_dtype
        B, S = tokens.shape
        h = embed(params["embedding"], tokens, dt) + params["dec_pos"][:S].astype(dt)[
            None
        ]
        h = shard(h, "batch", None, "model")
        positions = jnp.arange(S)[None, :]

        def body(h, blk):
            a = attn.attention(
                blk["self_attn"],
                layernorm(blk["ln1"], h),
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim,
                rope_theta=0.0,
                positions=positions,
            )
            h = h + a
            xk, xv = _enc_kv(blk["cross_attn"], enc_out, cfg)
            h = h + _cross_attention(
                blk["cross_attn"], layernorm(blk["ln_x"], h), xk, xv, cfg
            )
            h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln2"], h))
            return shard(h, "batch", "seq_act", None), None

        h = scan_over_segments(
            h, params["decoder"], body, cfg.n_layers, segment_sizes, segment_remat
        )
        h = layernorm(params["dec_norm"], h)
        return unembed(params["head"], h)

    def loss(self, params, batch: Dict[str, jax.Array], segment_sizes=None,
             segment_remat=None):
        enc_out = self.encode(params, batch["frames"], segment_sizes, segment_remat)
        logits = self.decode_train(
            params, batch["tokens"], enc_out, segment_sizes, segment_remat
        )
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # ------------------------------------------------------------- decode

    def init_caches(self, params, frames: jax.Array, max_seq: int):
        """Run the encoder once; precompute cross K/V; empty self caches."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        enc_out = self.encode(params, frames)
        B = frames.shape[0]

        def per_layer(blk):
            xk, xv = _enc_kv(blk["cross_attn"], enc_out, cfg)
            return {"xk": xk, "xv": xv}

        cross = jax.vmap(per_layer)(params["decoder"])
        self_kv = {
            "k": jnp.zeros(
                (cfg.n_layers, B, max_seq, cfg.n_kv_heads, cfg.head_dim), dt
            ),
            "v": jnp.zeros(
                (cfg.n_layers, B, max_seq, cfg.n_kv_heads, cfg.head_dim), dt
            ),
        }
        return {"cross": cross, "self": self_kv}

    def decode_step(self, params, tokens, caches, position):
        cfg = self.cfg
        dt = cfg.activation_dtype
        B = tokens.shape[0]
        pos_emb = jnp.take(params["dec_pos"], position, axis=0).astype(dt)[:, None, :]
        h = embed(params["embedding"], tokens, dt) + pos_emb

        def body(h, xs):
            blk, self_k, self_v, cross = xs
            a, nk, nv = attn.decode_attention(
                blk["self_attn"],
                layernorm(blk["ln1"], h),
                self_k,
                self_v,
                position,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim,
                rope_theta=0.0,
            )
            h = h + a
            h = h + _cross_attention(
                blk["cross_attn"],
                layernorm(blk["ln_x"], h),
                cross["xk"],
                cross["xv"],
                cfg,
            )
            h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln2"], h))
            return h, (nk, nv)

        h, (nk, nv) = jax.lax.scan(
            body,
            h,
            (params["decoder"], caches["self"]["k"], caches["self"]["v"], caches["cross"]),
        )
        h = layernorm(params["dec_norm"], h)
        logits = unembed(params["head"], h)
        return logits, {"cross": caches["cross"], "self": {"k": nk, "v": nv}}
