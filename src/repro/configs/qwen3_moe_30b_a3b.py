"""qwen3-moe-30b-a3b — MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936.
"""

from .base import ModelConfig, MoEConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,
        vocab_size=151936,
        d_head=128,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
        rope_theta=1_000_000.0,
    )
