"""Discrete-event replay: determinism, peak/overlap properties, wallclock
plan selection through dp / Planner / plan_function (ISSUE 9)."""

import pytest

from repro.core import (
    PlanCache,
    Planner,
    chain,
    make_plan,
    rank_by_replay,
    replay,
    window_peaks,
)
from repro.core import dp as dp_mod
from repro.core.lower_sets import all_lower_sets

from conftest import random_dag


def _feasible_plans(g, n_budgets=4):
    """A few (budget, plan) pairs across the graph's feasible range."""
    fam = all_lower_sets(g)
    b_min = dp_mod.min_feasible_budget_exact(g, fam)
    b_max = g.total_memory
    out = []
    for i in range(n_budgets):
        B = b_min + (b_max - b_min) * i / max(n_budgets - 1, 1)
        res = dp_mod.solve(g, B, fam)
        if res.feasible:
            out.append((B, make_plan(g, res.sequence)))
    return out


# ----------------------------------------------------------- core properties


def test_replay_deterministic(rng):
    g = random_dag(rng, 7)
    for B, plan in _feasible_plans(g):
        a = replay(g, plan, budget=B)
        b = replay(g, plan, budget=B)
        assert a == b


def test_window_peaks_match_analytic_peak(rng):
    """max over backward windows == dp.peak_memory_live, bit for bit."""
    for trial in range(30):
        g = random_dag(rng, rng.randint(2, 8))
        for _, plan in _feasible_plans(g, 3):
            assert max(window_peaks(g, plan)) == plan.peak_memory, trial


def test_simulated_peak_le_analytic_on_random_dags(rng):
    """Acceptance property: simulated peak ≤ the plan's analytic peak
    (default budget: the overlap stream may only fill the plan's own
    headroom)."""
    for trial in range(30):
        g = random_dag(rng, rng.randint(2, 8))
        for _, plan in _feasible_plans(g, 3):
            res = replay(g, plan)
            assert res.simulated_peak <= plan.peak_memory, trial


def test_simulated_peak_le_budget_when_given(rng):
    for trial in range(20):
        g = random_dag(rng, rng.randint(3, 8))
        for B, plan in _feasible_plans(g, 3):
            res = replay(g, plan, budget=B)
            assert res.simulated_peak <= max(B, plan.peak_memory), trial


def test_overlap_le_serial_for_every_plan(rng):
    """Acceptance property: replayed time with overlap ≤ without, and the
    no-overlap replay equals its own serial sum."""
    for trial in range(30):
        g = random_dag(rng, rng.randint(2, 8))
        for B, plan in _feasible_plans(g, 3):
            on = replay(g, plan, budget=B)
            off = replay(g, plan, overlap=False, budget=B)
            assert on.seconds <= off.seconds, trial
            assert off.seconds == off.serial_seconds == on.serial_seconds
            assert on.seconds == on.serial_seconds - on.hidden_seconds


def test_more_budget_never_slower(rng):
    """Headroom is monotone in the budget, so replayed seconds are
    non-increasing as the budget grows."""
    for trial in range(20):
        g = random_dag(rng, rng.randint(3, 8))
        plans = _feasible_plans(g, 2)
        if not plans:
            continue
        _, plan = plans[0]
        base = plan.peak_memory
        prev = None
        for mult in (1.0, 1.5, 2.0, 4.0):
            s = replay(g, plan, budget=base * mult).seconds
            if prev is not None:
                assert s <= prev + 1e-12, trial
            prev = s


def test_replay_prices_the_whole_step():
    """The serial sum decomposes exactly: one forward pass + per-segment
    (recompute + backward_factor·forward + comm)."""
    g = chain(6)
    plan = make_plan(g, [frozenset(range(i + 1)) for i in range(6)])
    res = replay(g, plan, overlap=False)
    assert res.forward_seconds == g.total_time
    expected = res.forward_seconds + sum(
        s.recompute_seconds + s.backward_seconds + s.comm_seconds
        for s in res.segments
    )
    assert res.seconds == res.serial_seconds == expected
    for seg, timing in zip(plan.segments, res.segments):
        assert timing.backward_seconds == pytest.approx(
            2.0 * sum(g.time_v[v] for v in seg.nodes))
        assert timing.recompute_seconds == pytest.approx(
            sum(g.time_v[v] for v in seg.recompute))
    assert res.hidden_seconds == 0.0


def test_overlap_hides_recompute_with_headroom():
    """A plan with real recompute + a budget above its peak must hide a
    positive amount of replay time."""
    g = chain(10)
    fam = all_lower_sets(g)
    b_min = dp_mod.min_feasible_budget_exact(g, fam)
    res = dp_mod.solve(g, b_min, fam)
    plan = make_plan(g, res.sequence)
    assert any(seg.recompute for seg in plan.segments)
    roomy = replay(g, plan, budget=g.total_memory)
    assert roomy.hidden_seconds > 0.0
    assert roomy.seconds < roomy.serial_seconds


def test_comm_bytes_extend_step_time():
    g = chain(6)
    plan = make_plan(g, [frozenset(range(i + 1)) for i in range(6)])
    quiet = replay(g, plan)
    chatty = replay(g, plan, comm_bytes=4.5e10)  # 1 s at the default fabric
    assert chatty.serial_seconds == pytest.approx(quiet.serial_seconds + 1.0)
    assert sum(s.comm_seconds for s in chatty.segments) == pytest.approx(1.0)


def test_segment_costs_override_forward_seconds():
    g = chain(4)
    plan = make_plan(g, [frozenset(range(i + 1)) for i in range(4)])
    doubled = {seg.index: 2.0 * sum(g.time_v[v] for v in seg.nodes)
               for seg in plan.segments}
    res = replay(g, plan, segment_costs=doubled)
    assert res.forward_seconds == pytest.approx(2.0 * g.total_time)


def test_rank_by_replay_deterministic_tie_break(rng):
    g = random_dag(rng, 6)
    seqs = [[s.lower_set for s in pl.segments]
            for _, pl in _feasible_plans(g, 4)]
    if not seqs:
        pytest.skip("no feasible plans on this draw")
    i1, p1, r1 = rank_by_replay(g, seqs, budget=g.total_memory)
    i2, p2, r2 = rank_by_replay(g, seqs, budget=g.total_memory)
    assert (i1, r1.seconds) == (i2, r2.seconds)
    # identical duplicate candidates resolve to the earlier index
    i3, _, _ = rank_by_replay(g, [seqs[0], seqs[0]], budget=g.total_memory)
    assert i3 == 0


# ------------------------------------------------- wallclock through the DP


def test_dp_solve_wallclock_feasible_and_no_worse(rng):
    """The wallclock winner replays no slower than the overhead-optimal
    plan at the same budget (the tc plan is one of its candidates)."""
    for trial in range(15):
        g = random_dag(rng, rng.randint(3, 8))
        fam = all_lower_sets(g)
        B = dp_mod.min_feasible_budget_exact(g, fam) * 1.3
        tc = dp_mod.solve(g, B, fam, "time_centric")
        wc = dp_mod.solve(g, B, fam, "wallclock")
        if not tc.feasible:
            assert not wc.feasible
            continue
        assert wc.feasible
        assert wc.peak_memory <= B
        assert wc.overhead >= tc.overhead  # tc is overhead-minimal
        r_tc = replay(g, make_plan(g, tc.sequence), budget=B)
        r_wc = replay(g, make_plan(g, wc.sequence), budget=B)
        assert r_wc.seconds <= r_tc.seconds + 1e-12, trial


def test_dp_solve_wallclock_requires_liveness():
    g = chain(4)
    with pytest.raises(ValueError, match="liveness"):
        dp_mod.solve(g, 4.0, all_lower_sets(g), "wallclock",
                     functional="eq2")


def test_dp_solve_wallclock_infeasible_budget():
    g = chain(8)
    res = dp_mod.solve(g, 1.0, all_lower_sets(g), "wallclock")
    assert not res.feasible


# -------------------------------------------- wallclock through the Planner


def test_planner_wallclock_solve_and_report():
    g = chain(12)
    planner = Planner(cache=PlanCache())
    B = planner.min_feasible_budget(g, "exact_dp") * 1.2
    res = planner.solve(g, B, "exact_dp", "wallclock")
    assert res.feasible and res.peak_memory <= B
    rep = planner.plan(g, B, "exact_dp", "wallclock")
    assert rep.plan is not None
    assert rep.replayed_seconds is not None
    assert rep.replayed_seconds == pytest.approx(
        replay(g, rep.plan, budget=B).seconds)
    # non-wallclock reports carry no replay figure
    assert planner.plan(g, B, "exact_dp").replayed_seconds is None


def test_planner_wallclock_shares_tc_sweep_surface():
    """wallclock warms/reuses the time_centric sweep entry — no second
    cached surface for the same graph+family."""
    g = chain(10)
    planner = Planner(cache=PlanCache())
    planner.prewarm(g, "exact_dp", "wallclock")
    misses_before = planner.cache.stats()["misses"]
    B = planner.min_feasible_budget(g, "exact_dp") * 1.5
    wc = planner.solve(g, B, "exact_dp", "wallclock")
    tc = planner.solve(g, B, "exact_dp", "time_centric")
    assert wc.feasible and tc.feasible
    r_wc = replay(g, make_plan(g, wc.sequence), budget=B)
    r_tc = replay(g, make_plan(g, tc.sequence), budget=B)
    assert r_wc.seconds <= r_tc.seconds + 1e-12
    assert planner.cache.stats()["misses"] == misses_before


def test_planner_wallclock_solve_grid(rng):
    g = random_dag(rng, 7)
    planner = Planner(cache=PlanCache())
    b_min = planner.min_feasible_budget(g, "exact_dp")
    budgets = [b_min, b_min * 1.5, b_min * 3.0]
    grid = planner.solve_grid(g, budgets, "exact_dp", "wallclock")
    assert len(grid) == len(budgets)
    for B, res in zip(budgets, grid):
        assert res.feasible
        assert res.peak_memory <= B + 1e-9
        tc = planner.solve(g, B, "exact_dp")
        assert res.overhead >= tc.overhead - 1e-12


# ------------------------------------------------------- front-door surface


def test_plan_function_wallclock_report():
    import jax
    import jax.numpy as jnp

    from repro.core.lowering import plan_function

    def fn(params, x):
        h = x
        for w in params:
            h = jnp.tanh(h @ w)
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [jax.random.normal(jax.random.fold_in(key, i), (8, 8)) * 0.3
              for i in range(4)]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    planner = Planner(cache=PlanCache())
    pf = plan_function(fn, budget=None, planner=planner,
                       objective="wallclock", method="exact_dp")
    lowered = pf.lowered_for(params, x)
    assert lowered.report.replayed_seconds is not None
    assert lowered.report.replayed_seconds > 0.0
