"""Deprecated shim — the checkpoint lowerings now live in ``core.lowering``.

* ``apply_with_policy`` / ``plan_policy`` → ``core.lowering.policy``
  (the ``"policy"`` backend: one ``jax.checkpoint`` whose
  ``save_only_these_names`` policy is the plan's cache set U_k);
* ``segment_groups`` / ``even_groups`` → ``core.lowering.segment``
  (the ``"segment"`` backend's layer-chain projection, used by the
  scan-over-layers production models).

New code should go through ``repro.plan_function`` or the registry in
``core.lowering.base``.
"""

from __future__ import annotations

from .lowering.policy import apply_with_policy, plan_policy
from .lowering.segment import even_groups, segment_groups

__all__ = [
    "plan_policy",
    "apply_with_policy",
    "segment_groups",
    "even_groups",
]
