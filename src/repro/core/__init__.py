"""repro.core — the paper's contribution as a library.

Graph-theoretic recomputation planning (Kusumoto et al., NeurIPS 2019):
lower-set sequences, exact/approximate DP, memory-/time-centric strategies,
Chen's √n baseline, liveness simulation, and the bridges into JAX
(jaxpr graph extraction, checkpoint-policy lowering, segmented executor).
"""

from .chen import articulation_points, candidate_split_points, chen_sqrt_n
from .cost_model import (
    OpProfile,
    calibrated_graph,
    load_or_profile,
    measured_times,
    profile_ops,
)
from .dfs import exhaustive_search
from .dp import (
    DPResult,
    Sweep,
    SweepOverflow,
    approx_dp,
    cached_sets,
    decode_sweep,
    exact_dp,
    min_feasible_budget_exact,
    overhead,
    peak_memory,
    quantize_times,
    solve,
    sweep,
)
from .graph import (
    Graph,
    Node,
    canonical_maps,
    canonical_order,
    chain,
    from_cost_lists,
    graph_digest,
)
from .liveness import SimResult, simulate, vanilla_peak
from .lower_sets import all_lower_sets, count_lower_sets, pruned_lower_sets
from .plan_cache import (
    PlanCache,
    PlanKey,
    SweepKey,
    default_cache,
    set_default_cache_dir,
)
from .planner import (
    Planner,
    PlanReport,
    compare_methods,
    get_default_planner,
    min_feasible_budget,
    plan,
)
from .schedule import ExecutionPlan, Segment, make_plan, plan_summary

__all__ = [
    "Graph",
    "Node",
    "chain",
    "from_cost_lists",
    "all_lower_sets",
    "pruned_lower_sets",
    "count_lower_sets",
    "DPResult",
    "solve",
    "sweep",
    "Sweep",
    "SweepOverflow",
    "decode_sweep",
    "min_feasible_budget_exact",
    "exact_dp",
    "approx_dp",
    "overhead",
    "peak_memory",
    "cached_sets",
    "quantize_times",
    "exhaustive_search",
    "articulation_points",
    "candidate_split_points",
    "chen_sqrt_n",
    "SimResult",
    "simulate",
    "vanilla_peak",
    "ExecutionPlan",
    "Segment",
    "make_plan",
    "plan_summary",
    "PlanReport",
    "plan",
    "compare_methods",
    "min_feasible_budget",
    # plan compilation pipeline
    "graph_digest",
    "canonical_order",
    "canonical_maps",
    "PlanCache",
    "PlanKey",
    "SweepKey",
    "default_cache",
    "set_default_cache_dir",
    "Planner",
    "get_default_planner",
    "OpProfile",
    "profile_ops",
    "load_or_profile",
    "measured_times",
    "calibrated_graph",
]
