"""Jit-ready wrapper: ``flash_attention`` with a custom VJP whose backward
*recomputes* the attention probabilities (kernels/flash_attention.py).

Interface matches the model layout (B, S, H, D) / (B, S, KV, D); the kernel
layout transpose is fused by XLA.  ``interpret=None`` auto-selects: compiled
on TPU, interpret elsewhere (this container is CPU-only, so tests and
examples run the very same kernel body in interpret mode).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as fa
from .ref import expand_kv


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _flash(q, k, v, causal, block_q, block_k, interpret):
    out, _ = fa.flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out


def _flash_fwd(q, k, v, causal, block_q, block_k, interpret):
    out, lse = fa.flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    # residuals: q, k, v, out, lse — NOT the (Sq, Sk) probabilities
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    H, KV = q.shape[1], k.shape[1]
    kf = expand_kv(k, H)
    vf = expand_kv(v, H)
    dq, dk_full, dv_full = fa.flash_attention_bwd(
        q, kf, vf, out, lse, do, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    if KV != H:  # GQA: fold the head group back onto its kv head
        B, _, Sk, D = dk_full.shape
        dk = dk_full.reshape(B, KV, H // KV, Sk, D).sum(axis=2).astype(k.dtype)
        dv = dv_full.reshape(B, KV, H // KV, Sk, D).sum(axis=2).astype(v.dtype)
    else:
        dk, dv = dk_full.astype(k.dtype), dv_full.astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,
    causal: bool = True,
    block_q: int = fa.DEFAULT_BLOCK_Q,
    block_k: int = fa.DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Differentiable flash attention in model layout (B, S, H, D)."""
    if interpret is None:
        interpret = _auto_interpret()
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = _flash(qh, kh, vh, causal, block_q, block_k, interpret)
    return out.transpose(0, 2, 1, 3)
