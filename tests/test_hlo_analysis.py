"""Compiler-truth HLO analysis: parser units, census, drift gate, CLI.

Covers ``analysis.hlo_text`` (the shared HLO/StableHLO text parser the
dry-run now imports), ``analysis.hlo`` (remat conformance, the memory-drift
gate, compiled cost extraction), the ``cost_source`` plan-cache digest
separation, and the corruption regressions the acceptance criteria demand:
corrupting a plan's peak or dropping a cached tag must turn the pass red.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import check_hlo, drift_findings
from repro.analysis.hlo import (
    HEAVY_NODE_KINDS,
    analyze_hlo,
    analyze_twin,
    extract_segment_costs,
    heavy_census,
)
from repro.analysis.hlo_text import (
    collective_bytes,
    computation_multipliers,
    count_heavy_ops,
    reduce_precision_count,
    shape_bytes,
    split_computations,
)
from repro.analysis.report import Report
from repro.core import PlanCache, Planner
from repro.core.graph import Graph, Node, graph_digest
from repro.core.lowering.carriers import TracedCarrier

DN = (((1,), (0,)), ((), ()))


# ---------------------------------------------------------------------------
# hlo_text parser units (pure text, no compile)
# ---------------------------------------------------------------------------

# A hand-written post-optimization module: one dot in the entry, one dot
# inside a fusion called from a while body with trip count 5 (the scan
# lowering shape), an all-reduce in the same body, and two custom-calls of
# which only the oneDNN matmul is heavy.
_SYNTH_HLO = """\
HloModule synth

%fused_dot (a: f32[4,4], b: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4] parameter(0)
  %b = f32[4,4] parameter(1)
  ROOT %d = f32[4,4] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %h = f32[4,4] get-tuple-element(%p), index=1
  %f = f32[4,4] fusion(%h, %h), kind=kOutput, calls=%fused_dot
  %ar = f32[4,4] all-reduce(%f), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (arg: f32[4,4]) -> f32[4,4] {
  %arg = f32[4,4] parameter(0)
  %d0 = f32[4,4] dot(%arg, %arg), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cc = f32[4,4] custom-call(%arg, %arg), custom_call_target="__onednn$matmul"
  %cb = f32[4,4] custom-call(%arg), custom_call_target="xla_python_cpu_callback"
  %zero = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%zero, %d0)
  %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4,4] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert shape_bytes("bf16[16]") == 32
    assert shape_bytes("f32[]") == 4  # scalar
    assert shape_bytes("weird[3]") == 0  # unknown dtype → unparsable


def test_split_computations_entry_marker():
    comps = split_computations(_SYNTH_HLO)
    assert comps["__entry__"] == ["main"]
    assert set(comps) - {"__entry__"} == {
        "fused_dot", "add", "body", "cond", "main",
    }
    assert any(" dot(" in s for s in comps["fused_dot"])


def test_while_trip_count_propagates_into_fusions():
    """The trip-count-aware path: a fusion called from a while body whose
    condition compares against constant(5) inherits multiplier 5."""
    mults = computation_multipliers(split_computations(_SYNTH_HLO))
    assert mults["body"] == 5
    assert mults["fused_dot"] == 5  # calls= chain through the body
    assert mults["add"] == 5  # to_apply= chain through the body
    assert mults["main"] == 1


def test_count_heavy_ops_trip_aware_and_custom_call_filter():
    # 1 entry dot + 5x the fused dot + 1 heavy custom-call; the host
    # callback custom-call must not count.
    assert count_heavy_ops(_SYNTH_HLO) == 1 + 5 + 1


def test_collective_bytes_trip_aware():
    out = collective_bytes(_SYNTH_HLO)
    assert out["bytes_per_chip"]["all-reduce"] == 4 * 4 * 4 * 5
    assert out["dynamic_counts"]["all-reduce"] == 5
    assert out["static_counts"]["all-reduce"] == 1
    assert out["total_bytes_per_chip"] == 4 * 4 * 4 * 5


def test_reduce_precision_identity_filter_hlo():
    text = """\
HloModule rp

ENTRY %e (x: f32[4]) -> f32[4] {
  %x = f32[4] parameter(0)
  %rp1 = f32[4] reduce-precision(%x), exponent_bits=8, mantissa_bits=23
  %rp2 = f32[4] reduce-precision(%x), exponent_bits=4, mantissa_bits=3
  ROOT %o = f32[4] add(%rp1, %rp2)
}
"""
    # only the identity e8m23 marker counts; the genuine f8 downcast not
    assert reduce_precision_count(text) == 1


def test_reduce_precision_identity_filter_stablehlo():
    text = """\
module @jit_f {
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = stablehlo.reduce_precision %arg0, format = e8m23 : tensor<4xf32>
    %1 = stablehlo.reduce_precision %0, format = e4m3 : tensor<4xf32>
    %2 = stablehlo.reduce_precision %1, format = e5m10 : tensor<4xf32>
    return %2 : tensor<4xf32>
  }
}
"""
    assert reduce_precision_count(text) == 2  # e8m23 (f32) + e5m10 (f16)


def test_dryrun_reuses_hlo_text_parser():
    """Satellite: launch/dryrun.py must alias, not duplicate, the parser."""
    before = os.environ.get("XLA_FLAGS")
    try:
        import repro.launch.dryrun as dryrun
    finally:  # dryrun pins XLA_FLAGS at import; don't leak it to other tests
        if before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before
    from repro.analysis import hlo_text

    assert dryrun._split_computations is hlo_text.split_computations
    assert dryrun.collective_bytes is hlo_text.collective_bytes
    assert dryrun._shape_bytes is hlo_text.shape_bytes


# ---------------------------------------------------------------------------
# Heavy census (trace level)
# ---------------------------------------------------------------------------


def test_heavy_census_scan_trip_aware():
    """A dot inside a length-4 scan body counts 4 times."""

    def fn(x, w):
        def body(h, _):
            return lax.dot_general(h, w, DN), None

        h, _ = lax.scan(body, x, None, length=4)
        return jnp.sum(h)

    closed = jax.make_jaxpr(fn)(
        jnp.ones((2, 8), jnp.float32), jnp.ones((8, 8), jnp.float32)
    )
    census = heavy_census(closed)
    assert census.forward == 4
    assert census.remat == 0


# ---------------------------------------------------------------------------
# check_hlo on a planned carrier (the front-door hook)
# ---------------------------------------------------------------------------


def _mlp(n_layers=4, width=8, batch=4):
    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, DN))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (width, width)) * 0.3
        for i in range(n_layers)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, width))
    return fn, params, x


@pytest.fixture(scope="module")
def planned_mlp():
    fn, params, x = _mlp()
    carrier = TracedCarrier.trace(fn, (params, x))
    g = carrier.to_graph()
    planner = Planner(cache=PlanCache())
    rep = planner.plan(g, planner.min_feasible_budget(g))
    assert rep.plan is not None
    return carrier, rep.plan


def test_check_hlo_conformant_on_planned_mlp(planned_mlp):
    carrier, plan = planned_mlp
    res = analyze_hlo(carrier, plan)
    assert res.report.ok, str(res.report.findings)
    codes = {f.code for f in res.report.findings}
    assert codes & {"hlo-heavy-multiplicity-ok", "hlo-cse-elided-recompute"}
    # the drift record is one JSON row for BENCH_hlo_drift.json
    assert res.drift["heavy_measured"] <= res.drift["heavy_expected"]
    assert res.drift["saved_residuals"] >= 1
    assert res.drift["drift_status"] in ("ok", "remat-elided")


def test_corrupted_peak_fails_drift_gate(planned_mlp):
    """Acceptance regression: shrinking the plan's claimed peak 100x must
    trip the drift gate under the strict knobs (no slack, no vanilla
    ceiling — the defaults tolerate real-size twins, not corruption)."""
    carrier, plan = planned_mlp
    bad = dataclasses.replace(plan, peak_memory=plan.peak_memory / 100.0)
    res = analyze_hlo(carrier, bad, abs_slack=0.0, use_vanilla_ceiling=False)
    assert not res.report.ok
    assert "memory-drift" in {f.code for f in res.report.findings}
    assert res.drift["drift_status"] == "drift"


def test_check_hlo_wrapper_returns_report(planned_mlp):
    carrier, plan = planned_mlp
    r = check_hlo(carrier, plan)
    assert isinstance(r, Report) and r.checker == "hlo"
    assert r.ok


def test_check_hlo_not_applicable_on_non_traced_carrier():
    r = check_hlo(object(), None)
    assert r.ok
    assert [f.code for f in r.findings] == ["not-applicable"]


def test_extract_segment_costs_shape(planned_mlp):
    carrier, plan = planned_mlp
    costs = extract_segment_costs(carrier, plan)
    assert len(costs) == len(plan.segments)
    assert all(set(c) == {"flops", "bytes"} for c in costs)
    # the mlp's dot segments must show real compute
    assert sum(c["flops"] for c in costs) > 0


# ---------------------------------------------------------------------------
# analyze_twin on an executable benchmark twin (the plan_lint --hlo path)
# ---------------------------------------------------------------------------


def _chain_graph(n=6):
    nodes = [
        Node(i, f"v{i}", 10.0 if i % 2 == 0 else 1.0, 4.0,
             "conv" if i % 2 == 0 else "tanh")
        for i in range(n)
    ]
    return Graph(nodes, [(i, i + 1) for i in range(n - 1)])


def _planned_twin():
    networks = pytest.importorskip("benchmarks.networks")
    from repro.core import dp

    g = _chain_graph()
    planner = Planner(cache=PlanCache())
    rep = planner.plan(g, planner.min_feasible_budget(g))
    plan = rep.plan
    assert plan is not None
    fwd, ex_args, byte_graph = networks.executable_twin(g)
    peak = dp.peak_memory_live(
        byte_graph, [s.lower_set for s in plan.segments]
    )
    cached = set(plan.cached)
    recompute = set(range(g.n)) - cached
    cached_tags = {g.nodes[v].name for v in cached}
    recompute_tags = {g.nodes[v].name for v in recompute}
    plan_heavy = sum(
        1 for v in recompute if g.nodes[v].kind in HEAVY_NODE_KINDS
    )
    policy = jax.checkpoint_policies.save_only_these_names(
        *sorted(cached_tags)
    )
    fn_grad = jax.value_and_grad(jax.checkpoint(fwd, policy=policy))
    assert recompute, "min-feasible plan on a chain must recompute something"
    return (fwd, fn_grad, ex_args, cached_tags, recompute_tags,
            plan_heavy, peak)


def test_analyze_twin_passes_on_faithful_lowering():
    fwd, fn_grad, args, cached, recompute, heavy, peak = _planned_twin()
    res = analyze_twin(
        fn_grad, args,
        cached_tags=cached,
        recompute_tags=recompute,
        plan_heavy_recompute=heavy,
        analytic_peak=peak,
        vanilla_grad=jax.value_and_grad(fwd),
    )
    assert res.report.ok, str(res.report.findings)


def test_dropped_cached_tag_fails():
    """Acceptance regression: a plan caching a tag the twin never tags must
    fail — the policy cannot save what was never marked."""
    fwd, fn_grad, args, cached, recompute, heavy, peak = _planned_twin()
    res = analyze_twin(
        fn_grad, args,
        cached_tags=cached | {"ghost-residual"},
        recompute_tags=recompute,
        plan_heavy_recompute=heavy,
        analytic_peak=peak,
    )
    assert not res.report.ok
    assert "cached-tag-missing" in {f.code for f in res.report.findings}


def test_recompute_beyond_plan_fails():
    """A twin that rematerializes more than the plan's V \\ U_k (here: a
    plan claiming zero recompute) breaks the eq. (1) accounting."""
    fwd, fn_grad, args, cached, recompute, heavy, peak = _planned_twin()
    res = analyze_twin(
        fn_grad, args,
        cached_tags=cached,
        recompute_tags=set(),  # the plan claims nothing is recomputed
        plan_heavy_recompute=0,
        analytic_peak=peak,
    )
    assert not res.report.ok
    assert "recompute-exceeds-eq1" in {f.code for f in res.report.findings}


def test_twin_without_checkpoint_reports_no_remat():
    fwd, _, args, cached, recompute, heavy, peak = _planned_twin()
    res = analyze_twin(
        jax.value_and_grad(fwd), args,  # never went through jax.checkpoint
        cached_tags=cached,
        recompute_tags=recompute,
        plan_heavy_recompute=heavy,
        analytic_peak=peak,
    )
    assert not res.report.ok
    assert "no-remat" in {f.code for f in res.report.findings}


# ---------------------------------------------------------------------------
# drift_findings (pure)
# ---------------------------------------------------------------------------


def test_drift_findings_three_statuses():
    r = Report(checker="hlo")
    assert drift_findings(r, analytic_peak=100.0, temp_bytes=120.0,
                          rel=0.5, abs_slack=0.0) == "ok"
    assert r.ok

    r = Report(checker="hlo")
    assert drift_findings(r, analytic_peak=100.0, temp_bytes=400.0,
                          rel=0.0, abs_slack=0.0, ceiling=500.0) \
        == "remat-elided"
    assert r.ok  # warning, not error
    assert r.warnings()

    r = Report(checker="hlo")
    assert drift_findings(r, analytic_peak=100.0, temp_bytes=400.0,
                          rel=0.0, abs_slack=0.0) == "drift"
    assert not r.ok


# ---------------------------------------------------------------------------
# cost_source: plan-cache digest separation for compiled/profile costs
# ---------------------------------------------------------------------------


def test_cost_source_enters_digest_only_when_set():
    g1, g2 = _chain_graph(), _chain_graph()
    assert graph_digest(g1) == graph_digest(g2)  # default "" is stable
    gc = Graph(g1.nodes, g1.edges, cost_source="compiled:k")
    gp = Graph(g1.nodes, g1.edges, cost_source="profile:k")
    assert graph_digest(gc) != graph_digest(g1)
    assert graph_digest(gc) != graph_digest(gp)


def test_cost_source_survives_quantize_and_pin():
    from repro.analysis.effects import pin_graph
    from repro.core import dp

    g = Graph(_chain_graph().nodes, _chain_graph().edges,
              cost_source="compiled:k")
    assert dp.quantize_times(g).cost_source == "compiled:k"
    assert pin_graph(g, frozenset({1})).cost_source == "compiled:k"


def test_compiled_calibrated_graph_repricing():
    from repro.core.cost_model import (
        DEFAULT_PROFILE,
        compiled_calibrated_graph,
        measured_times,
    )

    g = _chain_graph()
    planner = Planner(cache=PlanCache())
    plan = planner.plan(g, planner.min_feasible_budget(g)).plan
    seg_costs = [{"flops": 1e9, "bytes": 1e6} for _ in plan.segments]
    cg = compiled_calibrated_graph(g, plan, seg_costs)
    assert cg.n == g.n
    assert cg.cost_source.startswith("compiled:")
    assert all(nd.time > 0 for nd in cg.nodes)
    assert graph_digest(cg) != graph_digest(g)
    # and the "measured" route stamps its own namespace
    mg = measured_times(g, DEFAULT_PROFILE)
    assert mg.cost_source.startswith("profile:")
    assert graph_digest(mg) != graph_digest(cg)


def test_profile_key_carries_source():
    from repro.core.cost_model import OpProfile

    base = dict(sec_per_flop_matmul=1e-12, sec_per_flop_attention=1e-12,
                sec_per_byte_elementwise=1e-10, backend="cpu",
                jax_version="x")
    measured = OpProfile(**base)  # source defaults to "measured"
    compiled = OpProfile(**base, source="compiled")
    assert measured.profile_key() != compiled.profile_key()
    assert compiled.profile_key().endswith("-compiled")


# ---------------------------------------------------------------------------
# verify_hlo at the front door
# ---------------------------------------------------------------------------


def test_plan_function_verify_hlo_end_to_end():
    import numpy as np

    import repro

    fn, params, x = _mlp()
    pf = repro.plan_function(fn, None, verify=True, verify_hlo=True,
                             backend="jaxpr",
                             planner=Planner(cache=PlanCache()))
    lowered = pf.lowered_for(params, x)
    assert lowered.backend == "jaxpr"
    loss, _ = pf(params, x)
    np.testing.assert_allclose(
        np.asarray(loss), np.asarray(fn(params, x)), rtol=1e-6
    )


def test_plan_function_compiled_cost_model():
    """cost_model="compiled": trace at flops granularity, extract XLA's
    per-segment costs, re-plan on the recalibrated graph."""
    import repro

    fn, params, x = _mlp()
    pf = repro.plan_function(fn, None, cost_model="compiled",
                             backend="jaxpr",
                             planner=Planner(cache=PlanCache()))
    lowered = pf.lowered_for(params, x)
    assert lowered.plan is not None


# ---------------------------------------------------------------------------
# pallas_call effect classification (satellite)
# ---------------------------------------------------------------------------


def test_pallas_call_is_opaque():
    from repro.analysis.effects import _classify
    from repro.core.prims import OPAQUE_PRIMS

    assert "pallas_call" in OPAQUE_PRIMS

    class _Prim:
        name = "pallas_call"

    class _Eqn:
        primitive = _Prim()
        params = {}
        effects = frozenset()

    klass, reason = _classify(_Eqn())
    assert klass == "opaque"
    assert "pallas_call" in reason


def test_pallas_call_traced_classification():
    pl = pytest.importorskip("jax.experimental.pallas")
    from repro.analysis.effects import classify_eqns

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        y = pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True,
        )(x)
        return jnp.sum(y * y)

    try:
        closed = jax.make_jaxpr(fn)(jnp.ones((8,), jnp.float32))
    except Exception as e:  # pallas interpret mode varies across backends
        pytest.skip(f"pallas tracing unavailable here: {e}")
    effs = classify_eqns(closed)
    pallas = [e for e in effs if e.primitive == "pallas_call"]
    assert pallas and all(e.klass == "opaque" for e in pallas)


# ---------------------------------------------------------------------------
# plan_lint --hlo CLI (one real network; the full sweep is the CI gate)
# ---------------------------------------------------------------------------


def test_cli_hlo_network_writes_drift_records(tmp_path):
    pytest.importorskip("benchmarks.networks")
    from repro.analysis.cli import main

    report = tmp_path / "lint.json"
    drift = tmp_path / "drift.json"
    rc = main(["--hlo", "--network", "vgg19",
               "--json", str(report), "--drift-json", str(drift)])
    assert rc == 0
    payload = json.loads(drift.read_text())
    assert payload["ok"] is True
    (rec,) = payload["records"]
    assert rec["target"] == "vgg19"
    assert rec["heavy_measured"] <= rec["heavy_expected"]
    assert rec["drift_status"] in ("ok", "remat-elided")
    assert json.loads(report.read_text())  # lint report also written
