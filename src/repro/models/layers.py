"""Core layer primitives — functional (init/apply pairs), no framework deps.

All params are plain dict pytrees; activations are annotated with logical
sharding axes (repro.parallel.sharding).  Matmuls accumulate in float32 and
cast back to the activation dtype, the TPU-native convention.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard


def _init_normal(rng, shape, scale, dtype=jnp.float32):
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


# ------------------------------------------------------------------ linear


def linear_init(rng, d_in: int, d_out: int, bias: bool = False, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": _init_normal(rng, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.einsum(
        "...i,io->...o", x, p["w"].astype(x.dtype), precision=jax.lax.Precision.DEFAULT
    )
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.astype(out_dtype)


# ----------------------------------------------------------------- rmsnorm


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dtype)


# -------------------------------------------------------------------- rope


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (d_head/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ swiglu


def swiglu_init(rng, d_model: int, d_ff: int):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "w_gate": _init_normal(r1, (d_model, d_ff), d_model**-0.5),
        "w_up": _init_normal(r2, (d_model, d_ff), d_model**-0.5),
        "w_down": _init_normal(r3, (d_ff, d_model), d_ff**-0.5),
    }


def swiglu(p, x):
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dtype)).astype(dtype)


def gelu_mlp_init(rng, d_model: int, d_ff: int):
    r1, r2 = jax.random.split(rng)
    return {
        "w_up": _init_normal(r1, (d_model, d_ff), d_model**-0.5),
        "w_down": _init_normal(r2, (d_ff, d_model), d_ff**-0.5),
    }


def gelu_mlp(p, x):
    dtype = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dtype)).astype(dtype)


# --------------------------------------------------------------- embedding


def embedding_init(rng, vocab: int, d_model: int):
    return {"embed": _init_normal(rng, (vocab, d_model), 1.0)}


def embed(p, tokens: jax.Array, dtype) -> jax.Array:
    emb = p["embed"].astype(dtype)
    return jnp.take(emb, tokens, axis=0)


def unembed_init(rng, d_model: int, vocab: int):
    return {"unembed": _init_normal(rng, (vocab, d_model), d_model**-0.5)}


def unembed(p, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, p["unembed"].astype(x.dtype))
    return shard(logits.astype(jnp.float32), "batch", None, "vocab")


# ------------------------------------------------------------------- loss


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy; logits (..., V) f32, labels (...) int.

    The gold logit is picked with a one-hot contraction, NOT take_along_axis:
    a gather along a vocab-sharded axis makes GSPMD all-gather the full
    logits (13 GB/chip at 4k×50k), while the one-hot product reduces
    shard-locally and all-reduces only the (B, S) result.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (
        labels[..., None] == jnp.arange(logits.shape[-1], dtype=labels.dtype)
    )
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
