"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

Everything here is straight-line jnp with explicit f32 softmax — no tricks,
no chunking — so a disagreement with the kernels localizes to the kernel.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, KV, S, D) → (B, H, S, D), repeating each kv head H/KV times."""
    B, KV, S, D = k.shape
    rep = n_heads // KV
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=1)


def attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,  # (B, KV, Sk, D)
    causal: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    k = expand_kv(k, H)
    v = expand_kv(v, H)
    Sk = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        # decode-style alignment: query i attends to keys ≤ i + (Sk - Sq)
        off = Sk - Sq
        mask = jnp.arange(Sq)[:, None] + off >= jnp.arange(Sk)[None, :]
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_with_lse_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Also return the per-row logsumexp (B, H, Sq) the backward recomputes
    probabilities from."""
    B, H, Sq, D = q.shape
    k = expand_kv(k, H)
    v = expand_kv(v, H)
    Sk = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        off = Sk - Sq
        mask = jnp.arange(Sq)[:, None] + off >= jnp.arange(Sk)[None, :]
        scores = jnp.where(mask, scores, -jnp.inf)
    lse = jax.nn.logsumexp(scores, axis=-1)  # (B,H,Sq)
    probs = jnp.exp(scores - lse[..., None])
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
    return out.astype(q.dtype), lse


def attention_vjp_ref(q, k, v, do, causal: bool = True):
    """Reference gradients via jax.vjp over the oracle."""
    f = lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)
