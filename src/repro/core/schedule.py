"""Canonical strategy (§3) as an executable plan.

``ExecutionPlan`` is the pivot of the unified pipeline: the DP output (a
lower-set sequence) lowered into segments/cache-set form, which every
registered backend in ``core.lowering`` executes —

* ``"interpreter"`` — segment-by-segment VJP interpreter (paper-faithful
  semantics; validates gradients and audits live bytes);
* ``"policy"`` / ``"jaxpr"`` — one ``jax.checkpoint`` whose
  ``save_only_these_names`` policy is the plan's cache set U_k (production
  paths, composing with jit/pjit sharding, for BlockGraphs and arbitrary
  traced functions respectively);
* ``"segment"`` — per-segment ``jax.checkpoint``, projecting onto grouped
  scan remat for the layer-chain production models.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import EMPTY, Graph, NodeSet


@dataclasses.dataclass(frozen=True)
class Segment:
    """One V_i = L_i \\ L_{i-1} with its caching decisions."""

    index: int
    nodes: Tuple[int, ...]  # V_i in topological order
    lower_set: NodeSet  # L_i
    boundary: NodeSet  # ∂(L_i) ∪ (pins ∩ L_i) — cached after this forward
    keep: NodeSet  # boundary ∩ V_i — newly cached nodes
    recompute: NodeSet  # V_i \ U_k — recomputed during backward


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    segments: Tuple[Segment, ...]
    cached: NodeSet  # U_k — everything ever cached
    overhead: float  # eq. (1), plus strategy taxes for strategy plans
    peak_memory: float  # liveness-tight analytic peak (dp.peak_memory_live)
    #: Per-node storage strategy of the cached set (core.strategies codes).
    #: Empty for the paper's binary plans; keys are a subset of ``cached``
    #: and a missing key means "store".  Lowerings read this to place
    #: offloaded residuals on host and run quantized ones through the
    #: optim.compression round-trip.
    strategy: Dict[int, str] = dataclasses.field(default_factory=dict)

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    def segment_of(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for seg in self.segments:
            for v in seg.nodes:
                out[v] = seg.index
        return out


def make_plan(
    g: Graph,
    sequence: Sequence[NodeSet],
    assignment: Optional[Dict[int, str]] = None,
    strategies: Optional["object"] = None,
) -> ExecutionPlan:
    """Lower a validated lower-set sequence into an ExecutionPlan.

    ``peak_memory`` is the liveness-tight analytic peak — the budget the DP
    admitted the sequence under, and an exact upper bound on the
    interpreter's measured live bytes (equals the §2 event simulation with
    last-use frees).

    ``assignment`` (joint memory-strategy DP output) attaches a per-node
    storage strategy to the cached set: ``peak_memory`` then prices
    offloaded/quantized residuals at their reduced device bytes, and — when
    a ``strategies`` :class:`~repro.core.strategies.StrategyConfig` is
    given — ``overhead`` additionally carries the assignment's transfer /
    codec taxes (the joint DP's time-centric ``t`` axis).
    """
    from .dp import overhead as _overhead, peak_memory_live as _peak
    from .strategies import STORE, assignment_taxes

    g.check_increasing_sequence(sequence)
    order = g.topological_order()
    pos = {v: i for i, v in enumerate(order)}

    segments: List[Segment] = []
    prev: NodeSet = EMPTY
    cached: set = set()
    pins = g.store_pins
    for i, L in enumerate(sequence):
        Vi = L - prev
        # effective cache: boundary plus must_store pins (effect analysis)
        b = g.boundary(L) | (pins & L)
        cached |= b
        segments.append(
            Segment(
                index=i,
                nodes=tuple(sorted(Vi, key=pos.get)),
                lower_set=L,
                boundary=b,
                keep=frozenset(b & Vi),
                recompute=EMPTY,  # filled below once U_k is known
            )
        )
        prev = L
    U_k = frozenset(cached)
    segments = [
        dataclasses.replace(s, recompute=frozenset(set(s.nodes) - U_k))
        for s in segments
    ]
    strategy: Dict[int, str] = {}
    if assignment:
        strategy = {
            v: code for v, code in assignment.items()
            if v in U_k and code != STORE
        }
    overhead = _overhead(g, sequence)
    if strategy and strategies is not None:
        overhead += assignment_taxes(g, strategy, strategies)
    return ExecutionPlan(
        segments=tuple(segments),
        cached=U_k,
        overhead=overhead,
        peak_memory=_peak(g, sequence, strategy or None),
        strategy=strategy,
    )


def plan_summary(g: Graph, plan: ExecutionPlan) -> str:
    lines = [
        f"ExecutionPlan: {plan.num_segments} segments, "
        f"overhead T={plan.overhead:.3g} "
        f"({100 * plan.overhead / g.total_time:.1f}% of fwd), "
        f"analytic peak M={plan.peak_memory:.4g}"
    ]
    if plan.strategy:
        counts: Dict[str, int] = {}
        for code in plan.strategy.values():
            counts[code] = counts.get(code, 0) + 1
        lines[0] += " strategies=" + ",".join(
            f"{c}:{n}" for c, n in sorted(counts.items())
        )
    for s in plan.segments:
        lines.append(
            f"  seg {s.index}: |V|={len(s.nodes)} keep={sorted(s.keep)} "
            f"recompute={len(s.recompute)} nodes"
        )
    return "\n".join(lines)
