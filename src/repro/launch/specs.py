"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns the exact pytree the corresponding step
function consumes — weak-type-correct, shardable, zero device allocation.
``state_specs`` eval_shapes the model init (and AdamW init) the same way.
``input_shardings`` / ``cache_shardings`` / ``param_shardings`` map those
trees onto a mesh under the active logical rules with the divisibility guard
(repro.parallel.sharding).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.parallel.sharding import (
    _axis_sizes,
    drop_indivisible,
    named_sharding_tree,
)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    from repro.parallel.sharding import get_rules

    rule = get_rules().get("batch", ("pod", "data"))
    if isinstance(rule, str):
        rule = (rule,)
    names = tuple(mesh.axis_names)
    return tuple(a for a in rule if a in names)


# --------------------------------------------------------------------- inputs


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """The step-function batch for one cell (ShapeDtypeStructs only)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.encoder_decoder:
            batch["frames"] = _sds((B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend != "none":
            batch["extra_embeds"] = _sds(
                (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16
            )
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.encoder_decoder:
            batch["frames"] = _sds((B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend != "none":
            batch["extra_embeds"] = _sds(
                (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16
            )
        return batch
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "positions": _sds((B,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """KV/state caches for the decode step, via eval_shape (no allocation)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        params = params_specs(cfg)
        frames = _sds((B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
        return jax.eval_shape(lambda p, f: model.init_caches(p, f, S), params, frames)
    return jax.eval_shape(lambda: model.init_caches(B, S))


def params_specs(cfg: ModelConfig, serving: bool = False) -> Any:
    """serving=True casts float params to the activation dtype (bf16
    inference weights; the f32 master copy exists only in training)."""
    model = build_model(cfg)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(model.init, rng)
    if not serving:
        return params
    act = cfg.activation_dtype

    def cast(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(l.shape, act)
        return l

    return jax.tree_util.tree_map(cast, params)


def opt_specs(params: Any) -> Any:
    return jax.eval_shape(adamw.init, params)


# ------------------------------------------------------------------ shardings


def input_shardings(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> Dict[str, Any]:
    sizes = _axis_sizes(mesh)
    ba = batch_axes(mesh)
    specs = input_specs(cfg, shape)

    def one(name: str, sds) -> NamedSharding:
        spec = [ba] + [None] * (len(sds.shape) - 1)
        p = drop_indivisible(P(*spec), sds.shape, sizes)
        return NamedSharding(mesh, p)

    return {k: one(k, v) for k, v in specs.items()}


def cache_shardings(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, caches: Any
) -> Any:
    """Decode caches: batch → ("pod","data"); long-context (B=1) instead
    shards the sequence axis over "data" (SP); heads/kv axis → "model"."""
    sizes = _axis_sizes(mesh)
    ba = batch_axes(mesh)
    long_ctx = shape.global_batch == 1

    def leaf_spec(path: str, sds) -> NamedSharding:
        shp = sds.shape
        nd = len(shp)
        spec = [None] * nd
        # leading axis is the unit/layer stack for every cache leaf
        if nd >= 2:
            spec[1] = None if long_ctx else ba
        last = path.split("/")[-1]
        if last in ("k", "v", "xk", "xv") and nd == 5:
            # (L, B, S, KV, D): prefer kv-heads over "model"; when KV doesn't
            # divide the model axis (GQA kv < tp), shard the sequence instead
            # — an S-sharded KV cache decodes with small softmax collectives,
            # while an unsharded one simply does not fit (mistral 32k ≈ 94
            # GB/device otherwise).
            if long_ctx:
                spec[2] = "data"
            if shp[3] % sizes.get("model", 1) == 0:
                spec[3] = "model"
            elif shp[2] % sizes.get("model", 1) == 0 and spec[2] is None:
                spec[2] = "model"
        elif last in ("ssm", "C") and nd == 5:
            # (L, B, H, P, N)
            spec[2] = "model"
        elif last == "n" and nd == 5:
            spec[2] = "model"
        elif last == "conv" and nd == 4:
            spec[3] = "model"
        p = drop_indivisible(P(*spec), shp, sizes)
        return NamedSharding(mesh, p)

    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    treedef = jax.tree_util.tree_structure(caches)
    out = []
    for kp, leaf in flat:
        keys = []
        for pp in kp:
            keys.append(str(getattr(pp, "key", getattr(pp, "idx", pp))))
        out.append(leaf_spec("/".join(keys), leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params: Optional[Any] = None):
    from repro.launch.plan import needs_fsdp
    from repro.launch.steps import _model_shards
    from repro.parallel.sharding import get_rules

    if params is None:
        params = params_specs(cfg)
    rules = get_rules()
    if rules.get("heads") is None and rules.get("experts") is None:
        # dp_only mode: full ZeRO-3 over both axes
        return named_sharding_tree(
            params, mesh, fsdp=True, fsdp_axes=("data", "model")
        )
    if rules.get("heads") is None:  # dp_attn: ZeRO dense parts, EP experts
        return named_sharding_tree(params, mesh, fsdp=True)
    fsdp = needs_fsdp(cfg, _model_shards(mesh))
    return named_sharding_tree(params, mesh, fsdp=fsdp)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
