"""High-level planning API: solve the general recomputation problem for a
graph (or a traced JAX function) under a memory budget.

The paper's §5.1 protocol: "for the memory budget B … we chose the minimal
value B for which the solution … exists.  This value was determined using
binary search."  ``min_feasible_budget`` implements that search;
``plan`` is the one-call front door used by the framework.

Plan compilation pipeline (beyond-paper): every DP solve and budget search
is memoized through ``core.plan_cache`` behind a canonical graph digest, so
repeated plans — multi-budget sweeps, dry-run matrices, job restarts — are
hash lookups instead of exponential DP re-solves.  ``Planner`` is the
stateful front door carrying the cache and an optional measured cost model
(``core.cost_model``); the module-level ``plan``/``min_feasible_budget``
functions route through a process-default ``Planner`` so existing callers
inherit the caching transparently.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, List, Optional, Sequence, Tuple

from . import dp as dp_mod
from .chen import chen_sqrt_n
from .cost_model import OpProfile, calibrated_graph
from .dp import DPResult, approx_dp, exact_dp, solve
from .graph import Graph, NodeSet, graph_digest
from .liveness import simulate, vanilla_peak
from .lower_sets import all_lower_sets, pruned_lower_sets
from .plan_cache import PlanCache, default_cache
from .schedule import ExecutionPlan, make_plan


@dataclasses.dataclass
class PlanReport:
    """Everything the framework (and the benchmarks) need about one plan."""

    method: str  # "exact_dp" | "approx_dp" | "chen" | "vanilla"
    objective: str  # "time_centric" | "memory_centric" | "-"
    budget: float
    result: DPResult
    plan: Optional[ExecutionPlan]
    peak_with_liveness: float
    peak_without_liveness: float
    plan_seconds: float

    @property
    def feasible(self) -> bool:
        return self.result.feasible


def _family(g: Graph, method: str) -> Sequence[NodeSet]:
    if method == "exact_dp":
        return all_lower_sets(g)
    if method == "approx_dp":
        return pruned_lower_sets(g)
    raise ValueError(method)


def _min_feasible_budget_uncached(
    g: Graph,
    method: str = "approx_dp",
    tol: float = 1e-3,
    family: Optional[Sequence[NodeSet]] = None,
) -> float:
    """Binary search the minimal B with a feasible canonical strategy (§5.1).

    Bounds: any strategy needs at least max_i 2·M_v-ish memory; the
    single-segment strategy needs ≤ vanilla 2·M(V).  We search in
    [max_v M_v, 2·M(V)] to relative tolerance ``tol``, using the fast
    feasibility-only DP (core.dp.feasible) per probe.
    """
    from .dp import _prepare, feasible

    fam = list(family) if family is not None else list(_family(g, method))
    infos = _prepare(g, fam)
    lo = max(g.mem_v)
    hi = 2.0 * g.total_memory + max(g.mem_v)
    # verify hi feasible
    if not feasible(g, hi, fam, infos):
        raise RuntimeError("even the maximal budget is infeasible — bug")
    while hi - lo > tol * max(1.0, hi):
        mid = 0.5 * (lo + hi)
        if feasible(g, mid, fam, infos):
            hi = mid
        else:
            lo = mid
    return hi


class Planner:
    """Stateful planning front door: DP + plan cache + optional cost model.

    * ``cache``  — a ``core.plan_cache.PlanCache``; defaults to the process
      default cache (in-memory LRU, plus disk when a cache dir is attached).
    * ``profile``— an ``OpProfile`` from ``core.cost_model``; when set, every
      graph is re-priced to measured seconds and re-quantized before the DP,
      so the solved t-axis reflects the hardware instead of FLOP proxies.
    * ``quantize_levels`` — integer t-axis resolution for the calibration
      path (also usable without a profile to quantize FLOP-valued graphs).

    ``solve`` results are cached by ``(graph_digest, budget, family,
    objective)``; custom lower-set families bypass the cache (their identity
    isn't captured by the method name).
    """

    CACHEABLE_METHODS = ("exact_dp", "approx_dp")

    def __init__(
        self,
        cache: Optional[PlanCache] = None,
        profile: Optional[OpProfile] = None,
        quantize_levels: Optional[int] = None,
    ):
        self.cache = default_cache() if cache is None else cache
        self.profile = profile
        self.quantize_levels = quantize_levels
        # Tiny memo of the most recent canonical lower-set families:
        # enumerating 𝓛_G is the dominant cold-path cost (§4.2), and one
        # budget search + solve (or a multi-budget sweep) re-enumerates the
        # same family many times.  Kept small — families can be exponential.
        from collections import OrderedDict

        self._family_memo: "OrderedDict[Tuple[str, str], List[NodeSet]]" = (
            OrderedDict()
        )

    def family(self, g: Graph, method: str = "approx_dp") -> Sequence[NodeSet]:
        """The canonical lower-set family for ``method`` (memoized).

        Public so tooling (e.g. examples/plan_explorer.py) can inspect the
        family without paying a second enumeration on top of the planner's.
        """
        return self._family_for(self.prepare(g), method)

    def _family_for(self, gp: Graph, method: str) -> Sequence[NodeSet]:
        key = (graph_digest(gp), method)
        fam = self._family_memo.get(key)
        if fam is None:
            fam = list(_family(gp, method))
            self._family_memo[key] = fam
            while len(self._family_memo) > 4:
                self._family_memo.popitem(last=False)
        else:
            self._family_memo.move_to_end(key)
        return fam

    # -------------------------------------------------------------- prepare

    def prepare(self, g: Graph) -> Graph:
        """Apply the measured cost model / quantization (identity without)."""
        if self.profile is not None:
            return calibrated_graph(
                g, self.profile, levels=self.quantize_levels or 64
            )
        if self.quantize_levels:
            return dp_mod.quantize_times(g, levels=self.quantize_levels)
        return g

    # ---------------------------------------------------------------- solve

    def solve(
        self,
        g: Graph,
        budget: float,
        method: str = "approx_dp",
        objective: str = "time_centric",
        family: Optional[Sequence[NodeSet]] = None,
        prepared: bool = False,
    ) -> DPResult:
        """Algorithm 1 through the cache; bit-identical to an uncached solve."""
        gp = g if prepared else self.prepare(g)
        cacheable = (
            self.cache is not None
            and family is None
            and method in self.CACHEABLE_METHODS
        )
        key = None
        if cacheable:
            key = PlanCache.key_for(gp, budget, method, objective)
            hit = self.cache.get(gp, key)
            if hit is not None:
                return hit
        fam = list(family) if family is not None else self._family_for(gp, method)
        res = solve(gp, budget, fam, objective)
        if cacheable:
            self.cache.put(gp, key, res)
        return res

    def min_feasible_budget(
        self,
        g: Graph,
        method: str = "approx_dp",
        tol: float = 1e-3,
        family: Optional[Sequence[NodeSet]] = None,
        prepared: bool = False,
    ) -> float:
        gp = g if prepared else self.prepare(g)
        cacheable = self.cache is not None and family is None
        aux_key = None
        if cacheable:
            aux_key = f"{graph_digest(gp)}|{method}|{tol!r}"
            v = self.cache.get_aux("min_budget", aux_key)
            if v is not None:
                return v
        fam = family if family is not None else self._family_for(gp, method)
        b = _min_feasible_budget_uncached(gp, method, tol, fam)
        if cacheable:
            self.cache.put_aux("min_budget", aux_key, b)
        return b

    # ----------------------------------------------------------------- plan

    def plan(
        self,
        g: Graph,
        budget: Optional[float] = None,
        method: str = "approx_dp",
        objective: str = "time_centric",
    ) -> PlanReport:
        """Solve and lower to an ExecutionPlan (cached for the DP methods).

        budget=None reproduces the paper's protocol: minimal feasible B.
        method ∈ {"exact_dp", "approx_dp", "chen", "vanilla"}.
        """
        t0 = _time.perf_counter()
        gp = self.prepare(g)
        full = frozenset(range(gp.n))

        if method == "vanilla":
            res = DPResult(
                sequence=[full],
                overhead=0.0,
                peak_memory=dp_mod.peak_memory(gp, [full]),
                feasible=True,
            )
        elif method == "chen":
            res = chen_sqrt_n(gp, budget=None)
        else:
            if budget is None:
                budget = self.min_feasible_budget(gp, method, prepared=True)
            res = self.solve(gp, budget, method, objective, prepared=True)
        dt = _time.perf_counter() - t0

        if not res.feasible:
            return PlanReport(
                method=method,
                objective=objective if method.endswith("dp") else "-",
                budget=budget if budget is not None else float("nan"),
                result=res,
                plan=None,
                peak_with_liveness=float("inf"),
                peak_without_liveness=float("inf"),
                plan_seconds=dt,
            )

        ep = make_plan(gp, res.sequence)
        sim_live = simulate(gp, res.sequence, liveness=True)
        sim_nolive = simulate(gp, res.sequence, liveness=False)
        return PlanReport(
            method=method,
            objective=objective if method.endswith("dp") else "-",
            budget=budget if budget is not None else res.peak_memory,
            result=res,
            plan=ep,
            peak_with_liveness=sim_live.peak_memory,
            peak_without_liveness=sim_nolive.peak_memory,
            plan_seconds=dt,
        )


_DEFAULT_PLANNER = Planner()


def get_default_planner() -> Planner:
    """The process-wide Planner behind the module-level functions."""
    return _DEFAULT_PLANNER


def min_feasible_budget(
    g: Graph,
    method: str = "approx_dp",
    tol: float = 1e-3,
    family: Optional[Sequence[NodeSet]] = None,
) -> float:
    """§5.1 minimal-feasible-budget search (cached via the default Planner)."""
    return _DEFAULT_PLANNER.min_feasible_budget(g, method, tol, family)


def plan(
    g: Graph,
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
    planner: Optional[Planner] = None,
) -> PlanReport:
    """Solve and lower to an ExecutionPlan (one-call front door).

    Routes through the process-default ``Planner`` — repeated calls on the
    same (graph, budget) hit the plan cache instead of re-running the DP.
    """
    return (planner or _DEFAULT_PLANNER).plan(g, budget, method, objective)


def compare_methods(
    g: Graph, budget: Optional[float] = None, include_exact: bool = True
) -> List[PlanReport]:
    """The paper's Table-1 row for one network: all methods, one graph."""
    reports = [plan(g, method="vanilla")]
    reports.append(plan(g, method="chen"))
    for objective in ("memory_centric", "time_centric"):
        reports.append(plan(g, budget, "approx_dp", objective))
        if include_exact:
            reports.append(plan(g, budget, "exact_dp", objective))
    return reports
