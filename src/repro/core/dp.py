"""Dynamic-programming solutions to the General Recomputation Problem.

Implements Algorithm 1 of the paper (Appendix A) with the practical
accelerations the paper describes in §4.2:

* sparse DP table — ``opt[L, ·]`` holds only the *Pareto frontier* of
  ``(t, m)`` pairs ("when t < t' and opt[L,t] < opt[L,t'], we can skip the
  iteration for the entry opt[L,t']");
* node sets as arbitrary-precision integer bitmasks, so ``L ⊆ L'`` is one
  big-int AND;
* per-``L'`` segment terms (∂(L'), δ⁺(L')\\L', δ⁻(δ⁺(L'))\\L') precomputed
  once.

Three entry points:

* ``solve(graph, budget, family, objective="time_centric")`` — Algorithm 1;
  ``objective="memory_centric"`` replaces ``min`` with ``max`` at line 15
  (§4.4 / Appendix A note).
* ``exact_dp(graph, budget, ...)``  — family = 𝓛_G        (§4.2)
* ``approx_dp(graph, budget, ...)`` — family = 𝓛_G^Pruned (§4.3)

The DP requires integer ``T_v`` (the ``t`` axis of the table).  The paper
uses ``T_v ∈ {1, 10}``; for FLOP-derived costs use
``quantize_times(graph, levels)`` first.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from .graph import EMPTY, Graph, NodeSet
from .lower_sets import all_lower_sets, pruned_lower_sets


# ---------------------------------------------------------------------------
# Bitmask helpers
# ---------------------------------------------------------------------------


def to_mask(s: NodeSet) -> int:
    m = 0
    for v in s:
        m |= 1 << v
    return m


def from_mask(m: int) -> NodeSet:
    out = []
    v = 0
    while m:
        if m & 1:
            out.append(v)
        m >>= 1
        v += 1
    return frozenset(out)


def mask_iter(m: int):
    v = 0
    while m:
        if m & 1:
            yield v
        m >>= 1
        v += 1


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DPResult:
    """Solution of the general recomputation problem.

    Attributes:
      sequence: the increasing lower-set sequence {L₁ ≺ … ≺ L_k = V}.
      overhead: T(V \\ U_k) — total recomputation overhead (eq. 1).
      peak_memory: max_i 𝓜⁽ⁱ⁾ under the paper's model (eq. 2), *without*
        liveness analysis (the paper applies liveness post-hoc; see
        core.liveness for that refinement).
      feasible: False if no sequence satisfies the budget ("Impossible").
      states_visited: DP work counter (for the §5.1 runtime comparison).
    """

    sequence: List[NodeSet]
    overhead: float
    peak_memory: float
    feasible: bool
    states_visited: int = 0

    @property
    def num_segments(self) -> int:
        return len(self.sequence)


INF = float("inf")


# ---------------------------------------------------------------------------
# Segment-term precomputation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _LowerSetInfo:
    mask: int
    size: int
    T: float  # T(L)
    M: float  # M(L)
    boundary_mask: int  # ∂(L)
    T_boundary: float  # T(∂(L))
    m_after: float  # M(δ⁺(L) \ L) + M(δ⁻(δ⁺(L)) \ L)   (terms iii+iv of eq. 2)


def _prepare(g: Graph, family: Sequence[NodeSet]) -> List[_LowerSetInfo]:
    infos = []
    for L in family:
        mask = to_mask(L)
        dplus = g.delta_plus(L)
        dplus_out = to_mask(dplus) & ~mask  # δ⁺(L) \ L
        dmd_out = to_mask(g.delta_minus(dplus)) & ~mask  # δ⁻(δ⁺(L)) \ L
        boundary = g.boundary(L)
        infos.append(
            _LowerSetInfo(
                mask=mask,
                size=len(L),
                T=g.T(L),
                M=g.M(L),
                boundary_mask=to_mask(boundary),
                T_boundary=g.T(boundary),
                m_after=sum(g.mem_v[v] for v in mask_iter(dplus_out))
                + sum(g.mem_v[v] for v in mask_iter(dmd_out)),
            )
        )
    return infos


def _mask_M(g: Graph, mask: int) -> float:
    return sum(g.mem_v[v] for v in mask_iter(mask))


def _mask_T(g: Graph, mask: int) -> float:
    return sum(g.time_v[v] for v in mask_iter(mask))


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def solve(
    g: Graph,
    budget: float,
    family: Sequence[NodeSet],
    objective: str = "time_centric",
) -> DPResult:
    """Algorithm 1 (Appendix A) over an arbitrary lower-set family.

    objective:
      * "time_centric"   — minimize overhead (line 15: min)   §4.2/§4.3
      * "memory_centric" — maximize overhead (line 15: max)   §4.4
    """
    if objective not in ("time_centric", "memory_centric"):
        raise ValueError(f"unknown objective {objective!r}")

    infos = _prepare(g, family)
    # ascending order of set size (line 3)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    full_mask = (1 << g.n) - 1

    empty_id = None
    full_id = None
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id is None or full_id is None:
        raise ValueError("family must contain ∅ and V")

    # Sparse DP table: per lower-set id, a dict t -> (m, parent=(id, t)).
    # Pareto pruning: keep only entries where no t'' < t has m'' <= m.
    table: List[Dict[float, Tuple[float, Optional[Tuple[int, float]]]]] = [
        {} for _ in infos
    ]
    table[empty_id][0.0] = (0.0, None)

    states = 0
    n_fam = len(order)
    sizes = [infos[i].size for i in order]
    import bisect

    for pos, i in enumerate(order):
        info_L = infos[i]
        entries = table[i]
        if not entries:
            continue
        # Pareto-prune the source entries once before expanding (§4.2 note).
        # The dominance direction depends on the objective: TC keeps the
        # (t↓, m↓) frontier; MC keeps the (t↑, m↓) frontier — an entry is
        # dominated by one with ≥ overhead so far AND ≤ cache mass.
        pruned = _pareto(entries) if objective == "time_centric" else _pareto_mc(entries)
        table[i] = pruned
        pruned_items = list(pruned.items())
        mask_L = info_L.mask
        # strictly larger sets only: start past the last equal-size entry
        start = bisect.bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            # Pair terms.
            Vp_mask = info_Lp.mask & ~mask_L  # V' = L' \ L
            M_Vp = info_Lp.M - info_L.M
            # T(V' \ ∂(L')) = T(V') - T(V' ∩ ∂(L'))
            inter = Vp_mask & info_Lp.boundary_mask
            t_step = (info_Lp.T - info_L.T) - _mask_T(g, inter)
            # M(∂(L') \ L)
            m_step = _mask_M(g, info_Lp.boundary_mask & ~mask_L)
            m_fixed = 2.0 * M_Vp + info_Lp.m_after
            row = table[j]
            for t, (m, _parent) in pruned_items:
                states += 1
                Mi = m + m_fixed  # eq. (2): M(U_{i-1}) + 2M(V') + (iii) + (iv)
                if Mi > budget:
                    continue
                t2 = t + t_step
                m2 = m + m_step
                cur = row.get(t2)
                if cur is None or cur[0] > m2:
                    row[t2] = (m2, (i, t))

    final = table[full_id]
    if not final:
        return DPResult([], INF, INF, feasible=False, states_visited=states)

    if objective == "time_centric":
        t_star = min(final)
    else:  # memory_centric: max at line 15
        t_star = max(final)

    # Traceback (line 16).
    seq_ids: List[Tuple[int, float]] = []
    cur: Optional[Tuple[int, float]] = (full_id, t_star)
    while cur is not None:
        seq_ids.append(cur)
        _m, parent = table[cur[0]][cur[1]]
        cur = parent
    seq_ids.reverse()
    sequence = [from_mask(infos[i].mask) for i, _t in seq_ids if infos[i].mask != 0]

    peak = peak_memory(g, sequence)
    return DPResult(
        sequence=sequence,
        overhead=t_star,
        peak_memory=peak,
        feasible=True,
        states_visited=states,
    )


def feasible(g: Graph, budget: float, family: Sequence[NodeSet],
             infos: Optional[List[_LowerSetInfo]] = None) -> bool:
    """Fast feasibility oracle for the budget binary search (§5.1).

    For feasibility the t axis is irrelevant and smaller cache mass m is
    always at least as good, so one min-m entry per lower set suffices —
    O(#𝓛²) instead of O(T(V)·#𝓛²).
    """
    import bisect

    infos = infos if infos is not None else _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    best: List[float] = [INF] * len(infos)
    for i, info in enumerate(infos):
        if info.mask == 0:
            best[i] = 0.0
    n_fam = len(order)
    for pos, i in enumerate(order):
        m = best[i]
        if m == INF:
            continue
        info_L = infos[i]
        mask_L = info_L.mask
        start = bisect.bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue
            Mi = m + 2.0 * (info_Lp.M - info_L.M) + info_Lp.m_after
            if Mi > budget:
                continue
            m2 = m + _mask_M(g, info_Lp.boundary_mask & ~mask_L)
            if m2 < best[j]:
                best[j] = m2
    for i, info in enumerate(infos):
        if info.mask == full_mask:
            return best[i] < INF
    return False


def _pareto(
    entries: Dict[float, Tuple[float, Optional[Tuple[int, float]]]]
) -> Dict[float, Tuple[float, Optional[Tuple[int, float]]]]:
    """Keep only (t, m) not dominated by some (t'' ≤ t, m'' ≤ m), except both equal."""
    out: Dict[float, Tuple[float, Optional[Tuple[int, float]]]] = {}
    best = INF
    for t in sorted(entries):
        m, parent = entries[t]
        if m < best:
            out[t] = (m, parent)
            best = m
    return out


def _pareto_mc(
    entries: Dict[float, Tuple[float, Optional[Tuple[int, float]]]]
) -> Dict[float, Tuple[float, Optional[Tuple[int, float]]]]:
    """MC dominance: (t, m) is dominated by (t'' ≥ t, m'' ≤ m) — any feasible
    continuation of the dominated entry is feasible from the dominating one
    and ends with at least as much total overhead."""
    out: Dict[float, Tuple[float, Optional[Tuple[int, float]]]] = {}
    best = INF
    for t in sorted(entries, reverse=True):
        m, parent = entries[t]
        if m < best:
            out[t] = (m, parent)
            best = m
    return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def exact_dp(
    g: Graph, budget: float, objective: str = "time_centric", limit: int = 500_000
) -> DPResult:
    """§4.2 — DP over the full lattice 𝓛_G."""
    return solve(g, budget, all_lower_sets(g, limit=limit), objective)


def approx_dp(g: Graph, budget: float, objective: str = "time_centric") -> DPResult:
    """§4.3 — DP over 𝓛_G^Pruned (keys = principal lower sets L^v)."""
    return solve(g, budget, pruned_lower_sets(g), objective)


# ---------------------------------------------------------------------------
# Strategy evaluation (shared with DFS / Chen / tests)
# ---------------------------------------------------------------------------


def cached_sets(g: Graph, sequence: Sequence[NodeSet]) -> List[NodeSet]:
    """U_i = ∪_{j≤i} ∂(L_j) for each prefix."""
    u: set = set()
    out = []
    for L in sequence:
        u |= g.boundary(L)
        out.append(frozenset(u))
    return out


def overhead(g: Graph, sequence: Sequence[NodeSet]) -> float:
    """Eq. (1): T(V \\ U_k)."""
    U_k = cached_sets(g, sequence)[-1]
    allv = frozenset(range(g.n))
    return g.T(allv - U_k)


def peak_memory(g: Graph, sequence: Sequence[NodeSet]) -> float:
    """Eq. (2): max_i 𝓜⁽ⁱ⁾ (no liveness analysis — paper's analytic model)."""
    Us = cached_sets(g, sequence)
    peak = 0.0
    prev: NodeSet = EMPTY
    for i, L in enumerate(sequence):
        Vi = L - prev
        U_prev = Us[i - 1] if i > 0 else EMPTY
        dplus_out = g.delta_plus(L) - L
        dmd_out = g.delta_minus(g.delta_plus(L)) - L
        Mi = g.M(U_prev) + 2.0 * g.M(Vi) + g.M(dplus_out) + g.M(dmd_out)
        peak = max(peak, Mi)
        prev = L
    return peak


def quantize_times(g: Graph, levels: int = 64) -> Graph:
    """Rescale T_v to small positive integers so the DP's t-axis stays compact.

    Beyond-paper utility for FLOP-derived costs: T_v → max(1,
    round(levels · T_v / max_v T_v)).  The paper's {1, 10} costs pass through
    unchanged when levels ≥ 10·max/max.
    """
    from .graph import Node

    tmax = max(g.time_v)
    nodes = [
        Node(
            nd.idx,
            nd.name,
            float(max(1, round(levels * nd.time / tmax))),
            nd.memory,
            nd.kind,
        )
        for nd in g.nodes
    ]
    return Graph(nodes, g.edges)
