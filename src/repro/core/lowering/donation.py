"""Donation/alias hints: XLA buffer assignment realizing the analytic peak.

The interpreter backend *audits* the liveness-tight peak; the XLA backends
should *realize* it.  Two levers, both derived from the same functional:

* **Per-segment dead-at-peak hints** — the backward-window decomposition
  (``liveness.transition_excess``) prices window ``i`` as
  ``M(U_{i-1}) + excess(L_{i-1}, L_i)``: the only cached residuals charged
  are those of *earlier* segments (``U_{i-1}``) plus the window's own
  interior.  Every cached residual of a **later** segment
  (``U_k \\ U_i``) is provably dead at window ``i``'s peak — its VJP
  window already ran (backward processes segments last → first).
  :func:`donation_hints` names these per segment; the drift gate
  (``analysis.hlo.check_hlo``) confirms XLA's buffer assignment agrees.

* **Argument donation** — the planned twin's non-differentiated positional
  arguments (the batch, auxiliary inputs) are dead once their last
  (re)computation consumes them; ``jax.jit(donate_argnums=...)`` is the
  public surface that lets XLA alias their buffers into temps/outputs.
  Differentiated arguments are never donated (their values feed the VJP
  and callers keep them across steps).

Donation never changes values — gradients stay bit-identical to vanilla
``jax.value_and_grad`` — it only widens XLA's aliasing freedom; the
``check_hlo`` memory-drift gate is the acceptance test.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

from ..graph import Graph
from ..schedule import ExecutionPlan


def donation_hints(g: Graph, plan: ExecutionPlan) -> Dict[int, Tuple[str, ...]]:
    """Names of cached buffers provably dead at each window's in-peak.

    ``hints[i]`` lists the cached residuals **not** charged by the
    functional while segment ``i``'s backward window runs: exactly
    ``U_k \\ L_i`` — cached nodes of later segments, whose windows were
    already consumed when window ``i`` executes.  Sorted for determinism.
    """
    hints: Dict[int, Tuple[str, ...]] = {}
    for seg in plan.segments:
        dead = plan.cached - seg.lower_set
        hints[seg.index] = tuple(sorted(g.nodes[v].name for v in dead))
    return hints


def donatable_argnums(carrier: Any) -> Tuple[int, ...]:
    """Positional arguments of the lowered twin that are safe to donate.

    Traced carriers: every positional arg **not** differentiated
    (``carrier.argnums``) — grads are returned for the others, and the VJP
    rule may hold their values, so they stay caller-owned.  BlockGraph
    carriers: the ``inputs`` dict (arg 1; ``params`` is differentiated).
    """
    slices = getattr(carrier, "arg_slices", None)
    if slices is None:
        return (1,)  # BlockGraph convention: f(params, inputs)
    argnums = carrier.argnums
    diff = {argnums} if isinstance(argnums, int) else set(argnums)
    return tuple(i for i in range(len(slices)) if i not in diff)


def donate_lowered(
    fn_grad: Callable[..., Any],
    carrier: Any,
    g: Graph,
    plan: ExecutionPlan,
) -> Callable[..., Any]:
    """Wrap a lowered value_and_grad twin with donation-hinted ``jax.jit``.

    The returned callable carries ``donate_argnums`` (the donated
    positions) and ``donation_hints`` (the per-segment dead-at-peak names)
    as attributes for introspection and the drift-gate tests.  With no
    donatable positions, the twin is returned jitted but unhinted.
    """
    import jax

    dargs = donatable_argnums(carrier)
    jitted = (
        jax.jit(fn_grad, donate_argnums=dargs) if dargs else jax.jit(fn_grad)
    )

    def run(*args: Any) -> Any:
        return jitted(*args)

    run.donate_argnums = dargs  # type: ignore[attr-defined]
    run.donation_hints = donation_hints(g, plan)  # type: ignore[attr-defined]
    return run
