"""repro.core — the paper's contribution as a library.

Graph-theoretic recomputation planning (Kusumoto et al., NeurIPS 2019):
lower-set sequences, exact/approximate DP, memory-/time-centric strategies,
Chen's √n baseline, liveness simulation, and the bridges into JAX
(jaxpr graph extraction, checkpoint-policy lowering, segmented executor).
"""

from .chen import articulation_points, candidate_split_points, chen_sqrt_n
from .dfs import exhaustive_search
from .dp import (
    DPResult,
    approx_dp,
    cached_sets,
    exact_dp,
    overhead,
    peak_memory,
    quantize_times,
    solve,
)
from .graph import Graph, Node, chain, from_cost_lists
from .liveness import SimResult, simulate, vanilla_peak
from .lower_sets import all_lower_sets, count_lower_sets, pruned_lower_sets
from .planner import PlanReport, compare_methods, min_feasible_budget, plan
from .schedule import ExecutionPlan, Segment, make_plan, plan_summary

__all__ = [
    "Graph",
    "Node",
    "chain",
    "from_cost_lists",
    "all_lower_sets",
    "pruned_lower_sets",
    "count_lower_sets",
    "DPResult",
    "solve",
    "exact_dp",
    "approx_dp",
    "overhead",
    "peak_memory",
    "cached_sets",
    "quantize_times",
    "exhaustive_search",
    "articulation_points",
    "candidate_split_points",
    "chen_sqrt_n",
    "SimResult",
    "simulate",
    "vanilla_peak",
    "ExecutionPlan",
    "Segment",
    "make_plan",
    "plan_summary",
    "PlanReport",
    "plan",
    "compare_methods",
    "min_feasible_budget",
]
