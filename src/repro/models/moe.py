"""Mixture-of-Experts layer: top-k routing with capacity, expert parallelism.

Two execution paths:

* **shard_map EP path** (production, chosen whenever a mesh is bound and the
  shapes divide): tokens are split over every mesh axis (batch over
  pod/data, sequence over model), each device routes and packs its own
  (E, C_loc, D) dispatch buffer with a *local* scatter, and — when the
  expert count divides the model axis — one ``all_to_all`` pair moves rows
  to their expert owners and back (the Switch/Tutel schedule).  When E
  doesn't divide the axis (granite's 40 on tp=16) the expert weights stay
  replicated and the layer is entirely local: zero collectives.  Letting
  GSPMD infer this from a global scatter instead produces hundreds of GB of
  gather traffic per step — measured in EXPERIMENTS.md §Dry-run.

* **dense fallback** (no mesh / indivisible shapes / CPU tests): global
  scatter-add dispatch with the same routing math, bit-comparable at
  single-device shapes.

Tokens over capacity are dropped (standard Switch behaviour); the router
runs in float32.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.parallel.sharding import _axis_sizes, shard
from .layers import _init_normal


def moe_init(rng, d_model: int, cfg: MoEConfig):
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    E, F = cfg.num_experts, cfg.d_ff_expert
    scale_in = d_model**-0.5
    return {
        "router": _init_normal(r0, (d_model, E), scale_in),
        "experts": {
            "w_gate": _init_normal(r1, (E, d_model, F), scale_in),
            "w_up": _init_normal(r2, (E, d_model, F), scale_in),
            "w_down": _init_normal(r3, (E, F, d_model), F**-0.5),
        },
    }


def _route(router_w, xt, cfg: MoEConfig):
    """Shared routing math: (T, D) → gates (T, K), expert ids (T, K), logits."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    return gate_vals, expert_ids, logits


def _pack(xt, gate_vals, expert_ids, E: int, capacity: int, dt):
    """Scatter tokens into an (E, C, D) buffer; returns (disp, eid, pos, keep)."""
    T, D = xt.shape
    K = expert_ids.shape[-1]
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (T, K, E)
    flat_onehot = onehot.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat_onehot, axis=0) - flat_onehot
    pos = jnp.sum(pos_in_expert * flat_onehot, axis=-1)  # (T·K,)
    eid = expert_ids.reshape(T * K)
    keep = pos < capacity
    src = jnp.repeat(xt, K, axis=0)
    src = jnp.where(keep[:, None], src, 0)
    pos_c = jnp.minimum(pos, capacity - 1)
    disp = jnp.zeros((E, capacity, D), dt).at[eid, pos_c].add(src)
    return disp, eid, pos_c, keep


def _expert_ffn(w, disp, dt):
    g = jnp.einsum("ecd,edf->ecf", disp, w["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, w["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(dt))


def _combine(out_e, eid, pos_c, keep, gate_vals, T: int, K: int, D: int, dt):
    gathered = out_e[eid, pos_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    weights = gate_vals.reshape(T * K).astype(dt)
    return (gathered * weights[:, None]).reshape(T, K, D).sum(axis=1)


def _moe_shard_map(p, x: jax.Array, cfg: MoEConfig, mesh) -> Optional[jax.Array]:
    """Expert-parallel MoE under shard_map; None if the mesh/shape doesn't fit."""
    B, S, D = x.shape
    dt = x.dtype
    E, K = cfg.num_experts, cfg.top_k
    sizes = _axis_sizes(mesh)
    names = set(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    tp = sizes.get("model", 1) if "model" in names else 1
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    if dp == 1 and tp == 1:
        return None
    if B % dp:
        return None
    seq_split = tp if (tp > 1 and S % tp == 0) else 1
    ep = tp > 1 and E % tp == 0 and seq_split == tp  # all_to_all EP layout
    T_loc = (B // dp) * (S // seq_split)
    C_loc = max(8, int(math.ceil(cfg.capacity_factor * T_loc * K / E)))
    if ep and C_loc % 1:
        return None

    x_spec = P(dp_axes if dp_axes else None, "model" if seq_split > 1 else None, None)
    e_spec = (
        {k: P("model", None, None) for k in ("w_gate", "w_up", "w_down")}
        if ep
        else {k: P(None, None, None) for k in ("w_gate", "w_up", "w_down")}
    )

    def local_fn(router_w, experts_w, x_loc):
        b, s, _ = x_loc.shape
        xt = x_loc.reshape(b * s, D)
        gate_vals, expert_ids, _ = _route(router_w, xt, cfg)
        disp, eid, pos_c, keep = _pack(xt, gate_vals, expert_ids, E, C_loc, dt)
        if ep:
            # (E, C_loc, D) → (E/tp, C_loc·tp, D): rows travel to expert owners.
            # optimization_barrier pins the collective to the bf16 tensors —
            # without it XLA hoists the expert-silu f32 convert *before* the
            # all-to-all and doubles its bytes (measured: EXPERIMENTS §Perf).
            disp = jax.lax.all_to_all(
                disp, "model", split_axis=0, concat_axis=1, tiled=True
            )
            disp = jax.lax.optimization_barrier(disp)
            out = _expert_ffn(experts_w, disp, dt)
            out = jax.lax.optimization_barrier(out)
            out = jax.lax.all_to_all(
                out, "model", split_axis=1, concat_axis=0, tiled=True
            )
        else:
            out = _expert_ffn(experts_w, disp, dt)
        y = _combine(out, eid, pos_c, keep, gate_vals, b * s, K, D, dt)
        return y.reshape(b, s, D)

    fn = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(), e_spec, x_spec),
        out_specs=x_spec,
    )
    return fn(p["router"], p["experts"], x)


def moe_apply(
    p, x: jax.Array, cfg: MoEConfig, return_aux: bool = False
):
    """x: (B, S, D) → (B, S, D)[, aux-loss scalars]."""
    if not return_aux:
        from repro.parallel.compat import get_abstract_mesh

        try:
            mesh = get_abstract_mesh()
            has_mesh = mesh is not None and mesh.axis_names and not mesh.empty
        except Exception:
            has_mesh = False
        if has_mesh:
            y = _moe_shard_map(p, x, cfg, mesh)
            if y is not None:
                return y
    B, S, D = x.shape
    dt = x.dtype
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    capacity = max(8, int(cfg.capacity_factor * T * K / E))

    # position of each (token, k) slot within its expert queue
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (T, K, E)
    flat_onehot = onehot.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat_onehot, axis=0) - flat_onehot  # (T·K, E)
    pos = jnp.sum(pos_in_expert * flat_onehot, axis=-1)  # (T·K,)
    eid = expert_ids.reshape(T * K)
    keep = pos < capacity

    # dispatch: (E, C, D)
    disp = jnp.zeros((E, capacity, D), dt)
    src = jnp.repeat(xt, K, axis=0)  # (T·K, D) token replicated per route
    src = jnp.where(keep[:, None], src, 0)
    pos_c = jnp.minimum(pos, capacity - 1)
    disp = disp.at[eid, pos_c].add(src)
    # EP over the expert axis; when E doesn't divide the model axis (e.g.
    # granite's 40 experts on tp=16) the capacity rows shard instead — an
    # unsharded dispatch buffer is ~32 GB/device at production scale.
    disp = shard(disp, "experts", "expert_cap", None)

    # expert computation (batched over E, sharded = expert parallel)
    w = p["experts"]
    g = jnp.einsum("ecd,edf->ecf", disp, w["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, w["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(dt))
    out_e = shard(out_e, "experts", "expert_cap", None)

    # combine: gather each route's output, weight, sum over K
    gathered = out_e[eid, pos_c]  # (T·K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weights = gate_vals.reshape(T * K).astype(dt)
    combined = (gathered * weights[:, None]).reshape(T, K, D).sum(axis=1)
    y = combined.reshape(B, S, D)
    y = shard(y, "batch", None, "model")

    if not return_aux:
        return y
    # Switch-style load-balance loss + router z-loss
    density = probs.mean(axis=0)  # (E,)
    usage = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32).mean(axis=0)
    lb_loss = E * jnp.sum(density * usage)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}
