"""Plan explorer: the paper's Table-1 methods on any benchmark network,
assigned architecture, or **arbitrary traced JAX function**, with an ASCII
memory-vs-overhead frontier.

The whole exploration is ONE budget-free DP pass: ``Planner.solve_grid``
builds a capped sweep (core.dp.sweep) whose terminal frontier carries the
exact ``min_feasible_budget`` and every (budget → plan) point at once, and
caches it in the content-addressed plan cache under the budget-free
``sweep`` entry kind — so re-exploring a network, or pointing --cache-dir
(or REPRO_PLAN_CACHE_DIR) at a shared store, re-runs no DP at all.  When a
larger budget shows up later, the cached surface is lazily *extended*
(``Sweep.extend``), never rebuilt.

Run: PYTHONPATH=src:. python examples/plan_explorer.py --network unet
     PYTHONPATH=src:. python examples/plan_explorer.py --arch stablelm-3b
     PYTHONPATH=src:. python examples/plan_explorer.py --traced demo
     PYTHONPATH=src:. python examples/plan_explorer.py --traced pkg.mod:factory

``--traced`` explores any model via the plan_function front door: pass
``module:factory`` where ``factory()`` returns ``(fn, example_args)`` —
the function is traced (one graph node per jaxpr equation) and explored
like any benchmark network.  ``demo`` uses a built-in MLP factory.
"""

import argparse
import time

from repro.core import (
    chen_sqrt_n,
    get_default_planner,
    simulate,
    vanilla_peak,
)


def _gb(x: float) -> str:
    """Adaptive byte formatting (benchmark nets are GB, traced demos KB)."""
    if x >= 1e8:
        return f"{x/1e9:.2f} GB"
    if x >= 1e5:
        return f"{x/1e6:.2f} MB"
    return f"{x:.0f} B"


def frontier(g, n_points: int = 8, budget: float = None):
    """One sweep: exact min budget + the whole trade-off curve.

    ``budget`` anchors the explored range at a caller-chosen B instead of
    the minimal feasible one; an infeasible B exits non-zero (code 2) and
    prints the exact budget that would have worked.
    """
    planner = get_default_planner()
    fam = planner.family(g, "approx_dp")  # memoized — shared with the solves
    B_min = planner.min_feasible_budget(g, "approx_dp")  # exact, no search
    van = vanilla_peak(g, liveness=True)
    print(f"#V={g.n}  #L^pruned={len(fam)}  vanilla peak={_gb(van)}  "
          f"min_feasible_budget={_gb(B_min)} (exact)")
    if budget is not None and budget < B_min:
        print(f"budget {_gb(budget)} is INFEASIBLE: no strategy fits — "
              f"the exact minimal feasible budget is {_gb(B_min)} "
              f"({B_min:.0f} bytes); re-run with at least that")
        raise SystemExit(2)
    chen = chen_sqrt_n(g)
    chen_pk = simulate(g, chen.sequence, liveness=True).peak_memory
    print(f"Chen √n: peak {_gb(chen_pk)}, overhead "
          f"{100*chen.overhead/g.total_time:.0f}% of fwd\n")

    B_lo = budget if budget is not None else B_min
    budgets = [B_lo * (1.0 + 3.0 * i / max(n_points - 1, 1))
               for i in range(n_points)]
    t0 = time.perf_counter()
    results = planner.solve_grid(g, budgets, "approx_dp")  # one capped sweep
    grid_s = time.perf_counter() - t0
    grid_tier = planner.cache.last_tier or "local DP (now cached)"
    planner.cache.last_tier = None  # so the warm label reflects this call
    t0 = time.perf_counter()
    planner.solve(g, budgets[0], "approx_dp")
    warm_s = time.perf_counter() - t0
    warm_tier = planner.cache.last_tier or "in-process memo"
    print(f"solve_grid: {grid_s*1e3:.1f} ms (plan from {grid_tier}); "
          f"warm re-solve {warm_s*1e3:.2f} ms (from {warm_tier})\n")
    rows = []
    for res in results:
        if not res.feasible:
            continue
        pk = simulate(g, res.sequence, liveness=True).peak_memory
        oh = 100 * res.overhead / g.total_time
        rows.append((pk, oh, res.num_segments))
    if not rows:
        print(f"no feasible plan in the explored range "
              f"[{_gb(budgets[0])}, {_gb(budgets[-1])}] — the exact minimal "
              f"feasible budget is {_gb(B_min)}")
        raise SystemExit(2)
    print(f"{'peak':>12s} {'overhead%':>10s} {'segments':>9s}  frontier")
    max_oh = max(oh for _, oh, _ in rows) or 1
    for pk, oh, k in rows:
        bar = "#" * int(1 + 40 * oh / max_oh)
        print(f"{_gb(pk):>12s} {oh:10.1f} {k:9d}  {bar}")

    # the sweep's own Pareto staircase: every budget regime below the cap
    from repro.core import SweepOverflow

    try:
        crit = planner.frontier(g, "approx_dp")
    except SweepOverflow:
        return  # surface too wide for a full sweep — grid above suffices
    print(f"\n{len(crit)} critical budgets (full frontier from one sweep; "
          f"the first is min_feasible_budget):")
    for B, oh in crit[:12]:
        print(f"  B ≥ {_gb(B):>12s} → overhead {100*oh/g.total_time:5.1f}%")
    if len(crit) > 12:
        print(f"  … {len(crit) - 12} more")


def _demo_factory():
    """Built-in --traced entry: a 12-layer lax MLP with a skip connection."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    dn = (((1,), (0,)), ((), ()))

    def fn(params, x):
        h = x
        skip = None
        for i, w in enumerate(params):
            h = lax.tanh(lax.dot_general(h, w, dn))
            if i == 2:
                skip = h
            if i == 8:
                h = h + skip
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (64, 64)) * 0.2
        for i in range(12)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    return fn, (params, x)


def _bg_demo_factory():
    """Built-in BlockGraph demo: a 6-block tanh·matmul chain plus loss.

    With ``--backend jaxpr`` the BlockGraph is traced *whole* and planned
    at equation granularity (finer than blocks when XLA fusion allows) —
    the ISSUE-4 satellite path.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.core.blockgraph import Block, BlockGraph

    dn = (((1,), (0,)), ((), ()))

    def mk(name, src):
        return Block(
            name=name,
            apply=lambda p, h: lax.tanh(lax.dot_general(h, p["w"], dn)),
            inputs=(src,),
            init=lambda rng, shp: {
                "w": jax.random.normal(rng, (shp[-1], shp[-1])) * 0.2
            },
        )

    bg = BlockGraph([mk(f"b{i}", "x" if i == 0 else f"b{i-1}")
                     for i in range(6)], ["x"], ["b5"])
    params = bg.init(jax.random.PRNGKey(0), {"x": (16, 64)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(1), (16, 64))}
    loss = lambda out: jnp.sum(out * out)
    return bg, (params, inputs), loss


def traced_graph(spec: str, backend: str = "auto"):
    """``module:factory`` / ``demo`` / ``bg-demo`` → paper graph via the
    front door, planned with the chosen lowering ``backend``."""
    loss_fn = None
    if spec == "demo":
        fn, args = _demo_factory()
    elif spec == "bg-demo":
        fn, args, loss_fn = _bg_demo_factory()
    else:
        import importlib

        mod_name, _, attr = spec.partition(":")
        if not attr:
            raise SystemExit(
                f"--traced wants 'module:factory', 'demo' or 'bg-demo', "
                f"got {spec!r}"
            )
        fn, args = getattr(importlib.import_module(mod_name), attr)()
    import repro

    planned = repro.plan_function(fn, backend=backend, loss_fn=loss_fn)
    lowered = planned.lowered_for(*args)
    g = lowered.carrier.to_graph()
    print(f"traced {spec}: {g.n} nodes, backend {lowered.backend!r}, "
          f"plan at min_feasible_budget: {len(lowered.plan.segments)} "
          f"segments, overhead {lowered.plan.overhead:.0f} T-units")
    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default=None,
                    help="one of the paper's nets (benchmarks.networks)")
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--traced", default=None,
                    help="'demo', 'bg-demo' (BlockGraph at equation "
                         "granularity with --backend jaxpr) or "
                         "'module:factory' returning (fn, example_args)")
    ap.add_argument("--backend", default="auto",
                    help="lowering backend for --traced (auto | jaxpr | "
                         "policy | segment | interpreter)")
    ap.add_argument("--budget", type=float, default=None,
                    help="anchor the explored budget range at B bytes; an "
                         "infeasible B exits with code 2 and prints the "
                         "exact minimal feasible budget")
    ap.add_argument("--cache-dir", default=None,
                    help="on-disk plan cache (re-runs become lookups)")
    ap.add_argument("--remote", default=None,
                    help="fleet plan store path/URL (read-through under the "
                         "local tiers; see docs/plan_cache.md)")
    args = ap.parse_args()

    if args.cache_dir:
        from repro.core import set_default_cache_dir

        set_default_cache_dir(args.cache_dir)
    if args.remote:
        from repro.core import set_default_remote_store

        set_default_remote_store(args.remote)

    if args.traced:
        g = traced_graph(args.traced, backend=args.backend)
    elif args.arch:
        from repro.configs import SHAPES, get_config
        from repro.launch.plan import chain_graph, plan_inputs

        cfg = get_config(args.arch)
        pi = plan_inputs(cfg, SHAPES["train_4k"], dp_shards=16, model_shards=16)
        g = chain_graph(pi)
        print(f"arch {args.arch}: unit chain, {pi.n_units} units, "
              f"interior {pi.bytes_interior/1e9:.2f} GB/unit")
    else:
        from benchmarks.networks import NETWORKS

        name = args.network or "unet"
        g = NETWORKS[name]()
        print(f"network {name}:")
    frontier(g, budget=args.budget)


if __name__ == "__main__":
    main()
