"""Plan-cache microbenchmark: cold vs warm planning time.

Measures ``Planner.plan`` on repeated (graph, budget) pairs:

* **cold**  — empty cache: full exact DP over 𝓛_G (exponential, §4.2);
* **warm**  — same process, in-memory LRU hit;
* **disk**  — fresh process simulation: new ``PlanCache`` over the same
  on-disk store (content-addressed JSON), so only the canonical graph
  digest + file read are paid.

Acceptance gate (ISSUE 1): warm ≥ 10× faster than cold, and the cached
DPResult bit-identical to the freshly solved one.

Run: PYTHONPATH=src:. python -m benchmarks.plan_cache
"""

from __future__ import annotations

import random
import tempfile
import time
from typing import Dict

from repro.core import PlanCache, Planner, min_feasible_budget
from repro.core.graph import Graph, Node


def dense_dag(n: int, seed: int = 0, p: float = 0.3) -> Graph:
    """Random DAG dense enough that 𝓛_G is large (slow exact DP)."""
    r = random.Random(seed)
    edges = [
        (i, j) for i in range(n) for j in range(i + 1, n) if r.random() < p
    ]
    nodes = [
        Node(i, f"v{i}", r.choice([1.0, 10.0]), float(r.randint(1, 6)))
        for i in range(n)
    ]
    return Graph(nodes, edges)


def _identical(a, b) -> bool:
    return (
        a.feasible == b.feasible
        and a.sequence == b.sequence
        and a.overhead == b.overhead
        and a.peak_memory == b.peak_memory
    )


def run(n: int = 13, budgets=(1.2, 1.5, 2.0)) -> Dict[str, float]:
    g = dense_dag(n)
    B0 = min_feasible_budget(g, "exact_dp")

    with tempfile.TemporaryDirectory() as store:
        cold_planner = Planner(cache=PlanCache(cache_dir=store))
        t0 = time.perf_counter()
        cold = [cold_planner.solve(g, B0 * s, "exact_dp") for s in budgets]
        t_cold = time.perf_counter() - t0

        t0 = time.perf_counter()
        warm = [cold_planner.solve(g, B0 * s, "exact_dp") for s in budgets]
        t_warm = time.perf_counter() - t0

        # fresh in-memory cache over the same disk store = restarted process
        disk_planner = Planner(cache=PlanCache(cache_dir=store))
        t0 = time.perf_counter()
        disk = [disk_planner.solve(g, B0 * s, "exact_dp") for s in budgets]
        t_disk = time.perf_counter() - t0

    assert all(_identical(c, w) for c, w in zip(cold, warm)), "warm ≠ cold"
    assert all(_identical(c, d) for c, d in zip(cold, disk)), "disk ≠ cold"

    speedup_warm = t_cold / max(t_warm, 1e-9)
    speedup_disk = t_cold / max(t_disk, 1e-9)
    print(f"graph: n={n}, |E|={len(g.edges)}, budgets={list(budgets)}")
    print(f"cold : {t_cold*1e3:9.1f} ms   (exact DP per budget)")
    print(f"warm : {t_warm*1e3:9.1f} ms   ({speedup_warm:,.0f}× vs cold, LRU hit)")
    print(f"disk : {t_disk*1e3:9.1f} ms   ({speedup_disk:,.0f}× vs cold, "
          f"content-addressed store)")
    print(f"plans bit-identical across cold/warm/disk: True")
    assert speedup_warm >= 10.0, f"warm speedup {speedup_warm:.1f}× < 10×"
    return {
        "t_cold": t_cold,
        "t_warm": t_warm,
        "t_disk": t_disk,
        "speedup_warm": speedup_warm,
        "speedup_disk": speedup_disk,
    }


def main():
    print("\n== plan cache: cold vs warm planning ==")
    return run()


if __name__ == "__main__":
    main()
