"""Per-segment checkpoint backend, plus the layer-chain projections.

The third lowering of the canonical strategy: each segment V_i runs inside
its own ``jax.checkpoint`` — its residuals are its *inputs* (exactly the
cached boundary values ∂(L_{i-1}) ∪ earlier caches it consumes) and its
interior is recomputed during backward.  For scan-over-layers production
models the same plan projects to grouped scan remat (``segment_groups`` /
``SegmentPlan`` in ``launch.plan``): segments become inner-scan groups, so
the DP plan drives ``models.transformer`` without leaving the scan.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax

from ..schedule import ExecutionPlan
from .base import (
    Lowering,
    blockgraph_value_and_grad,
    register_lowering,
    reject_track_live,
)
from .carriers import BlockGraphCarrier


def _memory_kind_put(x, kind: str):
    """Best-effort ``device_put`` to a memory kind (``pinned_host`` /
    ``device``).  Backends without host memory spaces — or eager execution,
    where ``TransferToMemoryKind`` is jit-only — fall back to the identity:
    the value stays on device, which is numerically exact (offload is a
    placement hint, never a value change)."""
    if not hasattr(x, "dtype"):
        return x
    try:
        from jax._src.sharding_impls import TransferToMemoryKind

        return jax.device_put(x, TransferToMemoryKind(kind))
    except Exception:
        return x


def _apply_storage_strategy(val, code):
    """Realize one cached value's storage strategy (pytree-wide)."""
    from repro.optim.compression import straight_through_roundtrip
    import jax.numpy as jnp

    if code == "offload":
        return jax.tree_util.tree_map(
            lambda x: _memory_kind_put(x, "pinned_host"), val
        )
    if code == "quantize":
        return jax.tree_util.tree_map(
            lambda x: straight_through_roundtrip(x)
            if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)
            else x,
            val,
        )
    return val


def constrain_block_output(out, block, mesh):
    """Pin an annotated block output to its sharding (no-op without a
    concrete Mesh — abstract ``{axis: size}`` meshes only drive accounting)."""
    from jax.sharding import Mesh, NamedSharding

    if mesh is None or block.out_sharding is None or not isinstance(mesh, Mesh):
        return out
    from ..blockgraph import block_spec
    from repro.parallel.sharding import axis_sizes_of

    sizes = axis_sizes_of(mesh)

    def pin(x):
        if not hasattr(x, "shape"):
            return x
        spec = block_spec(block, tuple(x.shape), sizes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(pin, out)


def apply_segmented(bg, params: Dict[str, Any], inputs: Dict[str, Any],
                    plan: ExecutionPlan, checkpoint_policy=None,
                    mesh=None) -> Any:
    """Execute a BlockGraph under the plan: per-segment ``jax.checkpoint``.

    Each segment V_i runs inside ``jax.checkpoint``: its residuals are its
    *inputs* — exactly the cached boundary values ∂(L_{i-1}) ∪ earlier
    caches it consumes — and its interior is recomputed during backward,
    which is precisely §3's canonical strategy.  With ``mesh``, blocks
    annotated with ``out_sharding`` keep the caller's shardings on both the
    cached boundaries and the recomputed interiors (pjit-composability).
    """
    name_of = {i: b.name for i, b in enumerate(bg.blocks)}
    values: Dict[str, Any] = dict(inputs)
    # per-name storage strategy (joint memory-strategy DP): offloaded cache
    # entries live in host memory between their forward and backward use;
    # quantized ones round-trip through optim.compression (straight-through
    # gradient), so every later consumer sees the replay-from-storage value
    strat = {
        name_of[v]: code
        for v, code in (plan.strategy or {}).items()
        if v in name_of
    }

    def fetch(name: str):
        v = values[name]
        if strat.get(name) == "offload":
            return jax.tree_util.tree_map(
                lambda x: _memory_kind_put(x, "device"), v
            )
        return v

    for seg in plan.segments:
        seg_blocks = [bg.by_name[name_of[v]] for v in seg.nodes]
        # external inputs of this segment (cached boundary values)
        internal = {b.name for b in seg_blocks}
        ext_names: List[str] = []
        for b in seg_blocks:
            for i in b.inputs:
                if i not in internal and i not in ext_names:
                    ext_names.append(i)
        # values the rest of the graph needs from this segment
        out_names = [
            b.name
            for b in seg_blocks
            if _needed_later(bg, b.name, internal)
        ]

        def seg_fn(seg_params, *ext_vals, _blocks=seg_blocks,
                   _ext=tuple(ext_names), _out=tuple(out_names)):
            local: Dict[str, Any] = dict(zip(_ext, ext_vals))
            for b in _blocks:
                local[b.name] = constrain_block_output(
                    b.apply(
                        seg_params[b.name], *[local[i] for i in b.inputs]
                    ),
                    b, mesh,
                )
            return tuple(local[o] for o in _out)

        seg_params = {b.name: params[b.name] for b in seg_blocks}
        wrapped = jax.checkpoint(seg_fn, policy=checkpoint_policy)
        outs = wrapped(seg_params, *[fetch(i) for i in ext_names])
        for name, out in zip(out_names, outs):
            values[name] = _apply_storage_strategy(out, strat.get(name))

    res = tuple(fetch(o) for o in bg.outputs)
    return res[0] if len(res) == 1 else res


def _needed_later(bg, name: str, internal: set) -> bool:
    if name in bg.outputs:
        return True
    for b in bg.blocks:
        if name in b.inputs and b.name not in internal:
            return True
    return False


# ---------------------------------------------------------------------------
# Layer-chain projections (scan-over-layers production models)
# ---------------------------------------------------------------------------


def segment_groups(plan: ExecutionPlan, num_layers: int,
                   nodes_per_layer: int = 1) -> List[int]:
    """Layer-group sizes [g₁, …, g_k] induced by the plan on a layer chain.

    For the scan-over-layers production models the graph is a chain of
    ``num_layers`` macro-nodes; the plan's segments V_i are contiguous layer
    runs.  Returns the run lengths, which models.transformer uses to build a
    per-group ``jax.checkpoint`` inner scan (segment remat ≙ canonical
    strategy on the chain graph).
    """
    sizes = []
    for seg in plan.segments:
        n_nodes = len(seg.nodes)
        if n_nodes % nodes_per_layer:
            raise ValueError(
                f"segment {seg.index} has {n_nodes} nodes, not a multiple of "
                f"{nodes_per_layer} per layer — plan does not align to layers"
            )
        sizes.append(n_nodes // nodes_per_layer)
    if sum(sizes) != num_layers:
        raise ValueError(f"plan covers {sum(sizes)} layers, model has {num_layers}")
    return sizes


def even_groups(num_layers: int, num_segments: int) -> List[int]:
    """Chen-style √n fallback grouping (equal-size contiguous segments)."""
    base, extra = divmod(num_layers, num_segments)
    return [base + (1 if i < extra else 0) for i in range(num_segments)]


# ---------------------------------------------------------------------------
# Registry glue
# ---------------------------------------------------------------------------


class SegmentLowering(Lowering):
    """Per-segment ``jax.checkpoint`` over a BlockGraph."""

    name = "segment"

    def supports(self, carrier) -> bool:
        return isinstance(carrier, BlockGraphCarrier)

    def lower(self, carrier, plan: ExecutionPlan, track_live: bool = False,
              donate: bool = False):
        if track_live:
            reject_track_live(self.name)
        fn = blockgraph_value_and_grad(
            lambda p, x, _bg=carrier.bg, _plan=plan, _m=carrier.mesh:
                apply_segmented(_bg, p, x, _plan, mesh=_m),
            carrier.loss_fn,
        )
        if donate:
            from .donation import donate_lowered

            fn = donate_lowered(fn, carrier, carrier.to_graph(), plan)
        return fn


register_lowering(SegmentLowering())
