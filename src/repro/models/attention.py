"""Attention: GQA for train/prefill (dense or chunked memory-efficient) and
single-step decode against a KV cache.

Long sequences never materialize the (S, S) score matrix: ``chunked_attention``
scans over KV blocks with an online softmax (the XLA twin of the Pallas
flash kernel in repro.kernels — the kernel is the TPU hot path, this is the
portable lowering the dry-run compiles).  This is itself an instance of the
paper's theme: the score matrix is *recomputed* blockwise in the backward
pass instead of being cached.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard
from .layers import _init_normal, apply_rope


def attention_init(
    rng,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    qkv_bias: bool = False,
):
    rq, rk, rv, ro = jax.random.split(rng, 4)
    scale = d_model**-0.5
    p = {
        "wq": _init_normal(rq, (d_model, n_heads * d_head), scale),
        "wk": _init_normal(rk, (d_model, n_kv_heads * d_head), scale),
        "wv": _init_normal(rv, (d_model, n_kv_heads * d_head), scale),
        "wo": _init_normal(ro, (n_heads * d_head, d_model), (n_heads * d_head) ** -0.5),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
    return p


def qkv_proj(p, x, n_heads, n_kv_heads, d_head, positions, rope_theta):
    B, S, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv_heads, d_head)
    v = v.reshape(B, S, n_kv_heads, d_head)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) → (B, S, H, D) by repeating each kv head H/KV times."""
    B, S, KV, D = k.shape
    rep = n_heads // KV
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Reference O(S²)-memory attention. q (B,S,H,D), k/v (B,S,KV,D)."""
    B, S, H, D = q.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Memory-efficient attention: online softmax over KV chunks.

    Never materializes more than (B, H, q_chunk, kv_chunk) scores.  Wrapped in
    jax.checkpoint at the call site so the backward recomputes blocks — the
    flash-attention recipe expressed in XLA.
    """
    B, S, H, D = q.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    Sk = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Sk)
    # pad to chunk multiples (e.g. VLM prefix makes S = 32768 + 576); padded
    # KV rows sit beyond every real query position, so the causal mask
    # excludes them; padded Q rows are sliced off at the end.
    orig_S = S
    pad_q = (-S) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q or pad_k:
        assert causal, "chunk padding requires causal masking"
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        S += pad_q
        Sk += pad_k
    nq, nk = S // q_chunk, Sk // kv_chunk

    qs = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    ks = k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(D)

    def per_q_chunk(qi, q_blk):
        # online softmax state: (acc, row_max, row_sum)
        acc0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        s0 = jnp.zeros((B, H, q_chunk), jnp.float32)

        def body(carry, inputs):
            acc, m, s = carry
            ki, (k_blk, v_blk) = inputs
            scores = (
                jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32)
                * scale
            )
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                scores = jnp.where(mask, scores, -jnp.inf)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(scores - m_safe[..., None])
            p = jnp.where(jnp.isfinite(scores), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            s = s * alpha + p.sum(axis=-1)
            return (acc, m_new, s), None

        (acc, m, s), _ = jax.lax.scan(
            body, (acc0, m0, s0), (jnp.arange(nk), (ks, vs))
        )
        out = acc / jnp.maximum(s[..., None], 1e-30)
        return out  # (B,H,qc,D)

    outs = jax.lax.map(lambda t: per_q_chunk(t[0], t[1]), (jnp.arange(nq), qs))
    # (nq,B,H,qc,D) → (B, S, H, D)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return out[:, :orig_S].astype(q.dtype)


def attention(
    p,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    chunked_threshold: int = 8192,
    backend: str = "auto",
) -> jax.Array:
    """Self-attention over a full sequence (train / prefill).

    backend: "auto" → Pallas flash kernel on TPU, XLA path elsewhere;
             "kernel" / "xla" force one side (tests compare the two).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = qkv_proj(p, x, n_heads, n_kv_heads, d_head, positions, rope_theta)
    use_kernel = backend == "kernel" or (
        backend == "auto" and jax.default_backend() == "tpu" and S % 128 == 0
    )
    if use_kernel:
        from repro.kernels.ops import flash_attention as _flash

        ctx = _flash(q, k, v, causal=causal)
    elif S > chunked_threshold:
        ctx = jax.checkpoint(
            lambda q_, k_, v_: chunked_attention(q_, k_, v_, causal=causal)
        )(q, k, v)
    else:
        ctx = dense_attention(q, k, v, causal=causal)
    ctx = shard(ctx, "batch", None, "heads", None)
    out = jnp.einsum(
        "bsz,zd->bsd", ctx.reshape(B, S, n_heads * d_head), p["wo"].astype(x.dtype)
    )
    return shard(out, "batch", None, "model")


def decode_attention(
    p,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    position: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step.  x (B,1,d); cache_k/v (B,S,KV,D); position (B,).

    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, 1, n_heads, d_head)
    k = k.reshape(B, 1, n_kv_heads, d_head)
    v = v.reshape(B, 1, n_kv_heads, d_head)
    if rope_theta:
        q = apply_rope(q, position[:, None], rope_theta)
        k = apply_rope(k, position[:, None], rope_theta)

    # in-place cache update at `position`
    def upd(cache, new):
        return jax.vmap(
            lambda c, n, pos: jax.lax.dynamic_update_slice_in_dim(c, n, pos, axis=0)
        )(cache, new, position)

    cache_k = upd(cache_k, k)
    cache_v = upd(cache_v, v)
    cache_k = shard(cache_k, "batch", "seq_sp", "kv_heads", None)
    cache_v = shard(cache_v, "batch", "seq_sp", "kv_heads", None)

    S = cache_k.shape[1]
    kf = _expand_kv(cache_k, n_heads)
    vf = _expand_kv(cache_v, n_heads)
    scores = (
        jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
        / math.sqrt(d_head)
    )
    valid = (jnp.arange(S)[None, :] <= position[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    out = jnp.einsum(
        "bsz,zd->bsd", ctx.reshape(B, 1, n_heads * d_head), p["wo"].astype(dt)
    )
    return out, cache_k, cache_v
