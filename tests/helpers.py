"""Shared pure-Python test helpers (importable from any test module).

Kept separate from ``conftest.py`` so test modules can import them with a
plain ``from helpers import ...`` — cross-importing between test *modules*
(e.g. ``from test_graph import ...``) breaks under isolated collection
(``pytest tests/test_lower_sets.py`` alone, or xdist workers).
"""

import itertools

from repro.core.graph import Graph


def brute_lower_sets(g: Graph):
    """All lower sets of ``g`` by brute force over 2^V — the test oracle."""
    out = set()
    for r in range(g.n + 1):
        for comb in itertools.combinations(range(g.n), r):
            if g.is_lower_set(comb):
                out.add(frozenset(comb))
    return out
