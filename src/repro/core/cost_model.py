"""Measured per-op cost model: profiled T_v instead of FLOP proxies.

§3 of the paper: "We can either directly measure T_v … or use some form of
approximation."  The seed repo only approximated (10/1 for heavy/light, or
analytic FLOPs); this module *measures*.  It times three representative op
classes on the current backend — a matmul (the ``dot_general`` family), the
Pallas flash-attention kernel from ``repro.kernels`` (interpret mode off-TPU,
compiled on TPU), and a memory-bound elementwise chain — and distills them
into throughput rates:

* ``sec_per_flop_matmul``     — compute-bound ops priced by their FLOPs;
* ``sec_per_flop_attention``  — attention-kind nodes (the recompute-in-bwd
  kernel has a different achieved-FLOP rate than a plain matmul);
* ``sec_per_byte_elementwise``— everything else priced by its output bytes
  (memory-bound on every backend).

``calibrated_graph`` maps a FLOP-carrying graph (``jaxpr_graph`` with
``cost_model="flops"``, or ``launch.plan.chain_graph`` whose interior nodes
carry unit FLOPs) to measured seconds, then feeds the result through
``dp.quantize_times`` — giving the DP an integer t-axis whose *ratios* are
hardware-true rather than FLOP-proportional.  Profiles are content-addressed
on disk (backend + JAX version) via the same atomic-JSON machinery as the
plan cache, so a process profiles at most once per backend, ever.

Sharded graphs price **per shard**: a carrier traced under a mesh
(``core.jaxpr_graph`` with ``mesh=``) emits per-shard FLOPs in ``time`` for
compute-bound kinds (a matmul/attention output split k ways costs each
device 1/k of the global work) and per-device bytes in ``memory`` for
everything else — so ``node_seconds`` below yields per-device seconds with
no sharding-specific branch here, and the DP trades one accelerator's time
against one accelerator's memory, exactly the paper's single-device budget
semantics lifted onto a mesh.

Calibration deliberately changes ``T_v`` and therefore the graph digest
(``core.graph.graph_digest``): plans cached under a FLOP cost model and
plans cached under a measured profile never alias, and re-profiling on new
hardware invalidates old plans by construction.

Not meaningful for the paper's abstract {1, 10} cost graphs — those already
*are* a (coarse) measured model; calibration is for production graphs whose
``time`` field carries FLOPs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence

from .dp import quantize_times
from .graph import Graph, Node
from .prims import ATTENTION_KINDS, MATMUL_KINDS  # shared tables (core.prims)

# Host-link (PCIe-gen4-x16-class) and int8 block-codec throughputs pricing
# the "offload"/"quantize" storage strategies.  Defined in core.strategies
# (import-light) and re-exported here as the cost-model surface; a measured
# OpProfile can override them per backend.
from .strategies import (  # noqa: F401  (re-export)
    DEFAULT_HOST_BYTES_PER_SEC,
    DEFAULT_QUANTIZE_BYTES_PER_SEC,
)

PROFILE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class OpProfile:
    """Measured throughput rates for one backend (seconds per unit work)."""

    sec_per_flop_matmul: float
    sec_per_flop_attention: float
    sec_per_byte_elementwise: float
    backend: str = "unknown"
    jax_version: str = "unknown"
    #: Host-link (PCIe/ICI) bandwidth for offloaded residuals; defaulted so
    #: profiles serialized before the strategy lattice existed still load.
    host_bytes_per_sec: float = DEFAULT_HOST_BYTES_PER_SEC
    #: int8 block-codec throughput for quantized residuals.
    quantize_bytes_per_sec: float = DEFAULT_QUANTIZE_BYTES_PER_SEC
    #: Where the rates came from: "measured" (microbenchmarks, the default),
    #: "analytic" (DEFAULT_PROFILE's roofline constants), or "compiled"
    #: (XLA cost_analysis per-segment numbers, see
    #: ``compiled_calibrated_graph``).  Non-measured sources are suffixed
    #: into ``profile_key`` so differently-sourced calibrations never share
    #: a cache identity.
    source: str = "measured"

    def profile_key(self) -> str:
        base = f"{self.backend}-{self.jax_version}-v{PROFILE_VERSION}"
        return base if self.source == "measured" else f"{base}-{self.source}"


#: Analytical fallback (rough TPU-v5e-class numbers) used when profiling is
#: disabled or fails — keeps calibration total-order-correct without timing.
DEFAULT_PROFILE = OpProfile(
    sec_per_flop_matmul=1.0 / 100e12,
    sec_per_flop_attention=1.0 / 50e12,
    sec_per_byte_elementwise=1.0 / 500e9,
    backend="analytic",
    jax_version="-",
    source="analytic",
)


def _median(xs: Sequence[float]) -> float:
    xs = sorted(xs)
    return xs[len(xs) // 2]


def _time_call(fn: Any, *args: Any, repeats: int = 3) -> float:
    """Median wall time of ``fn(*args)`` with warmup (jit compile excluded)."""
    import jax

    out = fn(*args)
    jax.block_until_ready(out)  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return max(_median(ts), 1e-9)


def profile_ops(
    matmul_dim: int = 512,
    elem_elems: int = 1 << 22,
    attn_shape: tuple = (1, 128, 2, 32),
    repeats: int = 3,
    include_attention: bool = True,
) -> OpProfile:
    """Time representative ops on the current backend and fit the rates.

    Shapes are deliberately small: this runs inside tests and cold starts.
    On CPU the flash-attention kernel runs in Pallas interpret mode — the
    same kernel body, so the measured ratio is still the right *relative*
    signal, which is all the DP consumes after quantization.
    """
    import jax
    import jax.numpy as jnp

    backend = jax.default_backend()
    key = jax.random.PRNGKey(0)

    # --- matmul: 2·n³ FLOPs --------------------------------------------------
    a = jax.random.normal(key, (matmul_dim, matmul_dim), jnp.float32)
    b = jax.random.normal(key, (matmul_dim, matmul_dim), jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    t_mm = _time_call(mm, a, b, repeats=repeats)
    sec_per_flop_mm = t_mm / (2.0 * matmul_dim**3)

    # --- elementwise chain: memory-bound, ~4 passes over the array -----------
    x = jax.random.normal(key, (elem_elems,), jnp.float32)
    ew = jax.jit(lambda v: jnp.tanh(v * 1.5 + 0.5) * v)
    t_ew = _time_call(ew, x, repeats=repeats)
    sec_per_byte = t_ew / (4.0 * elem_elems * 4)

    # --- attention kernel ----------------------------------------------------
    sec_per_flop_attn = sec_per_flop_mm * 2.0  # fallback: half matmul rate
    if include_attention:
        try:
            from repro.kernels import flash_attention

            B, S, H, D = attn_shape
            q = jax.random.normal(key, (B, S, H, D), jnp.float32)
            fa = jax.jit(lambda qq: flash_attention(qq, qq, qq, causal=True))
            t_fa = _time_call(fa, q, repeats=max(1, repeats - 1))
            attn_flops = 4.0 * B * H * S * S * D  # qk^T + pv
            sec_per_flop_attn = t_fa / attn_flops
        except Exception:
            pass  # interpret-mode kernel unavailable → keep the fallback rate

    return OpProfile(
        sec_per_flop_matmul=float(sec_per_flop_mm),
        sec_per_flop_attention=float(sec_per_flop_attn),
        sec_per_byte_elementwise=float(sec_per_byte),
        backend=backend,
        jax_version=jax.__version__,
    )


# ---------------------------------------------------------------------------
# Disk-cached profiles (one timing run per backend, ever).
# ---------------------------------------------------------------------------


def _profile_path(cache_dir: str, backend: str, jax_version: str) -> str:
    import os

    name = f"op_profile_{backend}_{jax_version}_v{PROFILE_VERSION}.json"
    return os.path.join(cache_dir, "profiles", name.replace("/", "_"))


def load_or_profile(
    cache_dir: Optional[str] = None, profiler: Any = profile_ops
) -> OpProfile:
    """Load the backend's profile from ``cache_dir`` or measure and store it.

    With ``cache_dir=None`` the plan cache's directory is used when attached
    (so plans and the profile that priced them live side by side); without
    either, the profile is measured fresh (still just a few hundred ms).
    """
    import jax

    from repro.checkpointing.store import atomic_write_json, read_json

    from .plan_cache import default_cache

    cache_dir = cache_dir or default_cache().cache_dir
    backend, version = jax.default_backend(), jax.__version__
    path = _profile_path(cache_dir, backend, version) if cache_dir else None

    if path:
        raw = read_json(path)
        if raw and raw.get("version") == PROFILE_VERSION:
            try:
                return OpProfile(
                    sec_per_flop_matmul=float(raw["sec_per_flop_matmul"]),
                    sec_per_flop_attention=float(raw["sec_per_flop_attention"]),
                    sec_per_byte_elementwise=float(raw["sec_per_byte_elementwise"]),
                    backend=str(raw["backend"]),
                    jax_version=str(raw["jax_version"]),
                    source=str(raw.get("source", "measured")),
                )
            except (KeyError, TypeError, ValueError):
                pass  # torn/stale file → re-profile

    prof = profiler()
    if path:
        try:
            atomic_write_json(
                path, {"version": PROFILE_VERSION, **dataclasses.asdict(prof)}
            )
        except OSError:
            pass  # unusable store → just re-profile next process
    return prof


# ---------------------------------------------------------------------------
# Applying a profile to a graph.
# ---------------------------------------------------------------------------


def node_seconds(nd: Node, profile: OpProfile) -> float:
    """Calibrated wall-clock estimate for one node.

    Compute-bound kinds read FLOPs from ``time``; all other kinds are priced
    memory-bound from their output bytes (``memory``).  The floor keeps
    Graph's positive-cost invariant.
    """
    if nd.kind in MATMUL_KINDS:
        sec = nd.time * profile.sec_per_flop_matmul
    elif nd.kind in ATTENTION_KINDS:
        sec = nd.time * profile.sec_per_flop_attention
    else:
        sec = nd.memory * profile.sec_per_byte_elementwise
    return max(sec, 1e-12)


def measured_times(g: Graph, profile: OpProfile) -> Graph:
    """New graph with ``T_v`` = calibrated seconds (topology/memory kept)."""
    nodes = [
        Node(nd.idx, nd.name, node_seconds(nd, profile), nd.memory, nd.kind,
             must_store=nd.must_store)
        for nd in g.nodes
    ]
    return Graph(nodes, g.edges,
                 cost_source=f"profile:{profile.profile_key()}")


def calibrated_graph(g: Graph, profile: OpProfile, levels: int = 64) -> Graph:
    """Measured seconds → integer DP t-axis (``dp.quantize_times``).

    This is the drop-in replacement for ``quantize_times(flop_graph)``: same
    output contract (small positive integer ``T_v``), hardware-true ratios.
    """
    return quantize_times(measured_times(g, profile), levels=levels)


# ---------------------------------------------------------------------------
# Compiled-cost calibration (XLA cost_analysis instead of microbenchmarks).
# ---------------------------------------------------------------------------


def roofline_seconds(flops: float, nbytes: float, profile: OpProfile) -> float:
    """Roofline wall-clock estimate: max of compute and memory time."""
    return max(
        flops * profile.sec_per_flop_matmul,
        nbytes * profile.sec_per_byte_elementwise,
        1e-12,
    )


def compiled_calibrated_graph(
    g: Graph,
    plan: Any,
    seg_costs: Sequence[Dict[str, float]],
    profile: Optional[OpProfile] = None,
    levels: int = 64,
) -> Graph:
    """Re-price ``T_v`` from XLA's own per-segment FLOPs / bytes-accessed.

    ``seg_costs`` is ``analysis.hlo.extract_segment_costs`` output: one
    ``{"flops", "bytes"}`` dict per ``plan.segments`` entry, measured by
    compiling each segment's sub-jaxpr in isolation and asking
    ``compiled.cost_analysis()`` — compiler truth after fusion and
    simplification, which analytic FLOP counting cannot see.  Each segment's
    roofline seconds are distributed over its nodes proportionally to their
    analytic ``T_v`` (compiler truth at segment granularity, analytic ratios
    within), then quantized for the DP.  The result carries
    ``cost_source="compiled:<profile key>"`` so compiled-calibrated plans
    never collide with flops- or microbenchmark-priced ones in the plan
    cache.
    """
    if profile is None:
        profile = dataclasses.replace(DEFAULT_PROFILE, source="compiled")
    secs = list(g.time_v)
    for seg, cost in zip(plan.segments, seg_costs):
        seg_sec = roofline_seconds(
            float(cost.get("flops", 0.0)), float(cost.get("bytes", 0.0)), profile
        )
        total = sum(g.time_v[v] for v in seg.nodes) or 1.0
        for v in seg.nodes:
            secs[v] = max(seg_sec * (g.time_v[v] / total), 1e-12)
    nodes = [
        Node(nd.idx, nd.name, secs[nd.idx], nd.memory, nd.kind,
             must_store=nd.must_store)
        for nd in g.nodes
    ]
    priced = Graph(nodes, g.edges,
                   cost_source=f"compiled:{profile.profile_key()}")
    return quantize_times(priced, levels=levels)
