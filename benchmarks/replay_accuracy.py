"""Replay accuracy + wall-clock plan selection on the benchmark nets (PR 9).

Two questions the discrete-event replay (``core.replay``) must answer to be
trusted as a *selection* signal:

1. **Accuracy** — does the replayed step time predict the *measured* step
   time of the executable twin?  Per net we calibrate the replay from two
   vanilla measurements only (a forward pass and a ``value_and_grad`` step,
   plus a per-op-kind microbenchmark for the conv/elementwise rate ratio —
   never from a planned run), then compare the no-overlap replay of each
   plan against the measured planned twin
   (``jax.checkpoint`` + ``save_only_these_names``, the same lowering the
   production ``"jaxpr"`` backend emits).  Guard: within
   ``PRED_REL_TOL`` (25 %) on every net × plan.

2. **Selection** — does ``objective="wallclock"`` pick plans that *measure*
   no slower than the abstract overhead-optimal plan at the same budget?
   The time-centric plan minimizes the paper's 10/1 FLOP overhead; the
   wall-clock plan is selected on the *calibrated* graph (measured per-kind
   rates), so where the hardware's real cost ratios diverge from the
   abstract model the two disagree — and the wall-clock pick must win.
   Guards: ``wc_meas ≤ tc_meas · WC_SLOWDOWN_TOL`` on every net (the pick
   is only as good as its calibrated model, so a noise-floor-sized
   tolerance applies), and at least one net where the wall-clock plan
   ties or beats the overhead-optimal plan's measured step
   (``WC_BEAT_TOL``; on the full net set the win is strict — e.g. pspnet
   measures ~7 % under the overhead-optimal plan with a different
   cache set).

Every run writes ``BENCH_replay.json`` — per-net replayed (overlap on/off)
vs measured step seconds for both plans, the calibration constants, and the
guard verdicts; ``--smoke`` trims the net set and exits 1 on any guard
violation (wired into CI, artifact uploaded per commit).

CPU note: the twins are toy-shaped (µs-scale steps), so all timings are
min-of-``REPS`` after warmup, and the overlap-on column is reported but
never guarded against CPU measurements — a single-stream CPU cannot
realize the overlap the model prices for accelerators.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import dp as dp_mod
from repro.core import make_plan, replay
from repro.core.graph import Graph, Node
from repro.core.lower_sets import pruned_lower_sets

from .networks import NETWORKS, executable_twin

SMOKE_NETS = ("vgg19", "unet")
BUDGET_MULT = 1.25  # budget = 1.25 × exact min feasible: real recompute, room to choose
PRED_REL_TOL = 0.25  # replay must predict measured step time within 25 %
WC_SLOWDOWN_TOL = 1.15  # wallclock plan never measures > 15 % over time-centric
WC_BEAT_TOL = 1.01  # "ties or beats": wc ≤ tc within timing noise
WARMUP = 3
REPS = 30
# Twin shapes: large enough that per-op compute dominates dispatch/fusion
# noise on CPU (µs-scale toy steps are unmeasurable to 25 %).
BATCH = 32
WIDTH = 128


# --------------------------------------------------------------- measurement


def _materialize(args: Tuple[Any, ...]) -> Tuple[Any, ...]:
    """ShapeDtypeStructs → deterministic concrete arrays."""
    key = jax.random.PRNGKey(0)
    i = [0]

    def mk(s):
        i[0] += 1
        return jax.random.normal(
            jax.random.fold_in(key, i[0]), s.shape, s.dtype) * 0.3

    return jax.tree_util.tree_map(mk, args)


def _min_seconds(fn, args, reps: int = REPS, warmup: int = WARMUP) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _kind_rate_ratio(batch: int = BATCH, width: int = WIDTH) -> float:
    """Measured conv-node / elementwise-node cost ratio at twin shapes."""
    dn = (((1,), (0,)), ((), ()))
    h = jnp.ones((batch, width), jnp.float32)
    w = jnp.ones((width, width), jnp.float32)
    conv = _min_seconds(jax.jit(lambda a, b: jax.lax.dot_general(a, b, dn)),
                        (h, w))
    other = _min_seconds(jax.jit(jnp.tanh), (h,))
    return max(conv / max(other, 1e-12), 1e-3)


ELEMWISE_FUSED_WEIGHT = 0.35  # single-pred elementwise ops fuse ~free under XLA


def _node_weight(byte_g: Graph, v: int, ratio: float) -> float:
    """Relative cost of the twin's op at node ``v``.

    ``conv`` nodes run a ``dot_general`` (measured ratio vs elementwise);
    multi-predecessor nodes stack-and-mean their inputs (memory traffic
    ∝ #preds); remaining single-pred elementwise ops mostly fuse into
    their consumers, so they carry a deep discount.
    """
    if byte_g.nodes[v].kind == "conv":
        return ratio
    p = len(byte_g.pred[v])
    return float(p) if p > 1 else ELEMWISE_FUSED_WEIGHT


def _seconds_graph(byte_g: Graph, fwd_seconds: float, ratio: float) -> Graph:
    """Re-price T_v in measured seconds: per-kind weights, anchored so the
    graph's total forward time equals the measured vanilla forward."""
    weights = [_node_weight(byte_g, v, ratio) for v in range(byte_g.n)]
    scale = fwd_seconds / max(sum(weights), 1e-12)
    nodes = [
        Node(nd.idx, nd.name, max(w * scale, 1e-12), nd.memory, nd.kind,
             must_store=nd.must_store)
        for nd, w in zip(byte_g.nodes, weights)
    ]
    return Graph(nodes, byte_g.edges, cost_source="replay_accuracy:measured")


def _planned_step(fwd, byte_g: Graph, plan):
    names = sorted(byte_g.nodes[v].name for v in plan.cached)
    policy = jax.checkpoint_policies.save_only_these_names(*names)
    return jax.jit(jax.value_and_grad(jax.checkpoint(fwd, policy=policy)))


# ------------------------------------------------------------------ per net


def bench_net(name: str) -> Dict[str, Any]:
    g_abs = NETWORKS[name]()
    fwd, spec_args, byte_g = executable_twin(g_abs, batch=BATCH, width=WIDTH)
    args = _materialize(spec_args)

    fwd_meas = _min_seconds(jax.jit(fwd), args)
    step_meas = _min_seconds(jax.jit(jax.value_and_grad(fwd)), args)
    backward_factor = max((step_meas - fwd_meas) / max(fwd_meas, 1e-12), 0.1)
    ratio = _kind_rate_ratio()
    g_sec = _seconds_graph(byte_g, fwd_meas, ratio)

    fam = pruned_lower_sets(byte_g)
    b_min = dp_mod.min_feasible_budget_exact(byte_g, fam)
    budget = b_min * BUDGET_MULT
    tc = dp_mod.solve(byte_g, budget, fam, "time_centric")
    # wallclock selection sees the *measured* rates (quantized for the DP
    # t-axis) — same node sets, same memory, hardware-true time ratios.
    # overlap=False: this benchmark measures on a single-stream CPU, which
    # cannot realize the overlap the model prices for accelerators — the
    # selection must be graded on the serial replay it can actually cash.
    wc = dp_mod.solve_wallclock(
        dp_mod.quantize_times(g_sec), budget, fam,
        backward_factor=backward_factor, overlap=False)
    assert tc.feasible and wc.feasible, name

    row: Dict[str, Any] = {
        "nodes": byte_g.n,
        "budget_bytes": budget,
        "fwd_measured_s": fwd_meas,
        "vanilla_step_s": step_meas,
        "backward_factor": backward_factor,
        "conv_rate_ratio": ratio,
        "plans_differ": tc.sequence != wc.sequence,
    }
    for tag, res in (("tc", tc), ("wc", wc)):
        plan = make_plan(byte_g, res.sequence)
        serial = replay(g_sec, plan, overlap=False,
                        backward_factor=backward_factor)
        overlapped = replay(g_sec, plan, budget=budget,
                            backward_factor=backward_factor)
        if tag == "wc" and not row["plans_differ"]:
            meas = row["tc"]["measured_s"]  # identical plan: same compiled step
        else:
            meas = _min_seconds(_planned_step(fwd, byte_g, plan), args)
        row[tag] = {
            "segments": len(plan.segments),
            "overhead": res.overhead,
            "replay_serial_s": serial.seconds,
            "replay_overlap_s": overlapped.seconds,
            "hidden_s": overlapped.hidden_seconds,
            "measured_s": meas,
            "pred_rel_err": abs(serial.seconds - meas) / meas,
        }
    row["wc_over_tc_measured"] = row["wc"]["measured_s"] / row["tc"]["measured_s"]
    return row


def check_rows(rows: Dict[str, Dict[str, Any]]) -> List[str]:
    failures = []
    for name, r in rows.items():
        for tag in ("tc", "wc"):
            err = r[tag]["pred_rel_err"]
            if err > PRED_REL_TOL:
                failures.append(
                    f"{name}/{tag}: replay off by {err:.0%} "
                    f"(> {PRED_REL_TOL:.0%}): replayed "
                    f"{r[tag]['replay_serial_s']:.2e}s vs measured "
                    f"{r[tag]['measured_s']:.2e}s")
            if r[tag]["replay_overlap_s"] > r[tag]["replay_serial_s"] + 1e-15:
                failures.append(f"{name}/{tag}: overlap replay > serial replay")
        if r["wc_over_tc_measured"] > WC_SLOWDOWN_TOL:
            failures.append(
                f"{name}: wallclock plan measured "
                f"{r['wc_over_tc_measured']:.2f}× the time-centric plan "
                f"(> {WC_SLOWDOWN_TOL}×)")
    if not any(r["wc_over_tc_measured"] <= WC_BEAT_TOL for r in rows.values()):
        failures.append(
            "no net where the wallclock plan ties or beats the "
            "overhead-optimal plan's measured step")
    return failures


# --------------------------------------------------------------------- main


def main(smoke: bool = False,
         out_json: str = "BENCH_replay.json") -> Dict[str, Any]:
    nets = SMOKE_NETS if smoke else tuple(NETWORKS)
    print(f"== replay accuracy vs measured twin steps ({', '.join(nets)}) ==")
    hdr = (f"{'network':12s} {'plan':>4s} {'replay_ser':>11s} "
           f"{'replay_ovl':>11s} {'measured':>11s} {'rel_err':>8s}")
    print(hdr)
    rows: Dict[str, Dict[str, Any]] = {}
    for name in nets:
        rows[name] = bench_net(name)
        for tag in ("tc", "wc"):
            r = rows[name][tag]
            print(f"{name:12s} {tag:>4s} {r['replay_serial_s']:11.2e} "
                  f"{r['replay_overlap_s']:11.2e} {r['measured_s']:11.2e} "
                  f"{r['pred_rel_err']:8.1%}")
        print(f"{'':12s} wc/tc measured: "
              f"{rows[name]['wc_over_tc_measured']:.3f}× "
              f"(plans differ: {rows[name]['plans_differ']})")
    failures = check_rows(rows)
    out = {
        "nets": rows,
        "thresholds": {
            "pred_rel_tol": PRED_REL_TOL,
            "wc_slowdown_tol": WC_SLOWDOWN_TOL,
            "wc_beat_tol": WC_BEAT_TOL,
            "budget_mult": BUDGET_MULT,
        },
        "failures": failures,
    }
    if out_json:
        import json

        with open(out_json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"\nwrote {out_json}")
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(f"  - {f}")
        if smoke:
            sys.exit(1)
    else:
        print("\nall replay-accuracy guards passed")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed net set; exit 1 on guard violations")
    ap.add_argument("--out-json", default="BENCH_replay.json")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out_json)
