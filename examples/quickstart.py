"""Quickstart: plan ANY JAX function's recomputation in three lines.

    planned = repro.plan_function(loss_fn, budget_bytes)
    loss, grads = planned(params, x)       # value_and_grad twin

Behind the front door: the function is traced to the paper's graph
G = (V, E) (one node per jaxpr equation), the General Recomputation
Problem is solved under the byte budget by the DP (through the
content-addressed plan cache), and the plan is lowered to a
``jax.checkpoint`` policy that saves exactly the cache set U_k.

Run: PYTHONPATH=src python examples/quickstart.py
(The assertions double as the CI smoke for the front door.)
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

import repro
from repro.core import PlanCache, Planner, vanilla_peak
from repro.core.jaxpr_graph import trace

# ---------------------------------------------------------------------------
# 1. A plain JAX function — no BlockGraph, no framework cooperation.
#    (lax primitives keep eager replay bit-exact; jnp wrappers like
#    jnp.tanh run as separate jit units eagerly and may drift by 1 ulp.)
# ---------------------------------------------------------------------------

DN = (((1,), (0,)), ((), ()))  # plain 2-D matmul dimension_numbers


def mlp_loss(params, x):
    h = x
    for w in params:
        h = lax.tanh(lax.dot_general(h, w, DN))
    return jnp.sum(h * h)


key = jax.random.PRNGKey(0)
params = [
    jax.random.normal(jax.random.fold_in(key, i), (32, 32)) * 0.3
    for i in range(10)
]
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))

# ---------------------------------------------------------------------------
# 2. Halve the activation budget and plan through the front door.
# ---------------------------------------------------------------------------

g = trace(mlp_loss, params, x).graph
budget = vanilla_peak(g, liveness=False) / 2
planner = Planner(cache=PlanCache())

planned = repro.plan_function(mlp_loss, budget, planner=planner)
loss, grads = planned(params, x)

lowered = planned.lowered_for(params, x)
print(f"graph: {g.n} equations; budget {budget:.0f} B "
      f"(vanilla needs {vanilla_peak(g, liveness=False):.0f} B)")
print(f"plan: {len(lowered.plan.segments)} segments, "
      f"analytic peak {lowered.plan.peak_memory:.0f} B, "
      f"overhead {lowered.plan.overhead:.0f} T-units, "
      f"backend {lowered.backend!r}")
assert lowered.plan.peak_memory <= budget

# ---------------------------------------------------------------------------
# 3. The canonical strategy never alters the computation (§3): loss and
#    gradients are bit-identical to vanilla jax.value_and_grad.
# ---------------------------------------------------------------------------

ref_loss, ref_grads = jax.value_and_grad(mlp_loss)(params, x)
assert np.array_equal(np.asarray(loss), np.asarray(ref_loss))
for a, b in zip(grads, ref_grads):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print(f"loss {float(loss):.6f} == vanilla, gradients bit-identical")

# The paper-faithful interpreter backend audits the memory claim live:
audited = repro.plan_function(mlp_loss, budget, backend="interpreter",
                              planner=planner, track_live=True)
_, _, live = audited(params, x)
peak_live = max(b for _, b in live)
print(f"measured live intermediates {peak_live} B <= "
      f"plan peak {lowered.plan.peak_memory:.0f} B")
assert peak_live <= lowered.plan.peak_memory

# ---------------------------------------------------------------------------
# 4. Re-planning is a cache hit: a fresh planned function re-solves nothing.
# ---------------------------------------------------------------------------

before = planner.cache.stats()
again = repro.plan_function(mlp_loss, budget, planner=planner)
_ = again(params, x)
after = planner.cache.stats()
assert after["hits"] > before["hits"], (before, after)
assert again.lowered_for(params, x).plan == lowered.plan
print(f"second plan_function call: plan-cache hit "
      f"({after['hits']} hits, {after['misses']} misses)")
print("OK — one pipeline: trace -> plan (cached) -> lowering.")
