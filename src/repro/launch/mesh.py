"""Production mesh definitions (TPU v5e target).

Single pod: 16 × 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 × 16 × 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for hierarchical gradient reduction
(reduce-scatter intra-pod over ICI, all-reduce across pods over DCI).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS *before* the first jax call).
"""

from __future__ import annotations

import jax

# v5e hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 1024**3  # 16 GiB per chip


def _auto(n):
    from repro.parallel.compat import AxisType

    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    from repro.parallel.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1):
    """Whatever this host has (tests / examples): (n_dev/model, model)."""
    from repro.parallel.compat import make_mesh

    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"), axis_types=_auto(2))


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
