"""Dynamic-programming solutions to the General Recomputation Problem.

Implements Algorithm 1 of the paper (Appendix A) with the practical
accelerations the paper describes in §4.2:

* sparse DP table — ``opt[L, ·]`` holds only the *Pareto frontier* of
  ``(t, m)`` pairs ("when t < t' and opt[L,t] < opt[L,t'], we can skip the
  iteration for the entry opt[L,t']");
* node sets as arbitrary-precision integer bitmasks, so ``L ⊆ L'`` is one
  big-int AND;
* per-``L'`` segment terms (∂(L'), δ⁺(L')\\L', δ⁻(δ⁺(L'))\\L') precomputed
  once.

Entry points:

* ``solve(graph, budget, family, objective="time_centric")`` — Algorithm 1;
  ``objective="memory_centric"`` replaces ``min`` with ``max`` at line 15
  (§4.4 / Appendix A note).
* ``exact_dp(graph, budget, ...)``  — family = 𝓛_G        (§4.2)
* ``approx_dp(graph, budget, ...)`` — family = 𝓛_G^Pruned (§4.3)
* ``sweep(graph, family, objective)`` — the **budget-free sweep solver**:
  one DP pass with the running peak of the memory functional's 𝓜⁽ⁱ⁾
  carried as a third frontier coordinate ``(t, m, peak)`` instead of the
  per-budget filter ``𝓜⁽ⁱ⁾ > B``.  The resulting :class:`Sweep` answers *every* budget:
  ``Sweep.extract(B)`` reproduces ``solve(graph, B, family, objective)``
  bit-identically (same lower-set sequence, same overhead), and the minimal
  peak at the terminal state is the *exact* minimal feasible budget — no
  binary search (§5.1) required.  ``Sweep.frontier()`` is the full
  (budget → overhead) Pareto staircase, e.g. a whole trade-off grid from
  one pass.  Sweeps serialize (``Sweep.encode``/``decode_sweep``) in
  canonical coordinates so ``core.plan_cache`` can admit every future
  budget query on a graph from one cold solve.

Bit-identity of ``Sweep.extract`` with the per-budget DP rests on the
per-cell tie-break both use: among equally cheap transitions into a table
cell ``(L', t')`` the winner is the one whose source lower set comes first
in the size-ascending family order (the per-budget DP realises this as
first-writer-wins; the sweep stores the source position explicitly and
minimizes ``(m, pos)``).

The DP requires integer ``T_v`` (the ``t`` axis of the table).  The paper
uses ``T_v ∈ {1, 10}``; for FLOP-derived costs use
``quantize_times(graph, levels)`` first.

**Memory functional.**  The paper's eq. 2 charges every transition its full
segment footprint ``m + 2·M(V') + M(δ⁺(L')\\L') + M(δ⁻(δ⁺(L'))\\L')``; the
interpreter's measured live-byte traces consistently undershoot it because
buffers die at their last use *inside* a segment.  The DP here therefore
prices transitions with the **liveness-tight** functional
``𝓜⁽ⁱ⁾ = m + liveness.transition_excess(L, L')`` — the exact per-transition
decomposition of ``liveness.simulate(..., liveness=True)`` — so
``peak_memory`` of a result is exactly the last-use-liveness execution
peak of its schedule, and budgets are honest in both directions: on
segment-structured graphs (chains, the benchmark CNNs) the tighter charge
admits more strategies per budget, while on gradient-dense graphs it can
sit *above* eq. 2, which under-counts gradient buffers held for earlier
segments (see ``transition_excess``).  Eq. 2 stays
available for the Appendix C ablation: the strategy evaluator
:func:`peak_memory` and the ``functional="eq2"`` knob on :func:`solve` /
:func:`feasible` / :func:`min_feasible_budget_exact` (benchmarks only — the
sweep and the plan cache speak the liveness functional, versioned by
:data:`MEMORY_FUNCTIONAL`).
"""

from __future__ import annotations

import dataclasses
import weakref
from bisect import bisect_left, bisect_right
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
from numpy.typing import NDArray

from .graph import EMPTY, Graph, NodeSet, from_mask, mask_iter, to_mask
from .liveness import (
    _masks_bools,
    record_excess,
    scalar_only,
    transition_excess,
    transition_excess_many,
    transition_excess_row,
)
from .lower_sets import all_lower_sets, pruned_lower_sets
from .strategies import (
    StrategyConfig,
    assignment_of,
    device_bytes,
    transition_options,
)

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids an import cycle)
    from .cost_model import OpProfile

# Version tag of the DP's memory functional, content-addressed into every
# plan-cache key (core.plan_cache) so plans solved under an older functional
# (e.g. the pre-liveness eq. 2) invalidate by construction.
MEMORY_FUNCTIONAL = "live-v1"

_FUNCTIONALS = ("liveness", "eq2")


def _check_functional(functional: str, g: Optional[Graph] = None) -> None:
    if functional not in _FUNCTIONALS:
        raise ValueError(f"unknown memory functional {functional!r}")
    if functional == "eq2" and g is not None and g.store_pins_mask:
        raise ValueError(
            "functional='eq2' cannot price must_store pins (the paper's "
            "eq. 2 predates effect analysis); use the liveness functional"
        )


# Bitmask helpers live in core.graph (shared with core.liveness);
# re-exported here for the existing callers.


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DPResult:
    """Solution of the general recomputation problem.

    Attributes:
      sequence: the increasing lower-set sequence {L₁ ≺ … ≺ L_k = V}.
      overhead: T(V \\ U_k) — total recomputation overhead (eq. 1).
      peak_memory: max_i 𝓜⁽ⁱ⁾ under the planner's liveness-tight
        functional (:func:`peak_memory_live` — equals the last-use-liveness
        execution peak of the schedule; ``functional="eq2"`` solves report
        the paper's eq. 2 instead, see :func:`peak_memory`).
      feasible: False if no sequence satisfies the budget ("Impossible").
      states_visited: DP work counter (for the §5.1 runtime comparison).
      assignment: per-cached-node storage strategy (node id → "store" /
        "offload" / "quantize") when the solve ran over an extended
        strategy lattice (``strategies=``); None for the paper's binary.
    """

    sequence: List[NodeSet]
    overhead: float
    peak_memory: float
    feasible: bool
    states_visited: int = 0
    assignment: Optional[Dict[int, str]] = None

    @property
    def num_segments(self) -> int:
        return len(self.sequence)


INF = float("inf")


# ---------------------------------------------------------------------------
# Segment-term precomputation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _LowerSetInfo:
    mask: int
    size: int
    T: float  # T(L)
    M: float  # M(L)
    boundary_mask: int  # ∂(L)
    cache_mask: int  # ∂(L) ∪ (pins ∩ L) — the effective cached set
    T_boundary: float  # T(∂(L))
    m_after: float  # M(δ⁺(L) \ L) + M(δ⁻(δ⁺(L)) \ L)   (terms iii+iv of eq. 2)


def _prepare(g: Graph, family: Sequence[NodeSet]) -> List[_LowerSetInfo]:
    infos = []
    pins = g.store_pins_mask
    for L in family:
        mask = to_mask(L)
        dplus = g.delta_plus(L)
        dplus_out = to_mask(dplus) & ~mask  # δ⁺(L) \ L
        dmd_out = to_mask(g.delta_minus(dplus)) & ~mask  # δ⁻(δ⁺(L)) \ L
        boundary = g.boundary(L)
        boundary_mask = to_mask(boundary)
        infos.append(
            _LowerSetInfo(
                mask=mask,
                size=len(L),
                T=g.T(L),
                M=g.M(L),
                boundary_mask=boundary_mask,
                cache_mask=boundary_mask | (pins & mask),
                T_boundary=g.T(boundary),
                m_after=sum(g.mem_v[v] for v in mask_iter(dplus_out))
                + sum(g.mem_v[v] for v in mask_iter(dmd_out)),
            )
        )
    return infos


def _mask_M(g: Graph, mask: int) -> float:
    return sum(g.mem_v[v] for v in mask_iter(mask))


def _mask_M_w(weights: Sequence[float], mask: int) -> float:
    """Ascending-id left fold of arbitrary per-node byte weights.

    The strategy lattice's analogue of :func:`_mask_M` — same fold shape,
    so an all-store weight vector reproduces ``_mask_M`` bit-for-bit.
    """
    return sum(weights[v] for v in mask_iter(mask))


def _mask_T(g: Graph, mask: int) -> float:
    return sum(g.time_v[v] for v in mask_iter(mask))


# ---------------------------------------------------------------------------
# Vectorized hot path (shared by solve / feasible / mfb / sweep)
# ---------------------------------------------------------------------------
#
# The DP's per-(L, L') work — the subset test, the cache-mass and overhead
# steps (_mask_M/_mask_T), the liveness excess, and the frontier merges —
# is batched with numpy one *source row* at a time: for each L in size
# order, all its targets L' ⊇ L are handled in one shot.  The scalar loops
# above stay byte-for-byte as oracles behind REPRO_DP_SCALAR=1.
#
# Bit-identity rests on three facts, each load-bearing:
#   * the segment sums fold node masses in ascending node id exactly like
#     ``sum(mem_v[v] for v in mask_iter(mask))`` — ``np.bincount`` with
#     weights accumulates sequentially in input order, and ``np.nonzero``
#     on a (J, n) mask emits row-major (= per-row ascending id) pairs, so
#     one bincount per source row is the scalar left fold, batched;
#   * every per-candidate expression (``m + m_step``, ``m + m_fixed``,
#     ``max(peak, Mi)``, the ``Mi > budget`` filter) is evaluated as the
#     same single IEEE operation, just elementwise;
#   * the scalar frontier inserts maintain exactly the Pareto-minimal set
#     of everything ever inserted — an order-independent *set* — so
#     gathering a cell's incoming candidates and canonically filtering
#     them once (sort + strict prefix-min scan) when the cell's lower set
#     becomes a source reproduces the scalar frontier arrays exactly.


@dataclasses.dataclass
class _VecPrep:
    """Per-(graph, family) batched transition terms, source-row major.

    ``targets[pos]`` are the family ids reachable from ``order[pos]``
    (strictly larger sets L' ⊇ L, in size order — the same jpos order the
    scalar loops walk).  ``m_step``/``t_step`` are the per-pair cache-mass
    and overhead steps; ``m_fixed`` rows are priced lazily (first DP that
    walks the row batches the liveness kernel) and shared by every entry
    point via this cache, so a solve after a min-budget pass re-prices
    nothing.
    """

    infos: List[_LowerSetInfo]
    order: List[int]
    sizes: List[int]
    empty_id: int
    full_id: int
    targets: List[NDArray[np.int64]]
    m_step: List[NDArray[np.float64]]
    t_step: List[NDArray[np.float64]]
    m_fixed: List[Optional[NDArray[np.float64]]]
    fam_b: NDArray[np.bool_]  # (F, n) family membership rows, by node id
    bound_b: NDArray[np.bool_]  # (F, n) boundary ∂(L) rows, by node id


_VEC_PREP: "weakref.WeakKeyDictionary[Graph, Dict[Tuple[int, ...], _VecPrep]]" = (
    weakref.WeakKeyDictionary()
)


def _vec_prep(
    g: Graph,
    family: Sequence[NodeSet],
    mem_w: Optional[Sequence[float]] = None,
    tag: str = "",
) -> _VecPrep:
    """``mem_w``/``tag`` override the cache-mass weights (strategy lattice:
    the ``mem_eff`` minimal-device-bytes vector for feasibility/mfb); the
    tag keys the cache so differently weighted preps never alias."""
    key: Tuple[Any, ...] = (tag,) + tuple(to_mask(L) for L in family)
    per_g = _VEC_PREP.setdefault(g, {})
    cached = per_g.get(key)
    if cached is not None:
        return cached
    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    empty_id = full_id = -1
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    # ∅/V may legitimately be absent for feasible(); solve/sweep/mfb raise
    # via _require_terminals, matching their scalar paths.

    n = g.n
    fam_b = _masks_bools([info.mask for info in infos], n)
    bound_b = _masks_bools([info.boundary_mask for info in infos], n)
    cache_b = _masks_bools([info.cache_mask for info in infos], n)
    # byte-packed family rows: the superset filter compares n/8 bytes
    # instead of n bools per candidate
    fam_p = np.packbits(fam_b, axis=1, bitorder="little")
    mem = np.asarray(g.mem_v if mem_w is None else mem_w, dtype=np.float64)
    tim = np.asarray(g.time_v, dtype=np.float64)
    t_of = np.array([info.T for info in infos], dtype=np.float64)
    order_arr = np.asarray(order, dtype=np.int64)

    targets: List[NDArray[np.int64]] = []
    m_steps: List[NDArray[np.float64]] = []
    t_steps: List[NDArray[np.float64]] = []
    empty_f = np.zeros(0, dtype=np.float64)
    empty_i = np.zeros(0, dtype=np.int64)
    for pos, i in enumerate(order):
        start = bisect_right(sizes, infos[i].size)
        cand = order_arr[start:]
        lb = fam_b[i]
        if len(cand) == 0:
            targets.append(empty_i)
            m_steps.append(empty_f)
            t_steps.append(empty_f)
            continue
        tg = cand[~((~fam_p[cand] & fam_p[i]).any(axis=1))]
        j_cnt = len(tg)
        if j_cnt == 0:
            targets.append(empty_i)
            m_steps.append(empty_f)
            t_steps.append(empty_f)
            continue
        # m_step = Σ mem over cache(L') \ L, left-folded in ascending id:
        # np.nonzero is row-major, bincount accumulates in input order.
        sel_m = cache_b[tg] & ~lb
        rr, cc = np.nonzero(sel_m)
        m_step = np.bincount(rr, weights=mem[cc], minlength=j_cnt)
        # t_step = (T(L') − T(L)) − Σ time over (L' \ L) ∩ cache(L').
        # (L'\L) ∩ cache(L') ⊆ cache(L') \ L, so compress the sel_m pairs
        # by L'-membership instead of scanning a second (J, n) matrix —
        # the surviving (rr, cc) keep their ascending-cc-per-row order.
        ft = fam_b[tg[rr], cc]
        t_sum = np.bincount(
            rr[ft], weights=tim[cc[ft]], minlength=j_cnt
        )
        t_step = (t_of[tg] - infos[i].T) - t_sum
        targets.append(tg)
        m_steps.append(m_step)
        t_steps.append(t_step)

    vp = _VecPrep(
        infos=infos,
        order=order,
        sizes=sizes,
        empty_id=empty_id,
        full_id=full_id,
        targets=targets,
        m_step=m_steps,
        t_step=t_steps,
        m_fixed=[None] * len(order),
        fam_b=fam_b,
        bound_b=bound_b,
    )
    per_g[key] = vp
    return vp


def _require_terminals(vp: _VecPrep) -> None:
    if vp.empty_id < 0 or vp.full_id < 0:
        raise ValueError("family must contain ∅ and V")


def _price_row(g: Graph, vp: _VecPrep, pos: int) -> NDArray[np.float64]:
    """Liveness excess for every target of source row ``pos`` (one batch).

    Memo-free: the row is cached here (shared by every entry point via
    ``_VEC_PREP``), and the traceback seeds the per-pair liveness memo for
    just the transitions the answer takes (:func:`_seed_chain_excess`).
    """
    mf = vp.m_fixed[pos]
    if mf is None:
        i = vp.order[pos]
        tg = vp.targets[pos]
        mf = transition_excess_row(
            g,
            vp.infos[i].mask,
            tmul=vp.fam_b[tg],
            bdful=vp.bound_b[tg],
        )
        vp.m_fixed[pos] = mf
    return mf


def _seed_chain_excess(g: Graph, vp: _VecPrep, chain: List[int]) -> None:
    """Seed the liveness memo along a traceback chain (full → ∅ order).

    The row pricer skips the per-pair memo (130k keys on a ResNet-152
    family, 99% never read back); the handful of transitions the chosen
    sequence takes are recorded here so ``peak_memory_live`` prices the
    returned plan with the *same floats* the DP's budget filter used.
    """
    pos_of = {i: p for p, i in enumerate(vp.order)}
    for child, parent in zip(chain[:-1], chain[1:]):
        pos = pos_of[parent]
        mf = vp.m_fixed[pos]
        if mf is None:  # pragma: no cover - chain rows are always priced
            continue
        idx = int(np.nonzero(vp.targets[pos] == child)[0][0])
        record_excess(
            g,
            vp.infos[parent].mask,
            vp.infos[child].mask,
            float(mf[idx]),
        )


def _pareto_keep(
    ms: NDArray[np.float64], ps: NDArray[np.float64]
) -> NDArray[np.bool_]:
    """Canonical (m, p) Pareto filter: sort callers pass (m asc, p asc)-
    sorted arrays; a point survives iff its p is strictly below every
    earlier point's — the same set the scalar bisect-insert loops keep."""
    keep = np.empty(len(ms), dtype=bool)
    keep[0] = True
    pm = np.minimum.accumulate(ps)
    keep[1:] = ps[1:] < pm[:-1]
    return keep


# ---------------------------------------------------------------------------
# Strategy-lattice solve (per-node {store, offload, quantize} choice)
# ---------------------------------------------------------------------------
#
# The joint memory-strategy DP keeps the legacy state (L, t) → minimal m
# and expands each transition once *per strategy option* of its newly
# cached set (core.strategies.transition_options — the Pareto frontier of
# the per-node Minkowski sum).  The strategy affects only the carried
# cache mass (m2 = m + option.m_add) and, for the time-centric and
# wallclock objectives, the t axis (t2 = t + (t_step + option.tax)); the
# transition's 𝓜⁽ⁱ⁾ = m + transition_excess stays strategy-independent
# because a node occupies full bytes during its own forward window and is
# compressed/offloaded only when the segment retires (see
# core.strategies).  Exactness over (sequence × assignment) follows from
# the legacy argument plus: each node is charged once (m_step counts
# cache(L')\L), smaller m weakly dominates, and the per-option folds are
# additive so intermediate Pareto pruning of options is lossless.
#
# Ordering contract (scalar ↔ vectorized bit-identity): the scalar loop
# iterates, per source, targets in jpos order with *options outer and
# entries inner*; the vectorized path flattens candidate rows
# target-major, option-minor, so the arrival sequence numbers
# (target, option, entry) reproduce the scalar first-writer-wins
# tie-break exactly.


def _strat_traceback(
    infos: List[_LowerSetInfo],
    chain: List[Tuple[int, float, Optional[Tuple[int, Tuple[str, ...]]]]],
) -> Tuple[List[NodeSet], Dict[int, str]]:
    """Masks (∅ dropped) + merged per-node assignment of a traceback chain.

    ``chain`` is in full → ∅ order; each element carries the lower-set id,
    its table t, and the arriving transition's (new_mask, codes) — None
    for the ∅ seed.
    """
    assignment: Dict[int, str] = {}
    masks: List[int] = []
    for cid, _t, opt in chain:
        if infos[cid].mask:
            masks.append(infos[cid].mask)
        if opt is not None:
            assignment.update(assignment_of(opt[0], opt[1]))
    masks.reverse()
    return [from_mask(mk) for mk in masks], assignment


def _solve_strat_scalar(
    g: Graph, budget: float, family: Sequence[NodeSet], objective: str,
    cfg: StrategyConfig,
) -> DPResult:
    """Scalar oracle of the joint memory-strategy DP (liveness functional)."""
    tc = objective == "time_centric"
    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    empty_id = full_id = -1
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id < 0 or full_id < 0:
        raise ValueError("family must contain ∅ and V")

    # t → (m, parent=(id, t) | None, (new_mask, codes) | None)
    table: List[Dict[float, Tuple[float, Any, Any]]] = [{} for _ in infos]
    table[empty_id][0.0] = (0.0, None, None)
    states = 0
    n_fam = len(order)
    for pos, i in enumerate(order):
        info_L = infos[i]
        entries = table[i]
        if not entries:
            continue
        pruned = _prune_generic(entries, reverse=not tc)
        table[i] = pruned
        pruned_items = list(pruned.items())
        mask_L = info_L.mask
        start = bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            Vp_mask = info_Lp.mask & ~mask_L
            inter = Vp_mask & info_Lp.cache_mask
            t_step = (info_Lp.T - info_L.T) - _mask_T(g, inter)
            new_mask = info_Lp.cache_mask & ~mask_L
            m_fixed = transition_excess(
                g, mask_L, info_Lp.mask, info_Lp.boundary_mask
            )
            row = table[j]
            for opt in transition_options(g, cfg, new_mask, tc):
                t_step_o = t_step + opt.tax if tc else t_step
                for t, (m, _p, _o) in pruned_items:
                    states += 1
                    Mi = m + m_fixed  # 𝓜⁽ⁱ⁾, strategy-independent
                    if Mi > budget:
                        continue
                    t2 = t + t_step_o
                    m2 = m + opt.m_add
                    cur = row.get(t2)
                    if cur is None or cur[0] > m2:
                        row[t2] = (m2, (i, t), (new_mask, opt.codes))

    final = table[full_id]
    if not final:
        return DPResult([], INF, INF, feasible=False, states_visited=states)
    t_star = min(final) if tc else max(final)
    chain: List[Tuple[int, float, Any]] = []
    cur_id, cur_t = full_id, t_star
    while cur_id >= 0:
        m, parent, opt = table[cur_id][cur_t]
        chain.append((cur_id, cur_t, opt))
        if parent is None:
            break
        cur_id, cur_t = parent
    sequence, assignment = _strat_traceback(infos, chain)
    return DPResult(
        sequence=sequence,
        overhead=t_star,
        peak_memory=peak_memory_live(g, sequence, assignment),
        feasible=True,
        states_visited=states,
        assignment=assignment,
    )


def _prune_generic(
    entries: Dict[float, Tuple[float, Any, Any]], reverse: bool
) -> Dict[float, Tuple[float, Any, Any]]:
    """:func:`_pareto` / :func:`_pareto_mc` over value tuples of any width
    (index 0 is m)."""
    out: Dict[float, Tuple[float, Any, Any]] = {}
    best = INF
    for t in sorted(entries, reverse=reverse):
        val = entries[t]
        if val[0] < best:
            out[t] = val
            best = val[0]
    return out


def _solve_strat_vec(
    g: Graph, budget: float, family: Sequence[NodeSet], objective: str,
    cfg: StrategyConfig,
) -> DPResult:
    """Vectorized joint memory-strategy DP.

    The legacy :func:`_solve_vec` with each source row's (target × option)
    pairs flattened target-major / option-minor — the arrival-sequence
    lexsort key then reproduces the scalar loop's first-writer-wins
    tie-break (options outer, entries inner) exactly.
    """
    tc = objective == "time_centric"
    vp = _vec_prep(g, family)
    _require_terminals(vp)
    n_infos = len(vp.infos)
    # pending chunks: (t2, m2, parent_id, parent_t, arrival seq, opt ref)
    pend: List[List[Tuple[NDArray[np.float64], NDArray[np.float64],
                          NDArray[np.int64], NDArray[np.float64],
                          NDArray[np.int64], NDArray[np.int64]]]] = [
        [] for _ in range(n_infos)
    ]
    zero = np.zeros(1, dtype=np.float64)
    neg1 = np.full(1, -1, dtype=np.int64)
    pend[vp.empty_id].append((zero, zero, neg1, zero, neg1, neg1))
    rows: List[Optional[Tuple[NDArray[np.float64], NDArray[np.float64],
                              NDArray[np.int64], NDArray[np.float64],
                              NDArray[np.int64]]]] = [None] * n_infos
    opt_tab: List[Tuple[int, Tuple[str, ...]]] = []  # ref → (new_mask, codes)
    states = 0
    seq_base = 0
    for pos, i in enumerate(vp.order):
        chunks = pend[i]
        pend[i] = []
        if not chunks:
            continue
        t2 = np.concatenate([c[0] for c in chunks])
        m2 = np.concatenate([c[1] for c in chunks])
        pid = np.concatenate([c[2] for c in chunks])
        pt = np.concatenate([c[3] for c in chunks])
        seq = np.concatenate([c[4] for c in chunks])
        oc = np.concatenate([c[5] for c in chunks])
        o = np.lexsort((seq, m2, t2))
        t2, m2, pid, pt, oc = t2[o], m2[o], pid[o], pt[o], oc[o]
        first = np.empty(len(t2), dtype=bool)
        first[0] = True
        first[1:] = t2[1:] != t2[:-1]
        t2, m2, pid, pt, oc = (
            t2[first], m2[first], pid[first], pt[first], oc[first]
        )
        if not tc:
            t2, m2, pid, pt, oc = (
                t2[::-1], m2[::-1], pid[::-1], pt[::-1], oc[::-1]
            )
        keepb = np.empty(len(m2), dtype=bool)
        keepb[0] = True
        pm = np.minimum.accumulate(m2)
        keepb[1:] = m2[1:] < pm[:-1]
        t_e, m_e, pid_e, pt_e, oc_e = (
            t2[keepb], m2[keepb], pid[keepb], pt[keepb], oc[keepb]
        )
        rows[i] = (t_e, m_e, pid_e, pt_e, oc_e)
        tg = vp.targets[pos]
        j_cnt, e_cnt = len(tg), len(t_e)
        if j_cnt == 0 or e_cnt == 0:
            continue
        mf = _price_row(g, vp, pos)
        t_stepv = vp.t_step[pos]
        mask_L = vp.infos[i].mask
        # flatten (target, option) rows: target-major, option-minor
        flat_j: List[int] = []
        flat_m: List[float] = []
        flat_t: List[float] = []
        flat_oc: List[int] = []
        for jj in range(j_cnt):
            j = int(tg[jj])
            new_mask = vp.infos[j].cache_mask & ~mask_L
            for opt in transition_options(g, cfg, new_mask, tc):
                flat_j.append(jj)
                flat_m.append(opt.m_add)
                flat_t.append(
                    float(t_stepv[jj]) + opt.tax if tc else float(t_stepv[jj])
                )
                flat_oc.append(len(opt_tab))
                opt_tab.append((new_mask, opt.codes))
        r_cnt = len(flat_j)
        states += r_cnt * e_cnt
        fj = np.asarray(flat_j, dtype=np.int64)
        fm = np.asarray(flat_m, dtype=np.float64)
        ft = np.asarray(flat_t, dtype=np.float64)
        foc = np.asarray(flat_oc, dtype=np.int64)
        t2m = t_e[None, :] + ft[:, None]
        m2m = m_e[None, :] + fm[:, None]
        ok = (m_e[None, :] + mf[fj][:, None]) <= budget
        seqm = seq_base + np.arange(r_cnt, dtype=np.int64)[:, None] * e_cnt + \
            np.arange(e_cnt, dtype=np.int64)
        seq_base += r_cnt * e_cnt
        pid_i = np.full(e_cnt, i, dtype=np.int64)
        cnt = ok.sum(axis=1)
        for rr, c in zip(range(r_cnt), cnt.tolist()):
            if c == 0:
                continue
            ocr = np.full(e_cnt, foc[rr], dtype=np.int64)
            if c == e_cnt:
                pend[int(tg[fj[rr]])].append(
                    (t2m[rr], m2m[rr], pid_i, t_e, seqm[rr], ocr)
                )
            else:
                okr = ok[rr]
                pend[int(tg[fj[rr]])].append(
                    (t2m[rr][okr], m2m[rr][okr], pid_i[okr], t_e[okr],
                     seqm[rr][okr], ocr[okr])
                )
    final = rows[vp.full_id]
    if final is None or len(final[0]) == 0:
        return DPResult([], INF, INF, feasible=False, states_visited=states)
    t_star = float(final[0][0])
    chain: List[Tuple[int, float, Any]] = []
    id_chain: List[int] = []
    cur_id, cur_t = vp.full_id, t_star
    while cur_id >= 0:
        row = rows[cur_id]
        assert row is not None
        k = int(np.nonzero(row[0] == cur_t)[0][0])
        ref = int(row[4][k])
        chain.append((cur_id, cur_t, opt_tab[ref] if ref >= 0 else None))
        id_chain.append(cur_id)
        cur_id, cur_t = int(row[2][k]), float(row[3][k])
    _seed_chain_excess(g, vp, id_chain)
    sequence, assignment = _strat_traceback(vp.infos, chain)
    return DPResult(
        sequence=sequence,
        overhead=t_star,
        peak_memory=peak_memory_live(g, sequence, assignment),
        feasible=True,
        states_visited=states,
        assignment=assignment,
    )


def _solve_strat(
    g: Graph, budget: float, family: Sequence[NodeSet], objective: str,
    cfg: StrategyConfig,
) -> DPResult:
    if scalar_only():
        return _solve_strat_scalar(g, budget, family, objective, cfg)
    return _solve_strat_vec(g, budget, family, objective, cfg)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def _solve_vec(
    g: Graph, budget: float, family: Sequence[NodeSet], objective: str
) -> DPResult:
    """Vectorized Algorithm 1 (liveness functional).

    Table rows are finalized by gathering each lower set's incoming
    candidates and picking, per distinct t, the minimal-m entry with the
    smallest arrival sequence number — the scalar ``row.get``-and-compare
    loop's first-writer-wins tie-break, reproduced as a lexsort key.
    """
    tc = objective == "time_centric"
    vp = _vec_prep(g, family)
    _require_terminals(vp)
    n_infos = len(vp.infos)
    # pending chunks per set: (t2, m2, parent_id, parent_t, arrival seq) —
    # rows here are wide (one entry per distinct t), so ndarray chunks beat
    # the flat-list accumulation _mfb_vec uses for its ~6-wide frontiers.
    pend: List[
        List[
            Tuple[
                NDArray[np.float64],
                NDArray[np.float64],
                NDArray[np.int64],
                NDArray[np.float64],
                NDArray[np.int64],
            ]
        ]
    ] = [[] for _ in range(n_infos)]
    zero = np.zeros(1, dtype=np.float64)
    neg1 = np.full(1, -1, dtype=np.int64)
    pend[vp.empty_id].append((zero, zero, neg1, zero, neg1))
    # finalized + Pareto-pruned rows, in expansion order (t asc for TC,
    # t desc for MC — the scalar dict-iteration order)
    rows: List[
        Optional[
            Tuple[
                NDArray[np.float64],
                NDArray[np.float64],
                NDArray[np.int64],
                NDArray[np.float64],
            ]
        ]
    ] = [None] * n_infos
    states = 0
    seq_base = 0
    for pos, i in enumerate(vp.order):
        chunks = pend[i]
        pend[i] = []
        if not chunks:
            continue
        t2 = np.concatenate([c[0] for c in chunks])
        m2 = np.concatenate([c[1] for c in chunks])
        pid = np.concatenate([c[2] for c in chunks])
        pt = np.concatenate([c[3] for c in chunks])
        seq = np.concatenate([c[4] for c in chunks])
        o = np.lexsort((seq, m2, t2))
        t2, m2, pid, pt = t2[o], m2[o], pid[o], pt[o]
        first = np.empty(len(t2), dtype=bool)
        first[0] = True
        first[1:] = t2[1:] != t2[:-1]
        t2, m2, pid, pt = t2[first], m2[first], pid[first], pt[first]
        if not tc:  # MC prunes (and expands) in descending-t order
            t2, m2, pid, pt = t2[::-1], m2[::-1], pid[::-1], pt[::-1]
        # _pareto/_pareto_mc: walk t (asc TC / desc MC), keep m strictly
        # below the running best
        keepb = np.empty(len(m2), dtype=bool)
        keepb[0] = True
        pm = np.minimum.accumulate(m2)
        keepb[1:] = m2[1:] < pm[:-1]
        t_e, m_e, pid_e, pt_e = t2[keepb], m2[keepb], pid[keepb], pt[keepb]
        rows[i] = (t_e, m_e, pid_e, pt_e)
        tg = vp.targets[pos]
        j_cnt, e_cnt = len(tg), len(t_e)
        if j_cnt == 0 or e_cnt == 0:
            continue
        states += j_cnt * e_cnt
        mf = _price_row(g, vp, pos)
        t2m = t_e[None, :] + vp.t_step[pos][:, None]
        m2m = m_e[None, :] + vp.m_step[pos][:, None]
        ok = (m_e[None, :] + mf[:, None]) <= budget  # scalar: skip Mi > B
        seqm = seq_base + np.arange(j_cnt, dtype=np.int64)[:, None] * e_cnt + np.arange(
            e_cnt, dtype=np.int64
        )
        seq_base += j_cnt * e_cnt
        pid_i = np.full(e_cnt, i, dtype=np.int64)
        cnt = ok.sum(axis=1)  # one reduction replaces 2 per-row dispatches
        for jj, c in zip(range(j_cnt), cnt.tolist()):
            if c == 0:
                continue
            if c == e_cnt:
                pend[tg[jj]].append((t2m[jj], m2m[jj], pid_i, t_e, seqm[jj]))
            else:
                okr = ok[jj]
                pend[tg[jj]].append(
                    (t2m[jj][okr], m2m[jj][okr], pid_i[okr], t_e[okr], seqm[jj][okr])
                )
    final = rows[vp.full_id]
    if final is None or len(final[0]) == 0:
        return DPResult([], INF, INF, feasible=False, states_visited=states)
    # rows are stored in expansion order: TC ascending t (min first), MC
    # descending t (max first) — the optimum is the first entry either way.
    t_star = float(final[0][0])
    chain: List[int] = []
    cur_id, cur_t = vp.full_id, t_star
    while cur_id >= 0:
        chain.append(cur_id)
        row = rows[cur_id]
        assert row is not None
        k = int(np.nonzero(row[0] == cur_t)[0][0])
        cur_id, cur_t = int(row[2][k]), float(row[3][k])
    _seed_chain_excess(g, vp, chain)
    masks = [
        vp.infos[cid].mask for cid in reversed(chain) if vp.infos[cid].mask != 0
    ]
    sequence = [from_mask(mk) for mk in masks]
    return DPResult(
        sequence=sequence,
        overhead=t_star,
        peak_memory=peak_memory_live(g, sequence),
        feasible=True,
        states_visited=states,
    )


def solve(
    g: Graph,
    budget: float,
    family: Sequence[NodeSet],
    objective: str = "time_centric",
    functional: str = "liveness",
    strategies: Optional[StrategyConfig] = None,
) -> DPResult:
    """Algorithm 1 (Appendix A) over an arbitrary lower-set family.

    objective:
      * "time_centric"   — minimize overhead (line 15: min)   §4.2/§4.3
      * "memory_centric" — maximize overhead (line 15: max)   §4.4
      * "wallclock"      — minimize *replayed step time* under the budget:
        the time-centric Pareto surface is swept, every feasible terminal
        overhead is lowered to a plan and priced by the discrete-event
        replay (``core.replay``), and the wall-clock winner is returned.
        Requires the liveness functional (the replay's overlap windows are
        its backward-window decomposition).

    functional:
      * "liveness" — 𝓜⁽ⁱ⁾ priced by ``liveness.transition_excess`` (the
        framework default; see the module docstring);
      * "eq2"      — the paper's original eq. 2 charge (Appendix C
        ablation / benchmarks only).

    strategies:
      an extended :class:`~repro.core.strategies.StrategyConfig` switches
      to the joint memory-strategy DP (per-node {store, offload,
      quantize} choice; liveness functional only) and the result carries
      ``assignment``.  ``None`` or a non-extended config routes through
      the untouched legacy paths — bit-identical to the pre-lattice
      solver by construction.
    """
    if strategies is not None and strategies.extended:
        if functional != "liveness":
            raise ValueError(
                "the strategy lattice requires functional='liveness'"
            )
        if objective == "wallclock":
            return solve_wallclock(g, budget, family, strategies=strategies)
        if objective not in ("time_centric", "memory_centric"):
            raise ValueError(f"unknown objective {objective!r}")
        return _solve_strat(g, budget, family, objective, strategies)
    if objective == "wallclock":
        if functional != "liveness":
            raise ValueError(
                "objective='wallclock' requires functional='liveness'"
            )
        return solve_wallclock(g, budget, family)
    if objective not in ("time_centric", "memory_centric"):
        raise ValueError(f"unknown objective {objective!r}")
    _check_functional(functional, g)
    live = functional == "liveness"
    if live and not scalar_only():
        return _solve_vec(g, budget, family, objective)

    infos = _prepare(g, family)
    # ascending order of set size (line 3)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    full_mask = (1 << g.n) - 1

    empty_id = None
    full_id = None
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id is None or full_id is None:
        raise ValueError("family must contain ∅ and V")

    # Sparse DP table: per lower-set id, a dict t -> (m, parent=(id, t)).
    # Pareto pruning: keep only entries where no t'' < t has m'' <= m.
    table: List[Dict[float, Tuple[float, Optional[Tuple[int, float]]]]] = [
        {} for _ in infos
    ]
    table[empty_id][0.0] = (0.0, None)

    states = 0
    n_fam = len(order)
    sizes = [infos[i].size for i in order]
    import bisect

    for pos, i in enumerate(order):
        info_L = infos[i]
        entries = table[i]
        if not entries:
            continue
        # Pareto-prune the source entries once before expanding (§4.2 note).
        # The dominance direction depends on the objective: TC keeps the
        # (t↓, m↓) frontier; MC keeps the (t↑, m↓) frontier — an entry is
        # dominated by one with ≥ overhead so far AND ≤ cache mass.
        pruned = (_pareto(entries) if objective == "time_centric"
                  else _pareto_mc(entries))
        table[i] = pruned
        pruned_items = list(pruned.items())
        mask_L = info_L.mask
        # strictly larger sets only: start past the last equal-size entry
        start = bisect.bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            # Pair terms (cache_mask = ∂(L') plus must_store pins in L').
            Vp_mask = info_Lp.mask & ~mask_L  # V' = L' \ L
            # T(V' \ cached) — pinned nodes are stored, never recomputed
            inter = Vp_mask & info_Lp.cache_mask
            t_step = (info_Lp.T - info_L.T) - _mask_T(g, inter)
            # M(cached(L') \ L)
            m_step = _mask_M(g, info_Lp.cache_mask & ~mask_L)
            m_fixed = (
                transition_excess(g, mask_L, info_Lp.mask, info_Lp.boundary_mask)
                if live
                else 2.0 * (info_Lp.M - info_L.M) + info_Lp.m_after
            )
            row = table[j]
            for t, (m, _parent) in pruned_items:
                states += 1
                Mi = m + m_fixed  # 𝓜⁽ⁱ⁾: M(U_{i-1}) + the transition charge
                if Mi > budget:
                    continue
                t2 = t + t_step
                m2 = m + m_step
                cur = row.get(t2)
                if cur is None or cur[0] > m2:
                    row[t2] = (m2, (i, t))

    final = table[full_id]
    if not final:
        return DPResult([], INF, INF, feasible=False, states_visited=states)

    if objective == "time_centric":
        t_star = min(final)
    else:  # memory_centric: max at line 15
        t_star = max(final)

    # Traceback (line 16).
    seq_ids: List[Tuple[int, float]] = []
    cur: Optional[Tuple[int, float]] = (full_id, t_star)
    while cur is not None:
        seq_ids.append(cur)
        _m, parent = table[cur[0]][cur[1]]
        cur = parent
    seq_ids.reverse()
    sequence = [from_mask(infos[i].mask) for i, _t in seq_ids if infos[i].mask != 0]

    peak = (peak_memory_live if live else peak_memory)(g, sequence)
    return DPResult(
        sequence=sequence,
        overhead=t_star,
        peak_memory=peak,
        feasible=True,
        states_visited=states,
    )


def solve_wallclock(
    g: Graph,
    budget: float,
    family: Sequence[NodeSet],
    profile: Optional["OpProfile"] = None,
    strategies: Optional[StrategyConfig] = None,
    **replay_kw: Any,
) -> DPResult:
    """Wall-clock plan selection: sweep the surface, replay the terminals.

    Every feasible terminal overhead of the (time-centric-shaped) sweep is
    a distinct Pareto plan at ``budget``; each is lowered via ``make_plan``
    and priced by :func:`repro.core.replay.replay`, and the minimal
    replayed-seconds candidate wins (deterministic tie-break on analytic
    peak, then overhead).  ``replay_kw`` is forwarded to the replay
    (``mesh=``, ``comm_bytes=``, ``segment_costs=``, ...).

    With an extended ``strategies`` config the candidate pool is the
    *union* of the legacy (all-store) sweep's terminals and the strategy
    sweep's terminals, ranked jointly by replayed seconds — so enabling
    strategies can never select a plan that replays slower than the
    legacy winner at the same budget (the legacy winner stays in the
    pool), which is the monotonicity the strategy-ablation benchmark
    guards.
    """
    from .replay import rank_by_replay

    sw = sweep(g, family, "wallclock", cap=budget)
    if strategies is None or not strategies.extended:
        return sw.extract_wallclock(g, budget, profile=profile, **replay_kw)

    ssw = sweep(g, family, "wallclock", cap=budget, strategies=strategies)
    ts = sw.terminal_candidates(budget)
    cands: List[Tuple[float, List[NodeSet], Optional[Dict[int, str]]]] = [
        (t, [from_mask(mk) for mk in sw._traceback(budget, t)], None)
        for t in ts
    ]
    assert isinstance(ssw, StrategySweep)
    for t in ssw.terminal_candidates(budget):
        masks, assignment = ssw.traceback_with_assignment(budget, t)
        cands.append((t, [from_mask(mk) for mk in masks], assignment))
    if not cands:
        return DPResult([], INF, INF, feasible=False,
                        states_visited=sw.states_visited + ssw.states_visited)
    replay_kw.setdefault("budget", budget)
    idx, plan, _res = rank_by_replay(
        g,
        [c[1] for c in cands],
        assignments=[c[2] for c in cands],
        strategies=strategies,
        profile=profile,
        **replay_kw,
    )
    t_win, seq_win, asg_win = cands[idx]
    return DPResult(
        sequence=seq_win,
        overhead=t_win,
        peak_memory=plan.peak_memory,
        feasible=True,
        states_visited=sw.states_visited + ssw.states_visited,
        assignment=asg_win,  # None ⇒ the legacy all-store candidate won
    )


def feasible(g: Graph, budget: float, family: Sequence[NodeSet],
             infos: Optional[List[_LowerSetInfo]] = None,
             functional: str = "liveness",
             strategies: Optional[StrategyConfig] = None) -> bool:
    """Fast feasibility oracle for the budget binary search (§5.1).

    For feasibility the t axis is irrelevant and smaller cache mass m is
    always at least as good, so one min-m entry per lower set suffices —
    O(#𝓛²) instead of O(T(V)·#𝓛²).

    With an extended ``strategies`` config the same argument collapses the
    strategy lattice: only each node's minimal legal device bytes matter
    (taxes never affect feasibility), so the joint problem is the binary
    one with ``mem_v`` replaced by ``StrategyConfig.min_device_bytes``.
    """
    import bisect

    _check_functional(functional, g)
    live = functional == "liveness"
    ext = strategies is not None and strategies.extended
    if ext and not live:
        raise ValueError("the strategy lattice requires functional='liveness'")
    mem_eff = strategies.min_device_bytes(g) if ext else None
    if ext and scalar_only():
        return _feasible_strat_scalar(g, budget, family, mem_eff)
    if live and not scalar_only():
        vp = (_vec_prep(g, family) if not ext else
              _vec_prep(g, family, mem_w=mem_eff,
                        tag=strategies.digest_token()))
        if vp.full_id < 0:
            return False
        best = np.full(len(vp.infos), INF, dtype=np.float64)
        if vp.empty_id >= 0:
            best[vp.empty_id] = 0.0
        for pos, i in enumerate(vp.order):
            m = best[i]
            if m == INF:
                continue
            tg = vp.targets[pos]
            if len(tg) == 0:
                continue
            mf = _price_row(g, vp, pos)
            ok = (m + mf) <= budget  # scalar: skip Mi > B
            if not ok.any():
                continue
            sel = tg[ok]
            m2 = m + vp.m_step[pos][ok]
            cur = best[sel]
            upd = m2 < cur
            best[sel[upd]] = m2[upd]
        return bool(best[vp.full_id] < INF)
    infos = infos if infos is not None else _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    best: List[float] = [INF] * len(infos)
    for i, info in enumerate(infos):
        if info.mask == 0:
            best[i] = 0.0
    n_fam = len(order)
    for pos, i in enumerate(order):
        m = best[i]
        if m == INF:
            continue
        info_L = infos[i]
        mask_L = info_L.mask
        start = bisect.bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue
            m_fixed = (
                transition_excess(g, mask_L, info_Lp.mask, info_Lp.boundary_mask)
                if live
                else 2.0 * (info_Lp.M - info_L.M) + info_Lp.m_after
            )
            Mi = m + m_fixed
            if Mi > budget:
                continue
            m2 = m + _mask_M(g, info_Lp.cache_mask & ~mask_L)
            if m2 < best[j]:
                best[j] = m2
    for i, info in enumerate(infos):
        if info.mask == full_mask:
            return best[i] < INF
    return False


def _feasible_strat_scalar(
    g: Graph, budget: float, family: Sequence[NodeSet],
    mem_eff: Sequence[float],
) -> bool:
    """Scalar strategy-lattice feasibility: min-m per set over mem_eff."""
    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    best: List[float] = [INF] * len(infos)
    for i, info in enumerate(infos):
        if info.mask == 0:
            best[i] = 0.0
    n_fam = len(order)
    for pos, i in enumerate(order):
        m = best[i]
        if m == INF:
            continue
        info_L = infos[i]
        mask_L = info_L.mask
        start = bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue
            m_fixed = transition_excess(
                g, mask_L, info_Lp.mask, info_Lp.boundary_mask
            )
            if m + m_fixed > budget:
                continue
            m2 = m + _mask_M_w(mem_eff, info_Lp.cache_mask & ~mask_L)
            if m2 < best[j]:
                best[j] = m2
    for i, info in enumerate(infos):
        if info.mask == full_mask:
            return best[i] < INF
    return False


def _pareto(
    entries: Dict[float, Tuple[float, Optional[Tuple[int, float]]]]
) -> Dict[float, Tuple[float, Optional[Tuple[int, float]]]]:
    """Keep only (t, m) not dominated by some (t'' ≤ t, m'' ≤ m), except both equal."""
    out: Dict[float, Tuple[float, Optional[Tuple[int, float]]]] = {}
    best = INF
    for t in sorted(entries):
        m, parent = entries[t]
        if m < best:
            out[t] = (m, parent)
            best = m
    return out


def _pareto_mc(
    entries: Dict[float, Tuple[float, Optional[Tuple[int, float]]]]
) -> Dict[float, Tuple[float, Optional[Tuple[int, float]]]]:
    """MC dominance: (t, m) is dominated by (t'' ≥ t, m'' ≤ m) — any feasible
    continuation of the dominated entry is feasible from the dominating one
    and ends with at least as much total overhead."""
    out: Dict[float, Tuple[float, Optional[Tuple[int, float]]]] = {}
    best = INF
    for t in sorted(entries, reverse=True):
        m, parent = entries[t]
        if m < best:
            out[t] = (m, parent)
            best = m
    return out


# ---------------------------------------------------------------------------
# Budget-free sweep solver
# ---------------------------------------------------------------------------
#
# The per-budget DP keeps, per (lower set, t), the minimal cache mass m of
# any transition chain whose every 𝓜⁽ⁱ⁾ fits the budget.  The sweep drops
# the filter and instead carries peak = max_i 𝓜⁽ⁱ⁾ along each chain, so a
# cell holds a small Pareto frontier over (m, peak):
#
#   * sorted by peak strictly ascending;
#   * (m, pos) lexicographically *strictly descending*, where pos is the
#     size-order position of the transition's source lower set.
#
# Projecting a cell at budget B (candidates with peak ≤ B, winner = minimal
# (m, pos)) recovers exactly the per-budget DP's cell: m matches its value
# and pos identifies the same first-writer parent.  Because the frontier
# keys are monotone, the projection winner is simply the candidate with the
# largest peak ≤ B — one bisect per cell.


class _Cell:
    """Frontier of one DP cell ``(lower set, t)``: parallel candidate lists.

    Invariants: ``peaks`` strictly ascending, ``(ms, poss)`` lex strictly
    descending.  ``parent_ids``/``parent_ts`` locate the predecessor cell
    (family index and t); the ∅-seed candidate uses ``(-1, 0.0)``.
    """

    __slots__ = ("peaks", "ms", "poss", "parent_ids", "parent_ts")

    def __init__(self):
        self.peaks: List[float] = []
        self.ms: List[float] = []
        self.poss: List[int] = []
        self.parent_ids: List[int] = []
        self.parent_ts: List[float] = []

    def insert(self, m: float, peak: float, pos: int, pid: int, pt: float) -> None:
        peaks = self.peaks
        ms = self.ms
        poss = self.poss
        i = bisect_left(peaks, peak)
        if i > 0:
            pm = ms[i - 1]
            if pm < m or (pm == m and poss[i - 1] <= pos):
                return  # dominated by a lower-peak candidate with a ≤ key
        j = i
        n = len(peaks)
        while j < n:
            jm = ms[j]
            if jm > m or (jm == m and poss[j] >= pos):
                j += 1  # evict candidates the newcomer dominates
            else:
                break
        if j < n and peaks[j] == peak:
            return  # an equal-peak candidate with a strictly smaller key
        del peaks[i:j], ms[i:j], poss[i:j]
        del self.parent_ids[i:j], self.parent_ts[i:j]
        peaks.insert(i, peak)
        ms.insert(i, m)
        poss.insert(i, pos)
        self.parent_ids.insert(i, pid)
        self.parent_ts.insert(i, pt)

    def winner(self, budget: float) -> int:
        """Index of the budget-B projection winner, or -1 if none fits."""
        return bisect_right(self.peaks, budget) - 1

    def min_peak(self) -> float:
        return self.peaks[0] if self.peaks else INF

    def copy(self) -> "_Cell":
        out = _Cell()
        out.peaks = list(self.peaks)
        out.ms = list(self.ms)
        out.poss = list(self.poss)
        out.parent_ids = list(self.parent_ids)
        out.parent_ts = list(self.parent_ts)
        return out


class SweepOverflow(RuntimeError):
    """Raised when a sweep would exceed its ``max_states`` work cap.

    The (t, m, peak) surface of a graph can be much larger than any single
    budget's slice of it (one slice per *budget regime*); callers that only
    need one budget catch this and fall back to the per-budget DP.
    """


def _mfb_vec(g: Graph, family: Sequence[NodeSet],
             vp: Optional[_VecPrep] = None) -> float:
    """Vectorized :func:`min_feasible_budget_exact` (liveness functional).

    Gather formulation: candidates pushed into a lower set are buffered as
    raw (m, peak) chunks and canonically Pareto-filtered once, when the
    set's turn comes as a source — the scalar insert loop maintains the
    same order-independent set incrementally.  ``vp`` lets the strategy
    lattice substitute its ``mem_eff``-weighted prep.
    """
    vp = vp if vp is not None else _vec_prep(g, family)
    _require_terminals(vp)
    # Incoming candidates accumulate as flat python float lists — 130k
    # tiny per-(source, target) ndarrays cost more to concatenate than the
    # whole DP; ``tolist``/``asarray`` round-trip float64 exactly, and
    # ``extend`` preserves the source-order arrival the canonical filter
    # expects.
    pend_m: List[List[float]] = [[] for _ in vp.infos]
    pend_p: List[List[float]] = [[] for _ in vp.infos]
    pend_m[vp.empty_id].append(0.0)
    pend_p[vp.empty_id].append(0.0)
    final_p: Optional[NDArray[np.float64]] = None
    for pos, i in enumerate(vp.order):
        mlist = pend_m[i]
        plist = pend_p[i]
        pend_m[i] = []
        pend_p[i] = []
        if not mlist:
            continue
        ms = np.asarray(mlist, dtype=np.float64)
        ps = np.asarray(plist, dtype=np.float64)
        o = np.lexsort((ps, ms))
        ms, ps = ms[o], ps[o]
        keep = _pareto_keep(ms, ps)
        src_m, src_p = ms[keep], ps[keep]
        if i == vp.full_id:
            final_p = src_p
        tg = vp.targets[pos]
        if len(tg) == 0:
            continue
        mf = _price_row(g, vp, pos)
        m_step = vp.m_step[pos]
        # (J, F) candidate blocks — the scalar expressions, elementwise.
        m2 = src_m[None, :] + m_step[:, None]
        peak2 = np.maximum(src_m[None, :] + mf[:, None], src_p[None, :])
        for t, mrow, prow in zip(tg.tolist(), m2.tolist(), peak2.tolist()):
            pend_m[t].extend(mrow)
            pend_p[t].extend(prow)
    if final_p is None or len(final_p) == 0:
        return INF
    return float(final_p[-1])


def min_feasible_budget_exact(g: Graph, family: Sequence[NodeSet],
                              functional: str = "liveness",
                              strategies: Optional[StrategyConfig] = None,
                              ) -> float:
    """Exact minimal feasible budget in one forward pass (no search).

    min over canonical strategies of max_i 𝓜⁽ⁱ⁾ (the liveness-tight
    functional; ``functional="eq2"`` prices by the paper's eq. 2 for the
    ablation benchmarks) — replaces the §5.1 binary search and its
    per-probe feasibility DPs, and unlike the search's tolerance the result
    is itself exactly feasible.

    This is the t-less projection of :func:`sweep`: per lower set a Pareto
    frontier over ``(m, peak)`` only.  Every arithmetic expression — the
    left-folded cache mass ``m + m_step`` and the transition peak
    ``m + m_fixed`` — is written *identically* to :func:`solve` /
    :func:`feasible`, so the returned budget sits exactly on the per-budget
    DP's own float feasibility threshold: ``solve(g, B)`` is feasible at
    ``B = result`` and infeasible one ulp below (a re-associated closed
    form, e.g. ``2·M(L') + m_after − 2·M(L)``, can land an ulp off and
    return a budget the DP rejects; the liveness functional sidesteps this
    by having all four entry points read the same memoized
    ``transition_excess`` value per pair).

    With an extended ``strategies`` config the lattice collapses exactly
    as in :func:`feasible` — a chain's peak only falls when carried bytes
    fall, so every node takes its minimal legal device bytes
    (``mem_eff``) and the legacy algorithm runs over that weight vector.
    The result sits on the joint DP's own float threshold:
    ``solve(..., strategies=cfg)`` is feasible at the returned budget and
    infeasible one ulp below, because the DP's all-min-bytes transition
    option folds the identical floats.
    """
    _check_functional(functional, g)
    live = functional == "liveness"
    ext = strategies is not None and strategies.extended
    if ext and not live:
        raise ValueError("the strategy lattice requires functional='liveness'")
    mem_eff = strategies.min_device_bytes(g) if ext else None
    if live and not scalar_only():
        if ext:
            return _mfb_vec(
                g, family,
                vp=_vec_prep(g, family, mem_w=mem_eff,
                             tag=strategies.digest_token()),
            )
        return _mfb_vec(g, family)
    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    empty_id = full_id = None
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id is None or full_id is None:
        raise ValueError("family must contain ∅ and V")

    # per lower set: ms ascending, peaks strictly descending (Pareto)
    fr_m: List[List[float]] = [[] for _ in infos]
    fr_p: List[List[float]] = [[] for _ in infos]
    fr_m[empty_id].append(0.0)
    fr_p[empty_id].append(0.0)
    n_fam = len(order)
    for pos, i in enumerate(order):
        src_m = fr_m[i]
        if not src_m:
            continue
        src_p = fr_p[i]
        info_L = infos[i]
        mask_L = info_L.mask
        start = bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            m_step = (
                _mask_M(g, info_Lp.cache_mask & ~mask_L)
                if mem_eff is None
                else _mask_M_w(mem_eff, info_Lp.cache_mask & ~mask_L)
            )
            m_fixed = (
                transition_excess(g, mask_L, info_Lp.mask, info_Lp.boundary_mask)
                if live
                else 2.0 * (info_Lp.M - info_L.M) + info_Lp.m_after
            )
            tm = fr_m[j]
            tp = fr_p[j]
            for m, peak in zip(src_m, src_p):
                Mi = m + m_fixed  # 𝓜⁽ⁱ⁾, same floats as solve()
                peak2 = Mi if Mi > peak else peak
                m2 = m + m_step
                idx = bisect_right(tm, m2) - 1
                if idx >= 0 and tp[idx] <= peak2:
                    continue  # dominated
                lo = bisect_left(tm, m2)
                hi = lo
                while hi < len(tm) and tp[hi] >= peak2:
                    hi += 1
                del tm[lo:hi], tp[lo:hi]
                tm.insert(lo, m2)
                tp.insert(lo, peak2)
    peaks = fr_p[full_id]
    return peaks[-1] if peaks else INF


@dataclasses.dataclass
class Sweep:
    """Full (budget → plan) Pareto surface of one planning problem.

    Produced by :func:`sweep`; ``extract(B)`` reproduces the per-budget
    ``solve`` bit-identically for any ``B``.  ``family_masks`` are node-set
    bitmasks in the coordinate system the sweep was built in (the source
    graph's node ids, or canonical positions after :meth:`to_canonical`);
    everything else — cells, t/m/peak values, parent links — is
    coordinate-free, which is what makes cached sweeps transfer between
    isomorphic graph labelings.
    """

    objective: str
    n: int
    family_masks: List[int]
    cells: List[Dict[float, _Cell]]
    empty_id: int
    full_id: int
    states_visited: int = 0
    cap: Optional[float] = None  # budgets > cap were not swept (None = all)

    def covers(self, budget: float) -> bool:
        """True iff ``extract(budget)`` is answerable from this sweep."""
        return self.cap is None or budget <= self.cap

    def extend(self, g: Graph, cap: Optional[float] = None,
               max_states: Optional[int] = None) -> "Sweep":
        """Grow this capped surface to ``cap`` (None = the full surface).

        Lazy refinement: a capped sweep's cells are exactly the full
        surface's cells with every candidate of peak > cap dropped — a
        large-peak candidate can only dominate/evict larger-peak ones, so
        the ≤ cap band is unaffected by the missing tail.  Extension
        therefore re-runs the transition pass seeded with the existing
        cells and only *inserts* candidates in the new ``(old cap, cap]``
        band; the already-materialized band is never re-built, and pairs
        that cannot reach the new band are skipped outright.

        ``g`` must be labeled in the sweep's own coordinates (as for
        :meth:`solve`); the planner remaps cached canonical sweeps first.
        Returns a **new** Sweep (``self`` is not mutated) whose
        ``extract(B)`` is bit-identical to a fresh
        ``sweep(g, family, cap=cap)`` at every ``B ≤ cap``.
        """
        if self.cap is None or (cap is not None and cap <= self.cap):
            return self  # already covers the requested range
        family = [from_mask(mk) for mk in self.family_masks]
        return sweep(g, family, self.objective, max_states=max_states,
                     cap=cap, prior=self)

    # ------------------------------------------------------------ extraction

    def _terminal_t(self, budget: float) -> Optional[float]:
        term = self.cells[self.full_id]
        ts = [t for t, cell in term.items() if cell.min_peak() <= budget]
        if not ts:
            return None
        return max(ts) if self.objective == "memory_centric" else min(ts)

    def extract(self, budget: float) -> Tuple[bool, float, List[int]]:
        """Budget-B projection: ``(feasible, overhead, sequence-of-masks)``.

        The mask sequence excludes ∅ and is expressed in the sweep's own
        coordinates (see class docstring).
        """
        if not self.covers(budget):
            raise ValueError(
                f"budget {budget!r} beyond this sweep's cap {self.cap!r}"
            )
        t_star = self._terminal_t(budget)
        if t_star is None:
            return False, INF, []
        return True, t_star, self._traceback(budget, t_star)

    def _traceback(self, budget: float, t_star: float) -> List[int]:
        """Mask sequence of the budget-B winner ending at terminal t_star."""
        masks: List[int] = []
        pid, pt = self.full_id, t_star
        while pid >= 0:
            cell = self.cells[pid][pt]
            k = cell.winner(budget)
            if self.family_masks[pid]:
                masks.append(self.family_masks[pid])
            pid, pt = cell.parent_ids[k], cell.parent_ts[k]
        masks.reverse()
        return masks

    def terminal_candidates(self, budget: float) -> List[float]:
        """Every feasible terminal overhead at ``budget``, ascending.

        Each entry is a distinct Pareto plan the budget admits —
        ``extract_at(budget, t)`` materializes any of them, and the
        wall-clock objective ranks them all by replayed time instead of
        taking the min/max one.
        """
        if not self.covers(budget):
            raise ValueError(
                f"budget {budget!r} beyond this sweep's cap {self.cap!r}"
            )
        term = self.cells[self.full_id]
        return sorted(
            t for t, cell in term.items() if cell.min_peak() <= budget
        )

    def extract_at(self, budget: float, t: float) -> List[int]:
        """Mask sequence of the plan ending at terminal overhead ``t``."""
        cell = self.cells[self.full_id].get(t)
        if cell is None or cell.min_peak() > budget:
            raise ValueError(
                f"terminal t={t!r} is not feasible at budget {budget!r}"
            )
        return self._traceback(budget, t)

    def extract_wallclock(
        self, g: Graph, budget: float,
        profile: Optional["OpProfile"] = None, **replay_kw: Any,
    ) -> DPResult:
        """Replay-ranked extraction: the minimal replayed-seconds terminal.

        ``g`` must be labeled in the sweep's coordinates.  Feasibility is
        unchanged from the other objectives (peak-based); only the choice
        among feasible terminals differs.  ``replay_kw`` forwards to
        :func:`repro.core.replay.replay` (``mesh=``, ``comm_bytes=``, ...).
        """
        from .replay import rank_by_replay

        ts = self.terminal_candidates(budget)
        if not ts:
            return DPResult([], INF, INF, feasible=False,
                            states_visited=self.states_visited)
        seqs = [
            [from_mask(mk) for mk in self._traceback(budget, t)] for t in ts
        ]
        replay_kw.setdefault("budget", budget)
        idx, plan, _res = rank_by_replay(g, seqs, profile=profile, **replay_kw)
        return DPResult(
            sequence=seqs[idx],
            overhead=ts[idx],
            peak_memory=plan.peak_memory,
            feasible=True,
            states_visited=self.states_visited,
        )

    def solve(self, g: Graph, budget: float) -> DPResult:
        """``solve(g, budget, family, objective)`` via frontier lookup.

        ``g`` must be labeled in the sweep's coordinates (i.e. the graph the
        sweep was built from); the planner handles relabeled graphs itself.
        """
        if self.objective == "wallclock":
            return self.extract_wallclock(g, budget)
        ok, t_star, masks = self.extract(budget)
        if not ok:
            return DPResult([], INF, INF, feasible=False,
                            states_visited=self.states_visited)
        sequence = [from_mask(mk) for mk in masks]
        return DPResult(
            sequence=sequence,
            overhead=t_star,
            peak_memory=peak_memory_live(g, sequence),
            feasible=True,
            states_visited=self.states_visited,
        )

    def min_feasible_budget(self) -> float:
        """Exact minimal feasible budget: min over terminal cells of the
        smallest achievable peak (replaces the §5.1 binary search).

        On a capped sweep, INF means "infeasible within the cap", not
        globally infeasible — ``dp.min_feasible_budget_exact`` answers the
        uncapped question in one cheap scalar pass.
        """
        term = self.cells[self.full_id]
        return min((cell.min_peak() for cell in term.values()), default=INF)

    def frontier(self) -> List[Tuple[float, float]]:
        """(budget, overhead) Pareto staircase at the terminal state.

        Returns the critical budgets in increasing order with the overhead
        each unlocks; ``extract(B)`` for any ``B`` equals the entry with the
        largest budget ≤ B.  Time-centric: overhead strictly decreasing.
        Memory-centric: overhead strictly increasing (§4.4 maximizes).
        """
        term = self.cells[self.full_id]
        # empty cells (every candidate above the cap) carry peak = INF and
        # would otherwise emit phantom staircase entries
        pts = sorted(
            (cell.min_peak(), t) for t, cell in term.items() if cell.peaks
        )
        out: List[Tuple[float, float]] = []
        better = (lambda a, b: a > b) if self.objective == "memory_centric" else (
            lambda a, b: a < b)
        for peak, t in pts:
            if not out or better(t, out[-1][1]):
                if out and out[-1][0] == peak:
                    out[-1] = (peak, t)
                else:
                    out.append((peak, t))
        return out

    # ---------------------------------------------------------- relabeling

    def remap(self, mapping: Dict[int, int]) -> "Sweep":
        """New Sweep with every family mask pushed through ``mapping``."""
        remapped = []
        for mask in self.family_masks:
            m2 = 0
            for v in mask_iter(mask):
                m2 |= 1 << mapping[v]
            remapped.append(m2)
        return dataclasses.replace(self, family_masks=remapped)

    def to_canonical(self, to_pos: Dict[int, int]) -> "Sweep":
        """Sweep re-expressed in canonical positions (cache storage form)."""
        return self.remap(to_pos)

    # -------------------------------------------------------- serialization

    def encode(self) -> dict:
        """JSON-able form (store sweeps in canonical coordinates)."""
        return {
            "objective": self.objective,
            "cap": self.cap,
            "n": self.n,
            "family": [sorted(mask_iter(mk)) for mk in self.family_masks],
            "cells": [
                [
                    [t, cell.peaks, cell.ms, cell.parent_ids, cell.parent_ts]
                    for t, cell in sorted(cdict.items())
                ]
                for cdict in self.cells
            ],
            "states_visited": int(self.states_visited),
        }


class _SCell(_Cell):
    """A sweep cell that additionally remembers each candidate's strategy
    option (index into ``StrategySweep.opt_tab``)."""

    __slots__ = ("opt_ids",)

    def __init__(self):
        super().__init__()
        self.opt_ids: List[int] = []

    def insert_opt(self, m: float, peak: float, pos: int, pid: int,
                   pt: float, oc: int) -> None:
        """:meth:`_Cell.insert` with the option id carried alongside."""
        peaks = self.peaks
        ms = self.ms
        poss = self.poss
        i = bisect_left(peaks, peak)
        if i > 0:
            pm = ms[i - 1]
            if pm < m or (pm == m and poss[i - 1] <= pos):
                return
        j = i
        n = len(peaks)
        while j < n:
            jm = ms[j]
            if jm > m or (jm == m and poss[j] >= pos):
                j += 1
            else:
                break
        if j < n and peaks[j] == peak:
            return
        del peaks[i:j], ms[i:j], poss[i:j]
        del self.parent_ids[i:j], self.parent_ts[i:j], self.opt_ids[i:j]
        peaks.insert(i, peak)
        ms.insert(i, m)
        poss.insert(i, pos)
        self.parent_ids.insert(i, pid)
        self.parent_ts.insert(i, pt)
        self.opt_ids.insert(i, oc)

    def copy(self) -> "_SCell":
        out = _SCell()
        out.peaks = list(self.peaks)
        out.ms = list(self.ms)
        out.poss = list(self.poss)
        out.parent_ids = list(self.parent_ids)
        out.parent_ts = list(self.parent_ts)
        out.opt_ids = list(self.opt_ids)
        return out


@dataclasses.dataclass
class StrategySweep(Sweep):
    """Budget-free surface of the joint memory-strategy DP.

    ``opt_tab[k]`` is the ``(new_mask, codes)`` of one transition option;
    each cell candidate's ``opt_ids`` entry points into it, so a traceback
    recovers the per-node strategy assignment alongside the sequence.
    Strategy sweeps are in-memory objects: :meth:`encode` marks them with
    the config's digest token and ``decode_sweep`` refuses such entries,
    so they never alias a legacy surface in the plan cache.

    Tie-break note: when two strategy assignments reach a cell with the
    exact same carried mass ``m``, the cell keeps the lower-peak one while
    the per-budget ``_solve_strat`` table keeps the first writer — so
    :meth:`solve` here may return a *different equally-optimal* assignment
    than :func:`solve` (identical overhead and feasibility; both within
    budget).  The quantize byte ratio makes such exact ties more common
    than in the binary DP.
    """

    config: Optional[StrategyConfig] = None
    opt_tab: List[Tuple[int, Tuple[str, ...]]] = dataclasses.field(
        default_factory=list
    )

    def extend(self, g: Graph, cap: Optional[float] = None,
               max_states: Optional[int] = None) -> "Sweep":
        if self.cap is None or (cap is not None and cap <= self.cap):
            return self
        family = [from_mask(mk) for mk in self.family_masks]
        return sweep(g, family, self.objective, max_states=max_states,
                     cap=cap, strategies=self.config)

    def traceback_with_assignment(
        self, budget: float, t_star: float
    ) -> Tuple[List[int], Dict[int, str]]:
        """(mask sequence, merged node → strategy map) of the budget-B winner."""
        masks: List[int] = []
        assignment: Dict[int, str] = {}
        pid, pt = self.full_id, t_star
        while pid >= 0:
            cell = self.cells[pid][pt]
            assert isinstance(cell, _SCell)
            k = cell.winner(budget)
            if self.family_masks[pid]:
                masks.append(self.family_masks[pid])
            oc = cell.opt_ids[k]
            if oc >= 0:
                new_mask, codes = self.opt_tab[oc]
                assignment.update(assignment_of(new_mask, codes))
            pid, pt = cell.parent_ids[k], cell.parent_ts[k]
        masks.reverse()
        return masks, assignment

    def solve(self, g: Graph, budget: float) -> DPResult:
        if self.objective == "wallclock":
            return self.extract_wallclock(g, budget)
        ok, t_star, _masks = self.extract(budget)
        if not ok:
            return DPResult([], INF, INF, feasible=False,
                            states_visited=self.states_visited)
        masks, assignment = self.traceback_with_assignment(budget, t_star)
        sequence = [from_mask(mk) for mk in masks]
        return DPResult(
            sequence=sequence,
            overhead=t_star,
            peak_memory=peak_memory_live(g, sequence, assignment),
            feasible=True,
            states_visited=self.states_visited,
            assignment=assignment,
        )

    def extract_wallclock(
        self, g: Graph, budget: float,
        profile: Optional["OpProfile"] = None, **replay_kw: Any,
    ) -> DPResult:
        """Replay-ranked extraction over this surface's own candidates.

        Joint ranking against the legacy all-store surface lives in
        :func:`solve_wallclock` — that is the entry point that guarantees
        never-worse-than-legacy step time.
        """
        from .replay import rank_by_replay

        ts = self.terminal_candidates(budget)
        if not ts:
            return DPResult([], INF, INF, feasible=False,
                            states_visited=self.states_visited)
        pairs = [self.traceback_with_assignment(budget, t) for t in ts]
        seqs = [[from_mask(mk) for mk in masks] for masks, _a in pairs]
        replay_kw.setdefault("budget", budget)
        idx, plan, _res = rank_by_replay(
            g, seqs, assignments=[a for _m, a in pairs],
            strategies=self.config, profile=profile, **replay_kw,
        )
        return DPResult(
            sequence=seqs[idx],
            overhead=ts[idx],
            peak_memory=plan.peak_memory,
            feasible=True,
            states_visited=self.states_visited,
            assignment=pairs[idx][1],
        )

    def remap(self, mapping: Dict[int, int]) -> "StrategySweep":
        out = super().remap(mapping)
        tab = []
        for mask, codes in self.opt_tab:
            m2 = 0
            for v in mask_iter(mask):
                m2 |= 1 << mapping[v]
            tab.append((m2, codes))
        return dataclasses.replace(out, opt_tab=tab)

    def encode(self) -> dict:
        out = super().encode()
        out["strategy"] = self.config.digest_token() if self.config else ""
        return out


def _sweep_strat(g: Graph, family: Sequence[NodeSet], objective: str,
                 max_states: Optional[int], cap: Optional[float],
                 cfg: StrategyConfig) -> StrategySweep:
    """Budget-free joint memory-strategy sweep (scalar in both modes).

    One implementation serves vectorized and ``REPRO_DP_SCALAR=1``
    sessions alike — trivially bit-identical across modes; the strategy
    surface's option fan-out is frontier-bounded on the segment-structured
    graphs the planner sweeps, so the scalar loop is not the bottleneck.
    Candidate floats are folded exactly as :func:`_solve_strat_scalar`
    does (``m + option.m_add``, ``t + (t_step + option.tax)``,
    ``max(peak, m + m_fixed)``), so projecting the surface at a budget
    lands on the same feasibility thresholds the per-budget joint DP
    filters on.
    """
    tc = objective != "memory_centric"  # "wallclock" sweeps the TC surface
    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    pos_of = [0] * len(order)
    for p, i in enumerate(order):
        pos_of[i] = p
    sizes = [infos[i].size for i in order]
    full_mask = (1 << g.n) - 1
    empty_id = full_id = -1
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id < 0 or full_id < 0:
        raise ValueError("family must contain ∅ and V")

    cells: List[Dict[float, _Cell]] = [{} for _ in infos]
    seed = _SCell()
    seed.insert_opt(0.0, 0.0, -1, -1, 0.0, -1)
    cells[empty_id][0.0] = seed
    opt_tab: List[Tuple[int, Tuple[str, ...]]] = []

    states = 0
    state_cap = max_states if max_states is not None else INF
    budget_cap = cap if cap is not None else INF
    n_fam = len(order)

    for pos, i in enumerate(order):
        info_L = infos[i]
        cdict = cells[i]
        if not cdict:
            continue
        # Source-side (m, peak) frontier over cells in t order — identical
        # dominance rule to the legacy scalar sweep.
        fr_m: List[float] = []
        fr_p: List[float] = []
        expansions: List[Tuple[float, List[float], List[float]]] = []
        for t in sorted(cdict, reverse=not tc):
            cell = cdict[t]
            kms: List[float] = []
            kpeaks: List[float] = []
            for k in range(len(cell.peaks) - 1, -1, -1):  # m asc / peak desc
                m, peak = cell.ms[k], cell.peaks[k]
                idx = bisect_right(fr_m, m) - 1
                if idx >= 0 and fr_p[idx] <= peak:
                    continue
                kms.append(m)
                kpeaks.append(peak)
            if kms:
                expansions.append((t, kms, kpeaks))
            for m, peak in zip(kms, kpeaks):
                idx = bisect_right(fr_m, m) - 1
                if idx >= 0 and fr_p[idx] <= peak:
                    continue
                lo = bisect_left(fr_m, m)
                hi = lo
                while hi < len(fr_m) and fr_p[hi] >= peak:
                    hi += 1
                del fr_m[lo:hi], fr_p[lo:hi]
                fr_m.insert(lo, m)
                fr_p.insert(lo, peak)

        if not expansions:
            continue
        mask_L = info_L.mask
        src_pos = pos_of[i]
        start = bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            Vp_mask = info_Lp.mask & ~mask_L
            inter = Vp_mask & info_Lp.cache_mask
            t_step = (info_Lp.T - info_L.T) - _mask_T(g, inter)
            new_mask = info_Lp.cache_mask & ~mask_L
            m_fixed = transition_excess(
                g, mask_L, info_Lp.mask, info_Lp.boundary_mask
            )
            target = cells[j]
            for opt in transition_options(g, cfg, new_mask, tc):
                t_step_o = t_step + opt.tax if tc else t_step
                oc = len(opt_tab)
                oc_used = False
                for t, kms, kpeaks in expansions:
                    t2 = t + t_step_o
                    cell2 = target.get(t2)
                    for k in range(len(kms)):
                        m = kms[k]
                        peak = kpeaks[k]
                        Mi = m + m_fixed  # same floats as the joint DP
                        if Mi > peak:
                            peak = Mi
                        if peak > budget_cap:
                            continue
                        states += 1
                        if cell2 is None:
                            cell2 = target[t2] = _SCell()
                        assert isinstance(cell2, _SCell)
                        cell2.insert_opt(
                            m + opt.m_add, peak, src_pos, i, t, oc
                        )
                        oc_used = True
                if oc_used:
                    opt_tab.append((new_mask, opt.codes))
        if states > state_cap:
            raise SweepOverflow(
                f"strategy sweep exceeded max_states={max_states} "
                f"({states} transitions; family of {n_fam})"
            )

    return StrategySweep(
        objective=objective,
        n=g.n,
        family_masks=[info.mask for info in infos],
        cells=cells,
        empty_id=empty_id,
        full_id=full_id,
        states_visited=states,
        cap=cap,
        config=cfg,
        opt_tab=opt_tab,
    )


def decode_sweep(entry: dict) -> Optional[Sweep]:
    """Inverse of ``Sweep.encode``; returns None on any malformed input."""
    try:
        if entry.get("strategy"):
            return None  # strategy surfaces are in-memory only
        objective = entry["objective"]
        if objective not in ("time_centric", "memory_centric", "wallclock"):
            return None
        n = int(entry["n"])
        family_masks = [to_mask(members) for members in entry["family"]]
        full_mask = (1 << n) - 1
        empty_id = family_masks.index(0)
        full_id = family_masks.index(full_mask)
        sizes = [mk.bit_count() for mk in family_masks]
        order = sorted(range(len(family_masks)), key=lambda i: sizes[i])
        pos_of = [0] * len(order)
        for p, i in enumerate(order):
            pos_of[i] = p
        cells: List[Dict[float, _Cell]] = []
        for cdict_enc in entry["cells"]:
            cdict: Dict[float, _Cell] = {}
            for t, peaks, ms, pids, pts in cdict_enc:
                cell = _Cell()
                cell.peaks = [float(x) for x in peaks]
                cell.ms = [float(x) for x in ms]
                cell.parent_ids = [int(x) for x in pids]
                cell.parent_ts = [float(x) for x in pts]
                cell.poss = [
                    pos_of[pid] if pid >= 0 else -1 for pid in cell.parent_ids
                ]
                k = len(cell.peaks)
                if not (len(cell.ms) == len(cell.parent_ids)
                        == len(cell.parent_ts) == k) or k == 0:
                    return None
                cdict[float(t)] = cell
            cells.append(cdict)
        if len(cells) != len(family_masks):
            return None
        cap = entry.get("cap")
        return Sweep(
            objective=objective,
            n=n,
            family_masks=family_masks,
            cells=cells,
            empty_id=empty_id,
            full_id=full_id,
            states_visited=int(entry.get("states_visited", 0)),
            cap=float(cap) if cap is not None else None,
        )
    except (KeyError, IndexError, TypeError, ValueError):
        return None


def _finalize_cell(
    pk: NDArray[np.float64],
    mm: NDArray[np.float64],
    po: NDArray[np.int64],
    pid: NDArray[np.int64],
    pt: NDArray[np.float64],
) -> _Cell:
    """Canonical (peak, (m, pos)) frontier of one cell's gathered candidates.

    Reproduces what a sequence of :meth:`_Cell.insert` calls retains: the
    Pareto-minimal set under (peak ≤, (m, pos) lex ≤) with duplicates
    collapsed — order-independent, so one sort + strict prefix-min scan
    over a lex *rank* of (m, pos) equals the incremental result.
    """
    o2 = np.lexsort((po, mm))
    rk = np.empty(len(mm), dtype=np.int64)
    ch = np.empty(len(mm), dtype=np.int64)
    ch[0] = 0
    ch[1:] = np.cumsum(
        (mm[o2][1:] != mm[o2][:-1]) | (po[o2][1:] != po[o2][:-1])
    )
    rk[o2] = ch
    o = np.lexsort((rk, pk))
    rks = rk[o]
    keep = np.empty(len(o), dtype=bool)
    keep[0] = True
    pm = np.minimum.accumulate(rks)
    keep[1:] = rks[1:] < pm[:-1]
    ks = o[keep]
    cell = _Cell()
    cell.peaks = [float(x) for x in pk[ks]]
    cell.ms = [float(x) for x in mm[ks]]
    cell.poss = [int(x) for x in po[ks]]
    cell.parent_ids = [int(x) for x in pid[ks]]
    cell.parent_ts = [float(x) for x in pt[ks]]
    return cell


# A pending-candidate column: a full per-candidate array, or one scalar
# broadcast over the chunk (chunks from a single expansion share their
# source position/id, so materializing constant columns is wasted work).
_Col = Union[float, int, NDArray[np.float64], NDArray[np.int64]]


def _fill_col(vals: Sequence[_Col], counts: Sequence[int], total: int,
              dtype: type) -> np.ndarray:
    """Concatenate mixed scalar/array columns into one array.

    Scalars broadcast over their chunk's length — the assembly-time
    equivalent of the ``np.full`` columns chunks used to carry.
    """
    out = np.empty(total, dtype=dtype)
    off = 0
    for v, c in zip(vals, counts):
        out[off:off + c] = v
        off += c
    return out


def _sweep_vec(g: Graph, family: Sequence[NodeSet], objective: str,
               max_states: Optional[int], cap: Optional[float],
               prior: Optional[Sweep]) -> Sweep:
    """Vectorized :func:`sweep` — gather-then-filter frontier merges.

    Candidates bound for a cell are buffered as raw array chunks and
    canonically filtered once when the cell's lower set becomes a source
    (:func:`_finalize_cell`); per-pair expansion windows, cap filters and
    the work counter are evaluated as one (J targets × ΣF candidates)
    block per source, with the source's cells laid out as contiguous
    column segments (the per-cell crossover scan becomes a segmented
    min-reduce).  Small graphs are dominated by per-call overhead, so the
    kernel touches numpy O(sources) times, not O(source cells) times.
    """
    tc = objective != "memory_centric"  # "wallclock" sweeps the TC surface
    vp = _vec_prep(g, family)
    _require_terminals(vp)
    n_infos = len(vp.infos)
    n_fam = len(vp.order)

    # pending chunks per set: (t, peak, m, pos, parent_id, parent_t)
    pend: List[
        List[Tuple[_Col, NDArray[np.float64], NDArray[np.float64],
                   _Col, _Col, _Col]]
    ] = [[] for _ in range(n_infos)]

    skip_cap = -INF
    prior_states = 0
    if prior is not None:
        if prior.objective != objective:
            raise ValueError(
                f"prior sweep objective {prior.objective!r} != {objective!r}"
            )
        if prior.family_masks != [info.mask for info in vp.infos]:
            raise ValueError("prior sweep was built over a different family")
        if prior.cap is None or (cap is not None and cap <= prior.cap):
            return prior  # nothing to extend
        skip_cap = prior.cap
        prior_states = prior.states_visited
        for j, cdict_prior in enumerate(prior.cells):
            for t, cell in cdict_prior.items():
                pend[j].append((
                    t,
                    np.asarray(cell.peaks, dtype=np.float64),
                    np.asarray(cell.ms, dtype=np.float64),
                    np.asarray(cell.poss, dtype=np.int64),
                    np.asarray(cell.parent_ids, dtype=np.int64),
                    np.asarray(cell.parent_ts, dtype=np.float64),
                ))
    else:
        zero = np.zeros(1, dtype=np.float64)
        pend[vp.empty_id].append((0.0, zero, zero, -1, -1, 0.0))

    states = 0
    state_cap = max_states if max_states is not None else INF
    budget_cap = cap if cap is not None else INF
    cells: List[Dict[float, _Cell]] = [{} for _ in range(n_infos)]
    empty_f = np.zeros(0, dtype=np.float64)

    for pos, i in enumerate(vp.order):
        chunks = pend[i]
        pend[i] = []
        cdict = cells[i]
        if chunks:
            counts = [len(c[1]) for c in chunks]
            total = sum(counts)
            tt = _fill_col([c[0] for c in chunks], counts, total, np.float64)
            pk = np.concatenate([c[1] for c in chunks])
            mm = np.concatenate([c[2] for c in chunks])
            po = _fill_col([c[3] for c in chunks], counts, total, np.int64)
            pidv = _fill_col([c[4] for c in chunks], counts, total, np.int64)
            ptv = _fill_col([c[5] for c in chunks], counts, total, np.float64)
            ts_u, inv = np.unique(tt, return_inverse=True)
            so = np.argsort(inv, kind="stable")
            bnd = np.concatenate(
                [np.zeros(1, dtype=np.int64), np.cumsum(np.bincount(inv))]
            )
            for gi in range(len(ts_u)):
                idx = so[bnd[gi]:bnd[gi + 1]]
                cdict[float(ts_u[gi])] = _finalize_cell(
                    pk[idx], mm[idx], po[idx], pidv[idx], ptv[idx]
                )
        if not cdict:
            continue

        # Source-side pruning — the scalar running (m, peak) frontier over
        # cells in t order, with the per-cell scans batched.
        fr_m = empty_f
        fr_p = empty_f
        expansions: List[Tuple[float, NDArray[np.float64], NDArray[np.float64]]] = []
        for t in sorted(cdict, reverse=not tc):
            cell = cdict[t]
            m_a = np.asarray(cell.ms[::-1], dtype=np.float64)  # m asc / peak desc
            p_a = np.asarray(cell.peaks[::-1], dtype=np.float64)
            if len(fr_m):
                idx = np.searchsorted(fr_m, m_a, side="right") - 1
                dom = (idx >= 0) & (fr_p[np.maximum(idx, 0)] <= p_a)
                kms, kp = m_a[~dom], p_a[~dom]
            else:
                kms, kp = m_a, p_a
            if len(kms) == 0:
                continue
            expansions.append((t, kms, kp))
            am = np.concatenate([fr_m, kms])
            ap = np.concatenate([fr_p, kp])
            o = np.lexsort((ap, am))
            am, ap = am[o], ap[o]
            keep = _pareto_keep(am, ap)
            fr_m, fr_p = am[keep], ap[keep]

        if not expansions:
            continue
        tg = vp.targets[pos]
        j_cnt = len(tg)
        if j_cnt:
            mf = _price_row(g, vp, pos)
            m_step = vp.m_step[pos]
            t_step = vp.t_step[pos]
            # All of this source's cells in one block: columns are the
            # flattened per-cell candidates, contiguous per cell.
            t_cells = np.array([e[0] for e in expansions], dtype=np.float64)
            seg_len = np.array([len(e[1]) for e in expansions],
                               dtype=np.int64)
            seg_bnd = np.concatenate(
                [np.zeros(1, dtype=np.int64), np.cumsum(seg_len)]
            )
            f_tot = int(seg_bnd[-1])
            kms = np.concatenate([e[1] for e in expansions])
            kp = np.concatenate([e[2] for e in expansions])
            cell_of = np.repeat(
                np.arange(len(expansions), dtype=np.int64), seg_len
            )
            cols = np.arange(f_tot, dtype=np.int64)
            k_local = cols - seg_bnd[cell_of]
            # crossover: first k with kpeaks[k] <= kms[k] + m_fixed —
            # expansion stops one past it (see the scalar comment);
            # per (target, cell) via a segmented min over flagged columns
            pred = kp[None, :] <= (kms[None, :] + mf[:, None])
            first = np.minimum.reduceat(
                np.where(pred, cols[None, :], f_tot), seg_bnd[:-1], axis=1
            )
            found = first < seg_bnd[1:][None, :]
            end = np.where(
                found, first - seg_bnd[:-1][None, :] + 1, seg_len[None, :]
            )
            if prior is not None:
                # extension: cells already inside the old band only keep
                # pairs that can reach the new band
                old_cell = kp[seg_bnd[:-1]] <= skip_cap  # kp[0] per cell
                last_m = kms[seg_bnd[1:] - 1]            # kms[-1] per cell
                end = np.where(
                    old_cell[None, :]
                    & ((last_m[None, :] + mf[:, None]) <= skip_cap),
                    0, end,
                )
            if prior is None:
                states += int(end.sum())
            active = k_local[None, :] < end[:, cell_of]
            peak = np.maximum(kms[None, :] + mf[:, None], kp[None, :])
            okc = active & (peak <= budget_cap) & (peak > skip_cap)
            if prior is not None:
                states += int(okc.sum())  # new-band work only
            if okc.any():
                m2 = kms[None, :] + m_step[:, None]
                t2 = t_cells[None, :] + t_step[:, None]  # (J, cells)
                jj_nz, kk_nz = np.nonzero(okc)
                sel_cell = cell_of[kk_nz]
                pk_sel = peak[jj_nz, kk_nz]
                m2_sel = m2[jj_nz, kk_nz]
                t2_sel = t2[jj_nz, sel_cell]
                pt_sel = t_cells[sel_cell]
                bnds = np.searchsorted(jj_nz, np.arange(j_cnt + 1))
                for jj in range(j_cnt):
                    a, b = int(bnds[jj]), int(bnds[jj + 1])
                    if a == b:
                        continue
                    pend[int(tg[jj])].append((
                        t2_sel[a:b],
                        pk_sel[a:b],
                        m2_sel[a:b],
                        pos,
                        i,
                        pt_sel[a:b],
                    ))
        if prior_states + states > state_cap:
            raise SweepOverflow(
                f"budget sweep exceeded max_states={max_states} "
                f"({prior_states + states} transitions; family of {n_fam})"
            )

    return Sweep(
        objective=objective,
        n=g.n,
        family_masks=[info.mask for info in vp.infos],
        cells=cells,
        empty_id=vp.empty_id,
        full_id=vp.full_id,
        states_visited=prior_states + states,
        cap=cap,
    )


def sweep(g: Graph, family: Sequence[NodeSet],
          objective: str = "time_centric",
          max_states: Optional[int] = None,
          cap: Optional[float] = None,
          prior: Optional[Sweep] = None,
          strategies: Optional[StrategyConfig] = None) -> Sweep:
    """One budget-free DP pass carrying ``(t, m, peak)`` frontiers.

    Identical transition structure to :func:`solve` (liveness functional —
    the cached-surface contract is versioned by :data:`MEMORY_FUNCTIONAL`),
    with 𝓜⁽ⁱ⁾ folded into each chain's running ``peak`` instead of
    compared against a budget.  The source-side Pareto pruning mirrors :func:`_pareto` /
    :func:`_pareto_mc` with the peak coordinate added, so for every budget
    the set of expanded transitions is a superset of the per-budget DP's —
    and the per-cell ``(m, pos)`` tie-break makes ``extract`` land on the
    same plan the per-budget DP would have returned.

    Bit-identity holds in *float* arithmetic, not just on paper: every
    expression a candidate carries — the left-folded cache mass
    ``m + m_step`` and the peak ``max(peak, m + m_fixed)`` — is written
    identically to :func:`solve`'s, so ``extract(B)`` compares B against
    the very same float values the per-budget DP filters on.  (No
    re-associated shortcuts here: an ulp of drift in a peak moves a
    feasibility threshold and silently changes which plan a budget maps
    to.)

    ``max_states`` caps the transition work; a surface wider than the cap
    raises :class:`SweepOverflow` (deterministically for a given problem)
    so callers can fall back to per-budget solves.

    ``cap`` bounds the swept budget range: transitions whose peak exceeds
    ``cap`` are dropped — exactly the per-budget DP's ``𝓜⁽ⁱ⁾ > B`` filter
    at ``B = cap`` — so the sweep costs roughly one ``solve`` at the
    *largest* budget of interest times the number of regimes below it,
    instead of the full surface.  ``extract(B)`` stays bit-identical for
    every ``B ≤ cap`` and raises beyond it.

    ``prior`` (normally via :meth:`Sweep.extend`) seeds the pass with an
    existing capped sweep over the *same* graph/family/objective: only
    candidates with peak in ``(prior.cap, cap]`` are inserted, and
    transition pairs that cannot reach that band are skipped, so growing a
    cap costs the new band, not a rebuild.  ``states_visited`` then counts
    the prior's work plus this pass's *new* expansion work only.
    """
    if objective not in ("time_centric", "memory_centric", "wallclock"):
        raise ValueError(f"unknown objective {objective!r}")
    if strategies is not None and strategies.extended:
        if prior is not None:
            raise ValueError(
                "strategy sweeps do not support lazy extension from a "
                "prior surface; rebuild with the larger cap"
            )
        return _sweep_strat(g, family, objective, max_states, cap, strategies)
    if not scalar_only():
        return _sweep_vec(g, family, objective, max_states, cap, prior)
    # "wallclock" shares the time-centric transition structure bit-for-bit
    # (the surface is objective-agnostic; only extraction ranks by replay).
    tc = objective != "memory_centric"

    infos = _prepare(g, family)
    order = sorted(range(len(infos)), key=lambda i: infos[i].size)
    pos_of = [0] * len(order)
    for p, i in enumerate(order):
        pos_of[i] = p
    full_mask = (1 << g.n) - 1

    empty_id = None
    full_id = None
    for i, info in enumerate(infos):
        if info.mask == 0:
            empty_id = i
        if info.mask == full_mask:
            full_id = i
    if empty_id is None or full_id is None:
        raise ValueError("family must contain ∅ and V")

    skip_cap = -INF  # candidates with peak ≤ skip_cap are already present
    prior_states = 0
    if prior is not None:
        if prior.objective != objective:
            raise ValueError(
                f"prior sweep objective {prior.objective!r} != {objective!r}"
            )
        if prior.family_masks != [info.mask for info in infos]:
            raise ValueError("prior sweep was built over a different family")
        if prior.cap is None or (cap is not None and cap <= prior.cap):
            return prior  # nothing to extend
        skip_cap = prior.cap
        prior_states = prior.states_visited
        cells = [
            {t: cell.copy() for t, cell in cdict.items()}
            for cdict in prior.cells
        ]
    else:
        cells = [{} for _ in infos]

    states = 0
    state_cap = max_states if max_states is not None else INF
    budget_cap = cap if cap is not None else INF
    n_fam = len(order)
    sizes = [infos[i].size for i in order]

    if prior is None:
        seed = _Cell()
        seed.insert(0.0, 0.0, -1, -1, 0.0)
        cells[empty_id][0.0] = seed

    for pos, i in enumerate(order):
        info_L = infos[i]
        cdict = cells[i]
        if not cdict:
            continue
        # Source-side pruning, the sweep analogue of _pareto/_pareto_mc: a
        # candidate is skipped when a strictly-better-t cell (smaller t for
        # TC, larger for MC) holds one with m' ≤ m and peak' ≤ peak — for
        # every budget where the skipped candidate is its cell's projection
        # winner, the per-budget DP prunes the cell too.  fr_m ascending /
        # fr_p strictly descending is the running (m, peak) frontier.
        fr_m: List[float] = []
        fr_p: List[float] = []
        # per surviving cell: (t, ms ascending, peaks descending)
        expansions: List[Tuple[float, List[float], List[float]]] = []
        for t in sorted(cdict, reverse=not tc):
            cell = cdict[t]
            kms: List[float] = []
            kpeaks: List[float] = []
            for k in range(len(cell.peaks) - 1, -1, -1):  # m asc / peak desc
                m, peak = cell.ms[k], cell.peaks[k]
                idx = bisect_right(fr_m, m) - 1
                if idx >= 0 and fr_p[idx] <= peak:
                    continue
                kms.append(m)
                kpeaks.append(peak)
            if kms:
                expansions.append((t, kms, kpeaks))
            for m, peak in zip(kms, kpeaks):
                idx = bisect_right(fr_m, m) - 1
                if idx >= 0 and fr_p[idx] <= peak:
                    continue
                lo = bisect_left(fr_m, m)
                hi = lo
                while hi < len(fr_m) and fr_p[hi] >= peak:
                    hi += 1
                del fr_m[lo:hi], fr_p[lo:hi]
                fr_m.insert(lo, m)
                fr_p.insert(lo, peak)

        if not expansions:
            continue
        mask_L = info_L.mask
        src_pos = pos_of[i]
        start = bisect_right(sizes, info_L.size)
        for jpos in range(start, n_fam):
            j = order[jpos]
            info_Lp = infos[j]
            if mask_L & ~info_Lp.mask:
                continue  # L ⊄ L'
            Vp_mask = info_Lp.mask & ~mask_L
            inter = Vp_mask & info_Lp.cache_mask
            t_step = (info_Lp.T - info_L.T) - _mask_T(g, inter)
            m_step = _mask_M(g, info_Lp.cache_mask & ~mask_L)
            m_fixed = transition_excess(
                g, mask_L, info_Lp.mask, info_Lp.boundary_mask
            )
            target = cells[j]
            for t, kms, kpeaks in expansions:
                if kpeaks[0] <= skip_cap and kms[-1] + m_fixed <= skip_cap:
                    continue  # extension: every candidate is in the old band
                t2 = t + t_step
                # cells materialize only when a candidate survives the cap
                # filters below — a husk cell would make the encoded sweep
                # undecodable (decode_sweep rejects empty cells), silently
                # defeating the cache for capped surfaces
                cell2 = target.get(t2)
                # Once this transition's own 𝓜⁽ⁱ⁾ = m + m_fixed reaches a
                # candidate's carried peak, peak₂ = m + m_fixed grows with m
                # exactly as m₂ does — every candidate past the first such
                # one arrives strictly dominated (same source position), so
                # expansion stops one past the crossover.  kpeaks descends
                # and m + m_fixed ascends, so the predicate flips once.
                lo, hi = 0, len(kms)
                while lo < hi:
                    mid = (lo + hi) // 2
                    if kpeaks[mid] <= kms[mid] + m_fixed:
                        hi = mid
                    else:
                        lo = mid + 1
                end = lo + 1 if lo < len(kms) else lo
                if prior is None:
                    states += end
                # extension pass: counted per new-band candidate below —
                # each unit of count is a candidate that can actually grow
                # the surface, so cumulative extensions stay bounded by
                # max_states (a lower bound on a fresh build's count, i.e.
                # extensions never overflow where a fresh build would fit)
                # inlined _Cell.insert — this is the sweep's hot loop
                if cell2 is not None:
                    peaks2 = cell2.peaks
                    ms2 = cell2.ms
                    poss2 = cell2.poss
                    pids2 = cell2.parent_ids
                    pts2 = cell2.parent_ts
                for k in range(end):
                    m = kms[k]
                    peak = kpeaks[k]
                    Mi = m + m_fixed  # 𝓜⁽ⁱ⁾, same floats as solve()
                    if Mi > peak:
                        peak = Mi
                    if peak > budget_cap:
                        continue  # beyond the swept budget range
                    if peak <= skip_cap:
                        continue  # already materialized by the prior sweep
                    if prior is not None:
                        states += 1  # extension: count new-band work only
                    if cell2 is None:
                        cell2 = target[t2] = _Cell()
                        peaks2 = cell2.peaks
                        ms2 = cell2.ms
                        poss2 = cell2.poss
                        pids2 = cell2.parent_ids
                        pts2 = cell2.parent_ts
                    m2 = m + m_step
                    ci = bisect_left(peaks2, peak)
                    if ci > 0:
                        pm = ms2[ci - 1]
                        if pm < m2 or (pm == m2 and poss2[ci - 1] <= src_pos):
                            continue
                    cj = ci
                    cn = len(peaks2)
                    while cj < cn:
                        jm = ms2[cj]
                        if jm > m2 or (jm == m2 and poss2[cj] >= src_pos):
                            cj += 1
                        else:
                            break
                    if cj < cn and peaks2[cj] == peak:
                        continue
                    del peaks2[ci:cj], ms2[ci:cj], poss2[ci:cj]
                    del pids2[ci:cj], pts2[ci:cj]
                    peaks2.insert(ci, peak)
                    ms2.insert(ci, m2)
                    poss2.insert(ci, src_pos)
                    pids2.insert(ci, i)
                    pts2.insert(ci, t)
        # the cap bounds the *cumulative* surface (prior + extension): a
        # runaway sequence of lazy extensions trips it just as unbounded
        # fresh builds would (extension counts only surface-growing work,
        # so it is the permissive side of the fresh-build count)
        if prior_states + states > state_cap:
            raise SweepOverflow(
                f"budget sweep exceeded max_states={max_states} "
                f"({prior_states + states} transitions; family of {n_fam})"
            )

    return Sweep(
        objective=objective,
        n=g.n,
        family_masks=[info.mask for info in infos],
        cells=cells,
        empty_id=empty_id,
        full_id=full_id,
        states_visited=prior_states + states,
        cap=cap,
    )


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def exact_dp(
    g: Graph, budget: float, objective: str = "time_centric",
    limit: Optional[int] = None,
) -> DPResult:
    """§4.2 — DP over the full lattice 𝓛_G.

    ``limit`` caps the family enumeration; defaults to
    ``lower_sets.DEFAULT_LOWER_SET_LIMIT`` (the single source of truth
    shared with ``Planner`` and ``all_lower_sets``).
    """
    from .lower_sets import DEFAULT_LOWER_SET_LIMIT

    if limit is None:
        limit = DEFAULT_LOWER_SET_LIMIT
    return solve(g, budget, all_lower_sets(g, limit=limit), objective)


def approx_dp(g: Graph, budget: float, objective: str = "time_centric") -> DPResult:
    """§4.3 — DP over 𝓛_G^Pruned (keys = principal lower sets L^v)."""
    return solve(g, budget, pruned_lower_sets(g), objective)


# ---------------------------------------------------------------------------
# Strategy evaluation (shared with DFS / Chen / tests)
# ---------------------------------------------------------------------------


def cached_sets(g: Graph, sequence: Sequence[NodeSet]) -> List[NodeSet]:
    """U_i = ∪_{j≤i} (∂(L_j) ∪ (pins ∩ L_j)) for each prefix.

    With no ``must_store`` pins this is the paper's U_i exactly; pinned
    nodes (effect analysis) additionally join the cache at their own
    segment and are never recomputed.
    """
    pins = g.store_pins
    u: set = set()
    out = []
    for L in sequence:
        u |= g.boundary(L) | (pins & L)
        out.append(frozenset(u))
    return out


def overhead(g: Graph, sequence: Sequence[NodeSet]) -> float:
    """Eq. (1): T(V \\ U_k)."""
    U_k = cached_sets(g, sequence)[-1]
    allv = frozenset(range(g.n))
    return g.T(allv - U_k)


def peak_memory(g: Graph, sequence: Sequence[NodeSet]) -> float:
    """Eq. (2): max_i 𝓜⁽ⁱ⁾ (the paper's original segment-footprint model,
    kept for the Appendix C ablation — the DP itself prices transitions
    with :func:`peak_memory_live`)."""
    _check_functional("eq2", g)
    Us = cached_sets(g, sequence)
    peak = 0.0
    prev: NodeSet = EMPTY
    for i, L in enumerate(sequence):
        Vi = L - prev
        U_prev = Us[i - 1] if i > 0 else EMPTY
        dplus_out = g.delta_plus(L) - L
        dmd_out = g.delta_minus(g.delta_plus(L)) - L
        Mi = g.M(U_prev) + 2.0 * g.M(Vi) + g.M(dplus_out) + g.M(dmd_out)
        peak = max(peak, Mi)
        prev = L
    return peak


def peak_memory_live(g: Graph, sequence: Sequence[NodeSet],
                     assignment: Optional[Dict[int, str]] = None) -> float:
    """Liveness-tight analytic peak: max_i (M(U_{i-1}) + transition excess).

    The strategy evaluator of the DP's memory functional
    (``liveness.transition_excess`` per transition, cache mass left-folded
    exactly as the DP's ``m + m_step``) — for any valid schedule it equals
    ``liveness.simulate(g, sequence, liveness=True).peak_memory`` (the
    property test in tests/test_liveness.py pins this), and it is the value
    every feasible ``DPResult.peak_memory`` reports, so
    ``result.peak_memory ≤ budget`` holds exactly.

    ``assignment`` prices a strategy-annotated plan: the carried cache
    mass folds each node's *device* bytes (offloaded → 0, quantized →
    int8+scales; ``strategies.device_bytes``) while the per-transition
    excess stays at full bytes — a node lives on device at full precision
    during its own forward window (see ``core.strategies``).  The fold is
    float-identical to the joint DP's ``m + option.m_add``.
    """
    pins = g.store_pins_mask
    prev_mask = 0
    m = 0.0
    peak = 0.0
    w = device_bytes(g, assignment) if assignment else None
    for L in sequence:
        mask_Lp = to_mask(L)
        bd_mask = to_mask(g.boundary(L))
        # The excess is priced against the *true* boundary (gradient flow is
        # graph-structural); pins only add cache mass.
        Mi = m + transition_excess(g, prev_mask, mask_Lp, bd_mask)
        if Mi > peak:
            peak = Mi
        new_mask = (bd_mask | (pins & mask_Lp)) & ~prev_mask
        m = m + (_mask_M(g, new_mask) if w is None else _mask_M_w(w, new_mask))
        prev_mask = mask_Lp
    return peak


def quantize_times(g: Graph, levels: int = 64) -> Graph:
    """Rescale T_v to small positive integers so the DP's t-axis stays compact.

    Beyond-paper utility for FLOP-derived costs: T_v → max(1,
    round(levels · T_v / max_v T_v)).  The paper's {1, 10} costs pass through
    unchanged when levels ≥ 10·max/max.

    Degenerate graphs pass through unchanged: an empty graph has no times to
    rescale, and a graph whose times are all ≤ 0 (e.g. a pure-view subgraph
    assembled outside the ``Graph`` constructor's validation) has no usable
    scale — rescaling would divide by zero.
    """
    from .graph import Node

    if g.n == 0:
        return g
    tmax = max(g.time_v)
    if tmax <= 0:
        return g
    nodes = [
        Node(
            nd.idx,
            nd.name,
            float(max(1, round(levels * nd.time / tmax))),
            nd.memory,
            nd.kind,
            must_store=nd.must_store,
        )
        for nd in g.nodes
    ]
    return Graph(nodes, g.edges, cost_source=getattr(g, "cost_source", ""))
