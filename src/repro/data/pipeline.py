"""Deterministic synthetic data pipeline with per-host sharding.

Production shape: each host feeds only its slice of the global batch; the
global batch is (re)constructible from (seed, step) alone, so a restarted or
re-meshed job resumes mid-epoch with zero coordination — the data half of
the fault-tolerance story (train.loop restores the step counter from the
checkpoint; the pipeline is pure state-free indexing after that).

The token stream is a mixture of Zipf-distributed "unigram" tokens and
repeated n-gram motifs so the LM loss actually decreases — enough signal for
the end-to-end examples without external corpora (the container is offline).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    num_motifs: int = 64
    motif_prob: float = 0.5


class SyntheticLM:
    """Deterministic (seed, step) → batch generator."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError(
                f"global_batch {cfg.global_batch} not divisible by "
                f"num_hosts {cfg.num_hosts}"
            )
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.num_hosts
        root = np.random.default_rng(cfg.seed)
        # motif bank (shared across hosts — derived from the seed only)
        self.motifs = root.integers(
            0, cfg.vocab_size, size=(cfg.num_motifs, cfg.motif_len), dtype=np.int32
        )
        # Zipf weights over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self.probs = w / w.sum()

    def _rng_for(self, step: int) -> np.random.Generator:
        c = self.cfg
        return np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id])
        )

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """The host-local slice of global batch ``step``: tokens + labels."""
        c = self.cfg
        rng = self._rng_for(step)
        B, S = self.host_batch, c.seq_len
        toks = rng.choice(c.vocab_size, size=(B, S + 1), p=self.probs).astype(
            np.int32
        )
        # overwrite random spans with motifs (learnable structure)
        n_spans = int(c.motif_prob * (S // c.motif_len))
        if n_spans:
            for b in range(B):
                starts = rng.integers(0, S + 1 - c.motif_len, size=n_spans)
                ids = rng.integers(0, c.num_motifs, size=n_spans)
                for s0, mid in zip(starts, ids):
                    toks[b, s0 : s0 + c.motif_len] = self.motifs[mid]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def global_batch_for_test(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Assemble the full global batch by concatenating every host's slice —
    used by tests to assert host-sharding is a partition of the global batch."""
    parts = []
    for h in range(cfg.num_hosts):
        ds = SyntheticLM(dataclasses.replace(cfg, host_id=h))
        parts.append(ds.batch(step))
    return {
        k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]
    }
