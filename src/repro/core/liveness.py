"""Liveness analysis [Appel & Palsberg] + an event-level execution simulator
for the canonical strategy (§3, §4.4, Appendix C).

The paper scores strategies three ways:

* the analytic model, eq. (2)            → ``core.dp.peak_memory``
* measured execution *with liveness analysis*, where every buffer is freed at
  its last use                           → ``simulate(..., liveness=True)``
* measured execution *without* liveness (Appendix C ablation), where buffers
  are freed only at the canonical strategy's own segment-boundary rules
                                          → ``simulate(..., liveness=False)``

Since PR 5 the liveness-analyzed execution also has an exact *analytic*
form: :func:`transition_excess` (bottom of this module) decomposes the
liveness=True simulation per DP transition, and ``core.dp`` prices 𝓜⁽ⁱ⁾
with it — so the DP's budgets are last-use-liveness execution peaks, not
eq. 2's looser footprint.

The simulator expands the canonical strategy into a linear event list:

  forward  : for each segment i, compute f(v) for v ∈ V_i in topo order;
             at segment end, discard f(V_i \\ ∂(L_i)) (canonical rule).
  backward : for each segment i = k…1:
               recompute f(v) for uncached v ∈ V_i from the live caches;
               for w ∈ V_i in reverse topo order, run VJP(w): reads
               {f(p) : p ∈ pred(w)} ∪ {f(w), g(w)}, writes {g(p)};
             at segment end discard f/g buffers of V_i, keeping gradient
             contributions flowing to earlier segments
             (the δ⁺(L_{i-1}) ∩ V_i backward-cache rule of §3).

Because a discarded value is *recomputed* later, the same logical buffer has
several **versions** (live intervals).  The canonical strategy's explicit
discards delimit versions; liveness analysis can only shorten a version (free
at its last use inside the interval), never extend it.

Buffer sizes: both f(v) and g(v) occupy M_v (a gradient has the shape of its
value).  Parameters and inputs are excluded, as in §2.
"""

from __future__ import annotations

import dataclasses
import weakref
from collections import defaultdict
from typing import Dict, List, Sequence, Set, Tuple

from .graph import EMPTY, Graph, NodeSet, mask_iter

Buffer = Tuple[str, int]  # ("f"|"g", node)


@dataclasses.dataclass
class SimResult:
    peak_memory: float
    total_compute: float  # forward + recompute T (backward T excluded, §2)
    recompute_overhead: float  # T of recomputed nodes only
    num_events: int


@dataclasses.dataclass
class _Event:
    reads: List[Buffer]
    writes: List[Buffer]
    cost: float  # T_v for fwd/recompute events, 0 for VJP events (§2)
    frees_after: List[Buffer]  # explicit canonical-strategy discards


def _topo_within(g: Graph, nodes: NodeSet) -> List[int]:
    order = g.topological_order()
    return [v for v in order if v in nodes]


def build_events(g: Graph, sequence: Sequence[NodeSet]) -> List[_Event]:
    """Expand a lower-set sequence into the canonical-strategy event list."""
    g.check_increasing_sequence(sequence)
    events: List[_Event] = []
    k = len(sequence)
    prev: NodeSet = EMPTY
    segs: List[NodeSet] = []
    bounds: List[NodeSet] = []
    pins = g.store_pins
    for L in sequence:
        segs.append(L - prev)
        # effective cached set: the paper's boundary plus any must_store pins
        # (effect analysis) — pinned values are kept from their forward
        # computation and never recomputed.
        bounds.append(g.boundary(L) | (pins & L))
        prev = L
    # U_i = ∪_{j≤i} ∂(L_j)  (plus pins, when present)
    Us: List[NodeSet] = []
    acc: Set[int] = set()
    for b in bounds:
        acc |= b
        Us.append(frozenset(acc))
    U_k = Us[-1]

    # ---------------- forward ----------------
    for i, Vi in enumerate(segs):
        for v in _topo_within(g, Vi):
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # canonical rule: cache U_k ∩ V_i (its boundary nodes), discard rest
        drop = Vi - U_k
        if drop and events:
            events[-1].frees_after.extend(("f", v) for v in drop)

    # ---------------- backward ----------------
    for i in range(k - 1, -1, -1):
        Vi = segs[i]
        # recompute uncached forward values of V_i
        for v in _topo_within(g, Vi):
            if v in U_k:
                continue  # cached since the forward pass
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # VJP sweep in reverse topological order
        for w in reversed(_topo_within(g, Vi)):
            reads: List[Buffer] = [("f", p) for p in g.pred[w]]
            reads.append(("f", w))
            if g.succ[w]:
                reads.append(("g", w))
            events.append(
                _Event(
                    reads=reads,
                    writes=[("g", p) for p in g.pred[w]] or [("g", w)],
                    cost=0.0,
                    frees_after=[],
                )
            )
        # segment-end frees: drop f/g of V_i; gradient contributions to
        # earlier segments are ("g", p) with p ∉ V_i and thus survive.
        frees = [("f", v) for v in Vi] + [("g", v) for v in Vi]
        if events:
            events[-1].frees_after.extend(frees)
    return events


def build_vanilla_events(g: Graph) -> List[_Event]:
    """No-recomputation baseline: cache every forward value, then backprop."""
    events: List[_Event] = []
    order = g.topological_order()
    for v in order:
        events.append(
            _Event([("f", p) for p in g.pred[v]], [("f", v)], g.time_v[v], [])
        )
    for w in reversed(order):
        reads: List[Buffer] = [("f", p) for p in g.pred[w]] + [("f", w)]
        if g.succ[w]:
            reads.append(("g", w))
        events.append(
            _Event(reads, [("g", p) for p in g.pred[w]] or [("g", w)], 0.0, [])
        )
    if events:
        events[-1].frees_after = [("f", v) for v in order] + [
            ("g", v) for v in order
        ]
    return events


def simulate_events(
    g: Graph, events: List[_Event], liveness: bool
) -> SimResult:
    """Peak live bytes over an event list, with versioned buffer intervals.

    A buffer *version* opens at its first write (or lazy-read for gradient
    seeds) and closes at the strategy's explicit discard.  liveness=True
    shrinks each version to end at its last use instead.
    """

    def size(buf: Buffer) -> float:
        return g.mem_v[buf[1]]

    # Pass 1: version intervals.
    open_ver: Dict[Buffer, int] = {}
    nver: Dict[Buffer, int] = defaultdict(int)
    start: Dict[Tuple[Buffer, int], int] = {}
    last_touch: Dict[Tuple[Buffer, int], int] = {}
    end: Dict[Tuple[Buffer, int], int] = {}

    def touch(b: Buffer, idx: int) -> None:
        if b not in open_ver:
            v = nver[b]
            nver[b] += 1
            open_ver[b] = v
            start[(b, v)] = idx
        last_touch[(b, open_ver[b])] = idx

    n_events = len(events)
    for idx, ev in enumerate(events):
        for b in ev.reads:
            touch(b, idx)
        for b in ev.writes:
            touch(b, idx)
        for b in ev.frees_after:
            if b in open_ver:
                end[(b, open_ver[b])] = idx
                del open_ver[b]
    for b, v in open_ver.items():
        end[(b, v)] = n_events - 1

    # Pass 2: sweep with a difference array.
    delta = [0.0] * (n_events + 1)
    for key, s_idx in start.items():
        e_idx = last_touch[key] if liveness else end[key]
        e_idx = min(e_idx, end.get(key, e_idx))
        delta[s_idx] += size(key[0])
        delta[e_idx + 1] -= size(key[0])
    peak = 0.0
    cur = 0.0
    for idx in range(n_events):
        cur += delta[idx]
        peak = max(peak, cur)

    total_T = sum(ev.cost for ev in events)
    return SimResult(
        peak_memory=peak,
        total_compute=total_T,
        recompute_overhead=total_T - g.total_time,
        num_events=n_events,
    )


def simulate(
    g: Graph, sequence: Sequence[NodeSet], liveness: bool = True
) -> SimResult:
    """Simulate the canonical strategy for a lower-set sequence."""
    return simulate_events(g, build_events(g, sequence), liveness)


# ---------------------------------------------------------------------------
# Analytic per-transition form of the liveness=True simulation.
#
# The event simulation above decomposes exactly along the strategy's
# transitions: while segment i's window runs (its forward pass, or its
# backward recompute + VJP sweep), the buffers alive from *outside* the
# window are precisely f(U_{i-1}) — every cached value of an earlier segment
# is still awaiting its own VJP — plus window-entry gradients determined by
# (L_{i-1}, L_i) alone.  So with last-use liveness,
#
#     simulated peak  =  max_i ( M(U_{i-1}) + excess(L_{i-1}, L_i) )
#
# where ``excess`` is a pure function of the transition pair — exactly the
# shape Algorithm 1's transition relation needs (eq. 2's
# ``𝓜⁽ⁱ⁾ = m + m_fixed`` with a tighter ``m_fixed``).  ``transition_excess``
# computes it in closed form, without building event lists:
#
# Within the backward window of V' = L' \ L (topo order u_1 … u_s, VJP
# events processed u_s … u_1), nothing dies during the recompute phase, and
# the first VJP event dominates it, so only the VJP events matter.  Each
# buffer contributes one interval on the t-axis (t = the index of VJP(u_t)):
#
#   f(u_i)            [i, s]   recomputed/cached value, read last by VJP(uᵢ)
#   g(u_i)            [i, s]   if u_i ∈ ∂(L')   (gradient arrived at entry)
#                     [i, max succ idx in V']   otherwise (first written by
#                                               the VJP of its latest succ)
#                     [i, i]   pred-less node with no succ in V' (self-seed)
#   g(p), p ∈ L       [1, s]   if p ∈ ∂(L')∩L  (arrived at entry, survives)
#                     [1, max succ idx in V']   if p ∈ δ⁻(V') ∩ L otherwise
#                                               (written here, flows onward)
#
# The forward window of the same transition holds only a subset of f(V')
# over the same baseline M(U_{i-1}) and is dominated by the backward
# window's first VJP event (which holds all of f(V') plus gradients), so the
# backward window alone decides the transition's peak.
# ---------------------------------------------------------------------------


# Per-graph transition memo, weakly keyed: entries die with their graph, so
# long-lived processes (planner services, sweeps over many models) don't
# accumulate excess tables for graphs nothing else references.
_EXCESS_MEMO: "weakref.WeakKeyDictionary[Graph, Dict[Tuple[int, int], float]]" = (
    weakref.WeakKeyDictionary()
)


def _topo_rank(g: Graph) -> List[int]:
    rank = getattr(g, "_topo_rank", None)
    if rank is None:
        rank = [0] * g.n
        for r, v in enumerate(g.topological_order()):
            rank[v] = r
        g._topo_rank = rank
    return rank


def transition_excess(g: Graph, mask_L: int, mask_Lp: int, bd_mask: int) -> float:
    """Liveness-tight ``m_fixed`` of one DP transition ``L → L'`` (bitmasks).

    The peak live bytes of the transition's execution window *beyond* the
    carried cache mass ``M(U_{i-1})``, with every buffer freed at its last
    use (``simulate(..., liveness=True)`` factored per transition — see the
    derivation above).  ``bd_mask`` must be the bitmask of ``∂(L')``.

    Always ≤ eq. 2's ``2·M(V') + M(δ⁺(L')\\L') + M(δ⁻(δ⁺(L'))\\L')`` on
    chain-like transitions and usually far below it on multi-node segments;
    on graphs whose gradients flow across many segments it can exceed
    eq. 2's (under-counted) charge — eq. 2 ignores gradient buffers held
    for earlier segments, this functional does not.

    Results are memoized per graph (graphs are immutable) in a weakly-keyed
    table, so the DP entry points (``solve`` / ``feasible`` / ``sweep`` /
    ``min_feasible_budget_exact``) all see the *same float* for a pair —
    the foundation of their bit-identity contract — while the memo itself
    never outlives its graph.
    """
    memo = _EXCESS_MEMO.get(g)
    if memo is None:
        memo = _EXCESS_MEMO[g] = {}
    key = (mask_L, mask_Lp)
    hit = memo.get(key)
    if hit is not None:
        return hit

    rank = _topo_rank(g)
    vp_mask = mask_Lp & ~mask_L
    nodes = sorted(mask_iter(vp_mask), key=rank.__getitem__)  # u_1 … u_s
    s = len(nodes)
    idx: Dict[int, int] = {u: i for i, u in enumerate(nodes, 1)}
    mem = g.mem_v
    pred = g.pred
    succ = g.succ

    # interval [lo, hi] → delta[lo] += M, delta[hi+1] -= M
    delta = [0.0] * (s + 2)
    maxq_L: Dict[int, int] = {}  # p ∈ δ⁻(V') ∩ L \ ∂(L') → max succ idx
    for i, u in enumerate(nodes, 1):
        mu = mem[u]
        # f(u): alive from before the VJP sweep until VJP(u) = e_i
        delta[i] += mu
        delta[s + 1] -= mu
        # g(u)
        if (bd_mask >> u) & 1:
            hi = s  # gradient arrived from later segments at window entry
        else:
            hi = 0
            for w in succ[u]:
                j = idx.get(w)  # non-boundary ⇒ every successor is in V'
                if j is not None and j > hi:
                    hi = j
            if hi == 0 and not pred[u]:
                hi = i  # VJP of a pred-less node writes g(u) itself
        if hi:
            delta[i] += mu
            delta[hi + 1] -= mu
        # gradients this window writes for earlier segments
        for p in pred[u]:
            if (mask_L >> p) & 1 and not ((bd_mask >> p) & 1):
                maxq_L[p] = i  # i ascends, so the last write wins
    for p, q in maxq_L.items():
        delta[1] += mem[p]
        delta[q + 1] -= mem[p]
    for p in mask_iter(bd_mask & mask_L):
        # entry gradients of earlier-segment boundary nodes: live all window
        delta[1] += mem[p]
        delta[s + 1] -= mem[p]

    peak = 0.0
    cur = 0.0
    for t in range(1, s + 1):
        cur += delta[t]
        if cur > peak:
            peak = cur
    memo[key] = peak
    return peak


def vanilla_peak(g: Graph, liveness: bool = True) -> float:
    """Peak of the no-recomputation baseline (cache everything)."""
    return simulate_events(g, build_vanilla_events(g), liveness).peak_memory
