"""Chen et al. [arXiv:1604.06174] √n-segmentation baseline, with the paper's
Appendix-B configuration.

Chen's algorithm divides an n-layer network into ~√n segments, caches segment
boundaries during the forward pass, and recomputes each segment from its
cached input during backprop.  The paper (Appendix B) fills in the two
under-specified pieces:

* topological order: DFS on the computation graph;
* candidate stage-splitting points C: nodes whose removal disconnects the
  graph — the *articulation points* of (the undirected version of) G.

A split at articulation point c induces the prefix lower set
``ancestors_of(c)`` (everything at or before c), so a Chen segmentation is a
special canonical strategy and can be scored with the same eq. (1)/(2) +
liveness machinery — exactly how the paper compares against it.
"""

from __future__ import annotations

import math
from typing import List, Optional, Set, Tuple

from .dp import DPResult, overhead, peak_memory_live
from .graph import Graph, NodeSet


def articulation_points(g: Graph) -> List[int]:
    """Articulation points of the undirected version of G (Tarjan, iterative)."""
    n = g.n
    adj: List[Set[int]] = [set() for _ in range(n)]
    for v, w in g.edges:
        adj[v].add(w)
        adj[w].add(v)

    visited = [False] * n
    disc = [0] * n
    low = [0] * n
    parent = [-1] * n
    ap = [False] * n
    timer = 0

    for root in range(n):
        if visited[root]:
            continue
        stack: List[Tuple[int, iter]] = [(root, iter(adj[root]))]
        visited[root] = True
        disc[root] = low[root] = timer = timer + 1
        root_children = 0
        while stack:
            v, it = stack[-1]
            advanced = False
            for w in it:
                if not visited[w]:
                    if v == root:
                        root_children += 1
                    visited[w] = True
                    timer += 1
                    disc[w] = low[w] = timer
                    parent[w] = v
                    stack.append((w, iter(adj[w])))
                    advanced = True
                    break
                elif w != parent[v]:
                    low[v] = min(low[v], disc[w])
            if not advanced:
                stack.pop()
                if stack:
                    u = stack[-1][0]
                    low[u] = min(low[u], low[v])
                    if u != root and low[v] >= disc[u]:
                        ap[u] = True
        if root_children > 1:
            ap[root] = True
    return [v for v in range(n) if ap[v]]


def candidate_split_points(g: Graph) -> List[int]:
    """Appendix B's C: articulation points, in topological order.

    A valid split point must additionally induce a *prefix*: every node is
    either an ancestor of c or a descendant (otherwise cutting at c leaves
    parallel work straddling the cut).  We keep points where
    ancestors ∪ descendants = V, which is what "removal disconnects the graph
    into a before and an after" means for a DAG stage split.
    """
    aps = set(articulation_points(g))
    full = frozenset(range(g.n))
    order = g.topological_order()
    out = []
    for v in order:
        if v not in aps:
            continue
        anc = g.ancestors_of(v)
        desc = g.reachable_from(v)
        if anc | desc == full:
            out.append(v)
    return out


def chen_sqrt_n(
    g: Graph, budget: Optional[float] = None, num_segments: Optional[int] = None
) -> DPResult:
    """Chen's √n segmentation over candidate split points.

    With no budget given, targets k = ⌈√(#C+1)⌉ segments of roughly equal
    T-cost (the √n rule).  With a budget, greedily packs candidates until
    the analytic peak of the running segmentation would exceed it (Chen's
    Algorithm 3 "Memory Planning with Budget" adapted to the paper's cost
    model), then verifies feasibility.  Peaks and feasibility use the same
    liveness-tight functional as the DP (``dp.peak_memory_live``), so a
    Chen segmentation and a DP plan scored at the same budget are
    comparable like for like.
    """
    cands = candidate_split_points(g)
    full = frozenset(range(g.n))

    if not cands:
        # Indivisible graph (paper §2: e.g. skip connection from every layer
        # to the output) — Chen degenerates to the vanilla single segment.
        seq = [full]
        return DPResult(
            sequence=seq,
            overhead=overhead(g, seq),
            peak_memory=peak_memory_live(g, seq),
            feasible=(budget is None or peak_memory_live(g, seq) <= budget),
        )

    prefixes = [g.ancestors_of(c) for c in cands]

    if budget is None:
        k = num_segments or max(1, int(math.isqrt(len(cands) + 1)))
        # pick k-1 split points equally spaced in cumulative T
        totT = g.total_time
        targets = [totT * i / k for i in range(1, k)]
        chosen: List[NodeSet] = []
        ti = 0
        for L in prefixes:
            if ti >= len(targets):
                break
            if g.T(L) >= targets[ti]:
                if not chosen or len(L) > len(chosen[-1]):
                    chosen.append(L)
                ti += 1
        seq = chosen + [full]
        seq = _dedup(seq)
        return DPResult(
            sequence=seq,
            overhead=overhead(g, seq),
            peak_memory=peak_memory_live(g, seq),
            feasible=True,
        )

    # Budgeted variant: greedy packing — extend current segment until adding
    # the next candidate would push the analytic peak for the segment over B.
    seq: List[NodeSet] = []
    for L in prefixes + [full]:
        if seq and len(L) <= len(seq[-1]):
            continue
        trial = _dedup(seq + ([full] if L != full else [L]))
        if L != full:
            trial = _dedup(seq + [L, full])
        if peak_memory_live(g, trial) <= budget:
            # keep the coarser segmentation (skip this cut) if still feasible
            continue
        if L != full:
            seq.append(L)
    seq = _dedup(seq + [full])
    pk = peak_memory_live(g, seq)
    return DPResult(
        sequence=seq,
        overhead=overhead(g, seq),
        peak_memory=pk,
        feasible=pk <= budget,
    )


def _dedup(seq: List[NodeSet]) -> List[NodeSet]:
    out: List[NodeSet] = []
    for L in seq:
        if not out or len(L) > len(out[-1]):
            out.append(L)
    return out
