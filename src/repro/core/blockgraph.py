"""BlockGraph — the framework's model-definition carrier.

A model is a DAG of named *blocks* (layer-granularity nodes), each a pure
``apply(params, *inputs) -> output`` with an ``init(rng, *in_shapes)``.
From a BlockGraph the framework derives, without running the model:

* the paper's ``core.Graph`` (M_v from traced output avals, T_v from the
  paper's 10/1 cost model or analytic FLOPs) — the planner's input;
* a vanilla executor (topological sweep);
* a **planned executor**: segments of the DP's lower-set sequence executed
  under ``jax.checkpoint``, so XLA caches exactly the boundary values
  ∂(L_i) (= the segment interfaces) and recomputes segment interiors during
  the backward pass — the canonical strategy (§3) as a jit/pjit-composable
  transformation.

Layer granularity matches how the paper treats "nodes" in its benchmarks
(#V of order 50–600), keeps #𝓛 tractable, and is the right granularity on
TPU, where XLA already fuses within a block.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, Node
from .jaxpr_graph import aval_bytes, trace
from .schedule import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class Block:
    """One node of the model DAG.

    apply(params, *inputs) -> single output array (or pytree).
    init(rng, *input_shapes) -> params pytree (possibly empty {}).
    inputs: names of producer blocks or graph inputs.
    heavy: paper cost model — True → T_v = 10, else 1.
    flops: optional analytic FLOPs for the "flops" cost model.
    out_sharding: optional sharding of the block's output — a
      ``PartitionSpec``, or a tuple of logical axis names resolved under
      the active ``parallel.sharding`` rules.  With a mesh, ``to_graph``
      budgets this block at per-device bytes and the checkpoint lowerings
      pin the output with ``with_sharding_constraint`` (same semantics as
      the traced carrier's propagated shardings).
    """

    name: str
    apply: Callable[..., Any]
    inputs: Tuple[str, ...]
    init: Optional[Callable[..., Any]] = None
    heavy: bool = True
    flops: Optional[float] = None
    out_sharding: Optional[Any] = None


def block_spec(block: Block, shape: Tuple[int, ...],
               axis_sizes: Dict[str, int]) -> Any:
    """A Block's ``out_sharding`` annotation → concrete PartitionSpec."""
    from jax.sharding import PartitionSpec

    from repro.parallel.sharding import resolve_spec

    sh = block.out_sharding
    if sh is None:
        return PartitionSpec()
    if isinstance(sh, PartitionSpec):
        return sh
    return resolve_spec(tuple(sh), axis_sizes, shape=shape)


class BlockGraph:
    def __init__(
        self,
        blocks: Sequence[Block],
        graph_inputs: Sequence[str],
        outputs: Sequence[str],
    ):
        self.blocks: List[Block] = list(blocks)
        self.graph_inputs: Tuple[str, ...] = tuple(graph_inputs)
        self.outputs: Tuple[str, ...] = tuple(outputs)
        names = [b.name for b in self.blocks]
        if len(set(names)) != len(names):
            raise ValueError("duplicate block names")
        self.by_name: Dict[str, Block] = {b.name: b for b in self.blocks}
        known = set(self.graph_inputs)
        for b in self.blocks:
            for i in b.inputs:
                if i not in known and i not in self.by_name:
                    raise ValueError(f"block {b.name}: unknown input {i!r}")
            known.add(b.name)
        for o in self.outputs:
            if o not in self.by_name:
                raise ValueError(f"unknown output {o!r}")

    # ------------------------------------------------------------------ init

    def init(self, rng: jax.Array,
             input_shapes: Dict[str, Tuple[int, ...]]) -> Dict[str, Any]:
        """Initialize all block params. input_shapes maps graph inputs to shapes."""
        shapes: Dict[str, Any] = dict(input_shapes)
        params: Dict[str, Any] = {}
        for b in self.blocks:
            in_shapes = [shapes[i] for i in b.inputs]
            if b.init is not None:
                rng, sub = jax.random.split(rng)
                params[b.name] = b.init(sub, *in_shapes)
            else:
                params[b.name] = {}
            # trace output shape
            in_structs = [
                jax.ShapeDtypeStruct(s, jnp.float32) if isinstance(s, tuple) else s
                for s in in_shapes
            ]
            out = jax.eval_shape(b.apply, params[b.name], *in_structs)
            shapes[b.name] = (
                out.shape if hasattr(out, "shape") else out
            )
        return params

    # ----------------------------------------------------------- vanilla run

    def apply(self, params: Dict[str, Any], inputs: Dict[str, Any]) -> Any:
        """Vanilla execution: topological sweep, everything live for AD."""
        values: Dict[str, Any] = dict(inputs)
        for b in self.blocks:
            values[b.name] = b.apply(params[b.name], *[values[i] for i in b.inputs])
        outs = tuple(values[o] for o in self.outputs)
        return outs[0] if len(outs) == 1 else outs

    # --------------------------------------------------------- planner input

    def to_graph(
        self,
        params: Dict[str, Any],
        inputs: Dict[str, Any],
        cost_model: str = "paper",
        mesh: Any = None,
    ) -> Graph:
        """Export the paper's G=(V,E) with traced M_v and the chosen T_v.

        With ``mesh`` (a Mesh or ``{axis: size}`` dict), blocks annotated
        with ``out_sharding`` are budgeted at **per-device** bytes through
        the shared accounting in ``repro.parallel.sharding``.
        """
        axis_sizes = None
        if mesh is not None:
            from repro.parallel.sharding import axis_sizes_of, sharded_aval_bytes

            axis_sizes = axis_sizes_of(mesh)
        values: Dict[str, Any] = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype) if hasattr(v, "shape") else v
            for k, v in inputs.items()
        }
        nodes: List[Node] = []
        edges: List[Tuple[int, int]] = []
        idx_of: Dict[str, int] = {}
        for b in self.blocks:
            out = jax.eval_shape(
                b.apply, params[b.name], *[values[i] for i in b.inputs]
            )
            leaves = jax.tree_util.tree_leaves(out)
            if axis_sizes is not None and b.out_sharding is not None:
                mem = float(sum(
                    sharded_aval_bytes(
                        leaf, block_spec(b, tuple(leaf.shape), axis_sizes),
                        axis_sizes,
                    )
                    for leaf in leaves
                ))
            else:
                mem = float(sum(aval_bytes(leaf) for leaf in leaves))
            if cost_model == "paper":
                t = 10.0 if b.heavy else 1.0
            elif cost_model == "flops":
                t = float(b.flops) if b.flops else (10.0 if b.heavy else 1.0)
            else:
                raise ValueError(cost_model)
            idx = len(nodes)
            nodes.append(Node(idx, b.name, t, max(mem, 1.0), "block"))
            idx_of[b.name] = idx
            for i in b.inputs:
                if i in idx_of:
                    edges.append((idx_of[i], idx))
            values[b.name] = out
        return Graph(nodes, edges)

    # ---------------------------------------------------------- planned run

    def apply_planned(
        self,
        params: Dict[str, Any],
        inputs: Dict[str, Any],
        plan: ExecutionPlan,
        checkpoint_policy: Any = None,
    ) -> Any:
        """Execute under the canonical strategy: per-segment jax.checkpoint.

        Delegates to the ``"segment"`` lowering backend
        (``core.lowering.segment.apply_segmented``): each segment V_i runs
        inside ``jax.checkpoint``, its residuals are its inputs (the cached
        boundary values) and its interior is recomputed during backward —
        precisely §3's canonical strategy.
        """
        from .lowering.segment import apply_segmented

        return apply_segmented(self, params, inputs, plan, checkpoint_policy)


# ---------------------------------------------------------------------------
# Convenience: plan a BlockGraph end to end.
# ---------------------------------------------------------------------------


def plan_blockgraph(
    bg: BlockGraph,
    params: Dict[str, Any],
    inputs: Dict[str, Any],
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
    cost_model: str = "paper",
) -> Tuple[Any, Callable[..., Any]]:
    """Trace → plan → return (PlanReport, planned_apply).

    The plan-only slice of the unified pipeline: carrier (this BlockGraph)
    → shared Planner (plan cache + budget sweep) → the ``"segment"``
    lowering via ``apply_planned``.  Callers wanting a value_and_grad twin
    should use ``repro.plan_function(bg, budget, loss_fn=...)`` instead.
    """
    from .lowering.base import InfeasibleBudgetError
    from .planner import plan as _plan

    g = bg.to_graph(params, inputs, cost_model=cost_model)
    report = _plan(g, budget=budget, method=method, objective=objective)
    if report.plan is None:
        raise InfeasibleBudgetError("infeasible budget for this BlockGraph")

    def planned_apply(p: Dict[str, Any], x: Dict[str, Any]) -> Any:
        return bg.apply_planned(p, x, report.plan)

    return report, planned_apply
