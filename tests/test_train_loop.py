"""Fault-tolerance behaviours of the training loop."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.parallel.compat import AxisType, make_mesh
from repro.train import TrainConfig, Trainer


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("stablelm-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    )
    return cfg, model, params, data


def _tc(**kw):
    base = dict(
        total_steps=8,
        log_every=0,
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8),
    )
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases(small_model):
    cfg, model, params, data = small_model
    tr = Trainer(model.loss, params, _tc(total_steps=30,
                 optimizer=AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=30)))
    out = tr.run(iter(data))
    first5 = np.mean(out["losses"][:5])
    last5 = np.mean(out["losses"][-5:])
    assert last5 < first5, (first5, last5)


def test_checkpoint_restart_resumes_exactly(small_model):
    cfg, model, params, data = small_model
    with tempfile.TemporaryDirectory() as d:
        tc = _tc(ckpt_dir=d, ckpt_every=4)
        tr = Trainer(model.loss, params, tc)
        tr.run(iter(data))
        tr.close()
        p_end = tr.params

        tr2 = Trainer(model.loss, params, tc)
        assert tr2.maybe_restore()
        assert tr2.step == 8
        # restored params equal the final saved ones
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            tr2.params,
            p_end,
        )
        tr2.close()


def test_nan_guard_skips_update(small_model):
    cfg, model, params, data = small_model

    def poisoned_loss(p, batch):
        loss = model.loss(p, batch)
        # poison every second step via the batch content hash
        bad = (batch["tokens"][0, 0] % 2 == 0).astype(jnp.float32)
        return loss + bad * jnp.float32(jnp.nan)

    tr = Trainer(poisoned_loss, params, _tc(total_steps=6))
    p0 = jax.tree_util.tree_leaves(tr.params)[0].copy()
    out = tr.run(iter(data))
    assert out["skipped"] >= 1
    # params are still finite (never poisoned)
    for leaf in jax.tree_util.tree_leaves(tr.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_straggler_detection(small_model):
    cfg, model, params, data = small_model
    tr = Trainer(model.loss, params, _tc(total_steps=4, straggler_factor=1.5))
    seen = []
    tr.on_straggler = lambda step, dt, ewma: seen.append((step, dt, ewma))
    # simulate timing directly
    tr._track_time(1.0)
    tr._track_time(1.0)
    tr._track_time(5.0)  # 5x the EWMA → straggler
    assert tr.straggler_steps == 1
    assert seen and seen[0][1] == 5.0


def test_gradient_compression_error_feedback_converges(small_model):
    """int8 round-trip with error feedback should track the uncompressed
    trajectory closely (beyond-paper distributed trick)."""
    cfg, model, params, data = small_model
    tc_plain = _tc(total_steps=10, optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    tc_comp = _tc(total_steps=10, compress_grads=True,
                  optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    out_p = Trainer(model.loss, params, tc_plain).run(iter(data))
    out_c = Trainer(model.loss, params, tc_comp).run(iter(data))
    assert abs(out_p["final_loss"] - out_c["final_loss"]) < 0.1


def test_remesh_rejits(small_model):
    cfg, model, params, data = small_model
    tr = Trainer(model.loss, params, _tc(total_steps=2))
    tr.run(iter(data))
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    tr.remesh(mesh)
    out = tr.run(iter(data))
    assert out["step"] == 2  # already at total; re-jit path exercised


def test_planned_step_matches_vanilla():
    """cfg.plan_budget routes the step through plan_function: same losses
    and parameters as the vanilla value_and_grad step, bit for bit, while
    actually planning under a halved activation budget."""
    from jax import lax

    from repro.core.jaxpr_graph import trace
    from repro.core.liveness import vanilla_peak

    dn = (((1,), (0,)), ((), ()))

    def loss_fn(params, batch):
        h = batch["x"]
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, dn))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3
        for i in range(6)
    ]
    batch = {"x": np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, 16)))}
    budget = vanilla_peak(
        trace(loss_fn, params, batch).graph, liveness=False
    ) / 2

    def run(tc):
        tr = Trainer(loss_fn, params, tc)
        out = tr.run(iter([batch] * 4))
        return out, tr.params

    out_vanilla, p_vanilla = run(_tc(total_steps=4))
    out_planned, p_planned = run(_tc(total_steps=4, plan_budget=budget))
    assert out_vanilla["losses"] == out_planned["losses"]
    for a, b in zip(p_vanilla, p_planned):
        assert np.array_equal(np.asarray(a), np.asarray(b))
