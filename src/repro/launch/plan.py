"""Apply the paper's planner to the production models.

One instance of the unified pipeline (carrier → Planner → lowering): the
carrier here is the unit-granularity *chain graph* of the scan-over-units
LM, the Planner is the shared process-default one (plan cache + budget
sweep + lazy cap extension), and the lowering is the scan-chain projection
of the ``"segment"`` backend (``segments_from_result`` →
``models.transformer`` ``segment_sizes``).

The scan-over-units LM is, at unit granularity, a *chain* — and on a chain
the lower-set lattice is exactly the set of prefixes, so the DP solution is
the true optimum (DESIGN.md §3).  Each unit is modelled as two nodes:

  interior  (M_v = unit's interior activation bytes, T_v = unit FLOPs)
  boundary  (M_v = bytes of the unit output h,        T_v ≈ 0)

so the DP's memory functional sees the real working set while the cached
boundary ∂(L_i) costs only the h tensor — the same accounting XLA applies to
the per-segment ``jax.checkpoint`` this plan lowers to (models.transformer
``segment_sizes``).  Since PR 5 the functional is liveness-tight
(``dp.peak_memory_live``): within a segment's backward window buffers are
charged only while they are actually live, so at a fixed per-device budget
the escalation below can pick coarser segmentations (fewer microbatches /
less recompute) than eq. (2)'s full-footprint charge admitted.

**Byte accounting is sharding-derived, not hand-rolled**: every chain-node
size comes from the shared per-device accounting in
``repro.parallel.sharding`` — each unit tensor is named by its logical axes
(:func:`unit_activation_inventory`), resolved to a PartitionSpec under the
active rules table, and ceil-divided into its per-device shard
(``resolve_spec`` + ``local_bytes``).  The same rules table drives the
model's GSPMD layout, so the bytes the DP budgets and the bytes the
compiled step materializes cannot drift apart.

Budget: per-device HBM minus params+optimizer+workspace, i.e. the activation
budget the paper's B represents (§3 "budget semantics on TPU" — B is the
memory of ONE accelerator).
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import Graph
from repro.core.dp import DPResult, quantize_times
from repro.core.graph import Node
from repro.core.planner import get_default_planner
from repro.launch.mesh import HBM_BYTES
from repro.models.transformer import unit_pattern
from repro.parallel.sharding import (
    DEFAULT_RULES,
    Rules,
    local_bytes,
    local_shape,
    resolve_spec,
)


@dataclasses.dataclass(frozen=True)
class PlanInputs:
    n_units: int
    bytes_boundary: float  # unit output h, per device
    bytes_interior: float  # unit interior activations, per device
    flops_unit: float  # per-shard forward FLOPs of one unit
    budget: float  # per-device activation budget (the paper's B)


def _chain_rules(rules: Optional[Rules]) -> Rules:
    """The rules table for chain accounting, plus the derived ``seq_chain``
    entry: the residual stream between units is sharded over whatever the
    sequence-parallel axes are — ``seq_sp`` (data, long-context) first,
    then ``seq_act`` (Megatron SP over the model axis)."""
    r = dict(DEFAULT_RULES if rules is None else rules)

    def axes(name) -> Tuple:
        t = r.get(name)
        if t is None:
            return ()
        return t if isinstance(t, tuple) else (t,)

    r["seq_chain"] = (axes("seq_sp") + axes("seq_act")) or None
    return r


def unit_activation_inventory(
    cfg: ModelConfig, b: int, s: int, tokens_local: Optional[int] = None
) -> List[Tuple[str, int, Tuple[int, ...], Tuple[Optional[str], ...]]]:
    """Live activation tensors of one unit: (name, count, shape, logical).

    Shapes are *global* per-microbatch; the logical axis names are resolved
    against the sharding rules table to produce per-device bytes — the
    single source of truth replacing the old hand-rolled
    ``activation_expansion`` table.  Sequence dims are GSPMD-padded
    (``pad_dims`` below); head/expert counts keep the strict divisibility
    guard (indivisible → replicated, like ``drop_indivisible``).
    """
    d = cfg.d_model
    kinds, _ = unit_pattern(cfg)
    nk = len(kinds)
    inv: List[Tuple[str, int, Tuple[int, ...], Tuple[Optional[str], ...]]] = []
    # gathered full-sequence attention tensors (k/v/context) — replicated
    # over the model axis for the unit's attention working set
    inv.append(("attn_gather", 2, (b, s, d), ("batch", "seq_sp", None)))
    # residual stream per sub-layer: 2 ln outs, mixer out, mlp out, 2 adds
    inv.append(("residual", 6 * nk, (b, s, d), ("batch", "seq_chain", None)))
    inv.append(
        ("q", nk, (b, s, cfg.n_heads, cfg.head_dim),
         ("batch", "seq_sp", "heads", None))
    )
    inv.append(
        ("kv", 2 * nk, (b, s, cfg.n_kv_heads, cfg.head_dim),
         ("batch", "seq_sp", "kv_heads", None))
    )
    if cfg.d_ff > 0:
        inv.append(
            ("ffn", 3 * nk, (b, s, cfg.d_ff), ("batch", "seq_sp", "ffn"))
        )
    if cfg.moe is not None:
        ntok = tokens_local if tokens_local is not None else b * s
        cap = max(
            1,
            -(-int(cfg.moe.capacity_factor * cfg.moe.top_k * ntok)
              // cfg.moe.num_experts),
        )
        inv.append(
            ("moe_capacity", 3 * nk,
             (cfg.moe.num_experts, cap, cfg.moe.d_ff_expert),
             ("experts", "expert_cap", None))
        )
    if cfg.ssm is not None:
        inv.append(
            ("ssm_branches", 2 * nk, (b, s, int(cfg.ssm.expand * d)),
             ("batch", "seq_sp", "ffn"))
        )
    return inv


def _per_device_bytes(
    shape: Tuple[int, ...],
    logical: Tuple[Optional[str], ...],
    axis_sizes: Dict[str, int],
    rules: Rules,
    act_bytes: int,
) -> int:
    """One tensor through the shared accounting: logical → spec → shard
    bytes.  Sequence dims are GSPMD-padded (ceil shards); head/expert
    count dims keep the strict divisibility guard (→ replicated)."""
    pad = tuple(
        i for i, nm in enumerate(logical) if nm and nm.startswith("seq")
    )
    spec = resolve_spec(logical, axis_sizes, shape=shape, rules=rules,
                        pad_dims=pad)
    return local_bytes(shape, spec, axis_sizes, act_bytes)


def unit_flops(cfg: ModelConfig, tokens: int) -> float:
    """Forward FLOPs of one unit (≈ 2 · active-params-per-unit · tokens)."""
    kinds, n_units = unit_pattern(cfg)
    per_unit_params = (cfg.num_active_params() - 2 * cfg.vocab_size * cfg.d_model) / max(
        n_units, 1
    )
    return 2.0 * max(per_unit_params, 1.0) * tokens


def chain_graph(pi: PlanInputs) -> Graph:
    """2-node-per-unit chain: interior → boundary → interior → …"""
    nodes = []
    edges = []
    for u in range(pi.n_units):
        i_int = 2 * u
        nodes.append(
            Node(i_int, f"u{u}_interior", max(pi.flops_unit, 1.0), max(pi.bytes_interior, 1.0), "unit")
        )
        nodes.append(
            Node(i_int + 1, f"u{u}_out", 1.0, max(pi.bytes_boundary, 1.0), "boundary")
        )
        edges.append((i_int, i_int + 1))
        if u:
            edges.append((i_int - 1, i_int))
    return Graph(nodes, edges)


def static_bytes(cfg: ModelConfig, model_shards: int, fsdp_shards: int = 1) -> float:
    """Per-device params (f32) + AdamW mu/nu (f32)."""
    return cfg.num_params() * (4 + 8) / max(model_shards, 1) / max(fsdp_shards, 1)


def needs_fsdp(cfg: ModelConfig, model_shards: int,
               hbm_bytes: float = HBM_BYTES) -> bool:
    """TP-only static state over ~35% of HBM → also shard params over data."""
    return static_bytes(cfg, model_shards) > 0.35 * hbm_bytes


def plan_inputs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    n_micro: int = 1,
    hbm_bytes: float = HBM_BYTES,
    act_bytes: int = 2,  # bf16
    rules: Optional[Rules] = None,
) -> PlanInputs:
    """Chain-graph inputs with every byte size derived from the shared
    sharding-aware accounting (``repro.parallel.sharding``).

    ``dp_shards``/``seq_shards`` both occupy the mesh "data" axis (which of
    the two actually shards is decided by the rules table + divisibility:
    batch takes it when it divides, otherwise ``seq_sp`` does — exactly the
    launchers' layout logic).  ``rules=None`` uses ``DEFAULT_RULES`` so
    direct calls are deterministic; the launchers pass their active table.
    """
    _, n_units = unit_pattern(cfg)
    r = _chain_rules(rules)
    axis_sizes = {
        "pod": 1,
        "data": max(dp_shards, 1) * max(seq_shards, 1),
        "model": max(model_shards, 1),
    }
    b_g = max(1, shape.global_batch // max(n_micro, 1))
    s = shape.seq_len
    d = cfg.d_model

    # local token count (drives FLOPs and MoE capacity rows)
    tok_spec = resolve_spec(("batch", "seq_sp"), axis_sizes, shape=(b_g, s),
                            rules=r, pad_dims=(1,))
    tl = local_shape((b_g, s), tok_spec, axis_sizes)
    tokens_local = tl[0] * tl[1]

    interior = sum(
        count * _per_device_bytes(shp, logical, axis_sizes, r, act_bytes)
        for _, count, shp, logical in unit_activation_inventory(
            cfg, b_g, s, tokens_local=tokens_local
        )
    )
    h_boundary = _per_device_bytes(
        (b_g, s, d), ("batch", "seq_chain", None), axis_sizes, r, act_bytes
    )
    # per-shard forward FLOPs (TP splits every unit matmul model_shards ways)
    flops = unit_flops(cfg, tokens_local) / max(model_shards, 1)
    fsdp = dp_shards if needs_fsdp(cfg, model_shards, hbm_bytes) else 1
    static = static_bytes(cfg, model_shards, fsdp)
    if n_micro > 1:
        static += cfg.num_params() * 4 / max(model_shards, 1) / max(fsdp, 1)  # grad accum f32
    budget = max(hbm_bytes - static, 0.05 * hbm_bytes)
    return PlanInputs(
        n_units=n_units,
        bytes_boundary=float(h_boundary),
        bytes_interior=float(interior),
        flops_unit=float(flops),
        budget=float(budget),
    )


def segments_from_result(
    res: DPResult, n_units: int
) -> Tuple[Tuple[int, ...], Tuple[bool, ...]]:
    """Lower-set sequence on the 2-node chain → (group sizes, remat flags).

    This is the scan-chain projection of the ``"segment"`` lowering backend
    (``core.lowering.segment.segment_groups``), specialized to the
    interior/boundary 2-node unit encoding of :func:`chain_graph`.

    On the chain, ∂(L) = {max(L)}: a lower set ending at a unit's *interior*
    node caches that interior — the unit runs unwrapped (vanilla residuals,
    no recompute).  Lower sets ending at *boundary* nodes delimit
    jax.checkpoint groups whose interiors are recomputed.  With ample budget
    the time-centric DP caches everything (overhead 0 = vanilla); under
    pressure it mixes — exactly the paper's trade, lowered to XLA.
    """
    cached_units = set()
    end_units = []
    for L in res.sequence:
        m = max(L)
        if m % 2 == 0:
            cached_units.add(m // 2)
        else:
            end_units.append(m // 2)
    sizes: list = []
    remat: list = []

    def emit(lo: int, hi: int) -> None:
        """units [lo, hi] — split into maximal cached/uncached runs."""
        u = lo
        while u <= hi:
            flag = u in cached_units
            v = u
            while v + 1 <= hi and ((v + 1) in cached_units) == flag:
                v += 1
            sizes.append(v - u + 1)
            remat.append(not flag)
            u = v + 1

    prev = -1
    for e in end_units:
        if e > prev:
            emit(prev + 1, e)
            prev = e
    if prev < n_units - 1:
        emit(prev + 1, n_units - 1)
    return tuple(sizes), tuple(remat)


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    sizes: Tuple[int, ...]
    remat: Tuple[bool, ...]
    n_micro: int = 1

    @property
    def n_segments(self) -> int:
        return len(self.sizes)


def _dp_chain_graph(pi: PlanInputs, measured: Optional[bool] = None) -> Graph:
    """Chain graph with the DP's integer t-axis.

    With measured costs (``measured=True`` or ``REPRO_MEASURED_COSTS=1``) the
    interior/boundary nodes are priced by the profiled cost model
    (FLOPs·matmul-rate vs bytes·HBM-rate) before quantization, so the DP
    trades real seconds, not FLOP proxies.  Default stays analytic —
    profiling costs a one-off timing run per backend.
    """
    raw = chain_graph(pi)
    if measured is None:
        measured = bool(os.environ.get("REPRO_MEASURED_COSTS"))
    if measured:
        from repro.core.cost_model import calibrated_graph, load_or_profile

        return calibrated_graph(raw, load_or_profile(), levels=32)
    return quantize_times(raw, levels=32)


def plan_unit_segments(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    n_micro: int = 1,
    budget: Optional[float] = None,
    objective: str = "time_centric",
    measured_costs: Optional[bool] = None,
    rules: Optional[Rules] = None,
) -> Tuple[SegmentPlan, DPResult]:
    """One-call front door used by the launchers and the dry-run.

    Solves through the process-default ``Planner``: repeated cells of the
    dry-run matrix, microbatch escalation retries, and job restarts hit the
    plan cache instead of re-running the exact DP.
    """
    pi = plan_inputs(cfg, shape, dp_shards, seq_shards, model_shards, n_micro,
                     rules=rules)
    g = _dp_chain_graph(pi, measured_costs)
    B = budget if budget is not None else pi.budget
    res = get_default_planner().solve(g, B, "exact_dp", objective)
    if not res.feasible:
        sp = SegmentPlan(tuple(1 for _ in range(pi.n_units)),
                         tuple(True for _ in range(pi.n_units)), n_micro)
        return sp, res
    _maybe_verify(g, res, B)
    sizes, remat = segments_from_result(res, pi.n_units)
    return SegmentPlan(sizes, remat, n_micro), res


def prewarm_unit_plans(
    cfg: ModelConfig,
    shapes: Sequence[ShapeConfig],
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    n_micro: int = 1,
    objective: str = "time_centric",
    measured_costs: Optional[bool] = None,
    rules: Optional[Rules] = None,
) -> Dict[str, bool]:
    """Pre-warm the plan cache for every expected planning signature.

    For each shape, builds the exact chain graph :func:`plan_unit_segments`
    would solve and makes sure a **full budget-free sweep** for it is hot
    (``Planner.prewarm`` on the process-default planner) — so the first
    real ``plan_unit_segments`` / ``plan_with_microbatching`` call at that
    signature is a frontier lookup, not a cold DP.  With a fleet store
    attached (``set_default_remote_store`` / ``REPRO_PLAN_REMOTE_DIR``) one
    replica's pre-warm serves the whole fleet via read-through.

    Returns ``{shape.name: already_warm}`` — False entries are the
    signatures this call paid a cold solve for.
    """
    planner = get_default_planner()
    out: Dict[str, bool] = {}
    for shape in shapes:
        pi = plan_inputs(cfg, shape, dp_shards, seq_shards, model_shards,
                         n_micro, rules=rules)
        g = _dp_chain_graph(pi, measured_costs)
        out[shape.name] = planner.prewarm(g, "exact_dp", objective)
    return out


def _maybe_verify(g: Graph, res: DPResult, budget: float) -> None:
    """``REPRO_VERIFY_PLANS=1``: statically re-verify the launch plan.

    Runs the DP-independent verifier (``repro.analysis.check_plan``) over
    the solved lower-set sequence — topology, replay soundness, simulated
    peak vs. the per-device budget, eq. (1) overhead — and refuses to hand
    a launcher an unsound schedule.  Off by default: the checks are cheap
    (linear in segments) but this path sits under dry-run sweeps that call
    it thousands of times.

    The stronger ``REPRO_VERIFY_PLANS=hlo`` level (compiler-truth checks,
    ``analysis.check_hlo``) applies at the ``plan_function`` front door,
    where a traced carrier exists to compile; the launch chain graphs here
    have no compiled twin, so any truthy value — including ``hlo`` — runs
    the static verifier only.
    """
    if not os.environ.get("REPRO_VERIFY_PLANS"):
        return
    from repro import analysis
    from repro.analysis.report import PlanVerificationError
    from repro.core.schedule import make_plan

    report = analysis.check_plan(g, make_plan(g, res.sequence), budget=budget)
    if not report.ok:
        raise PlanVerificationError(str(report))


#: modeled per-extra-microbatch fixed cost, as a fraction of the whole
#: step's forward time (weight re-gathers under FSDP, scan constants,
#: pipeline fill) — escalating one more factor must buy at least this much
#: recompute overhead back
MICRO_STEP_TAX = 0.05


def plan_with_microbatching(
    cfg: ModelConfig,
    shape: ShapeConfig,
    dp_shards: int,
    seq_shards: int = 1,
    model_shards: int = 16,
    objective: str = "time_centric",
    max_micro: int = 16,
    rules: Optional[Rules] = None,
) -> Tuple[SegmentPlan, DPResult]:
    """Pick ``(n_micro, plan)`` jointly by modeled step time.

    Beyond §5.1's "smallest feasible factor": each candidate factor's
    (budget → overhead) Pareto staircase comes from a cached budget sweep
    capped at that factor's per-device budget (``Planner.solve_grid`` — one
    DP pass, reused verbatim by the final ``plan_unit_segments`` solve), so
    the modeled step time

        t(k) ≈ fwd_total · (3 + overhead_k(B_k)/T(V_k) + (k-1) · tax)

    trades recompute overhead (read off the staircase at the factor's
    budget) against the fixed per-microbatch cost ``MICRO_STEP_TAX``.  The
    best feasible factor wins; ties break toward fewer microbatches.
    Infeasible-everywhere falls back to the largest factor (old behavior).

    With ``objective="wallclock"`` each candidate factor is priced by the
    discrete-event replay simulator (``core.replay``) instead of the
    additive model: recompute that hides under the next segment's backward
    window (budget headroom permitting) is not charged, so a factor whose
    overhead overlaps away can beat a nominally lower-overhead one.  The
    early-exit guard is unchanged — overlap only shrinks a factor's step
    time, so the overhead bound on potential savings still holds.
    """
    b_loc = max(1, shape.global_batch // max(dp_shards, 1))
    planner = get_default_planner()
    best: Optional[Tuple[float, int]] = None  # (modeled time, n_micro)
    n_micro = 1
    while n_micro <= min(max_micro, b_loc):
        pi = plan_inputs(cfg, shape, dp_shards, seq_shards, model_shards,
                         n_micro, rules=rules)
        g = _dp_chain_graph(pi)
        res = planner.solve_grid(g, [pi.budget], "exact_dp", objective)[0]
        if res.feasible:
            oh_frac = res.overhead / g.total_time
            if objective == "wallclock":
                # Price the candidate with the replay simulator instead of
                # the additive overhead model: replayed seconds (with the
                # budget's headroom spent on overlap) normalized by forward
                # time is directly comparable to 3 + oh_frac across factors.
                from repro.core.replay import replay
                from repro.core.schedule import make_plan

                rr = replay(g, make_plan(g, res.sequence), budget=pi.budget)
                t_model = (rr.seconds / g.total_time
                           + (n_micro - 1) * MICRO_STEP_TAX)
            else:
                t_model = 3.0 + oh_frac + (n_micro - 1) * MICRO_STEP_TAX
            if best is None or t_model < best[0]:
                best = (t_model, n_micro)
            # sound early exit: a larger factor k' ≥ 2k pays ≥ k·tax extra
            # and can save at most this factor's whole overhead
            if oh_frac <= n_micro * MICRO_STEP_TAX:
                break
        n_micro *= 2
    chosen = best[1] if best is not None else min(max_micro, b_loc)
    return plan_unit_segments(
        cfg, shape, dp_shards, seq_shards, model_shards, chosen,
        objective=objective, rules=rules,
    )
