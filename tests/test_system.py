"""End-to-end behaviour: the paper's pipeline from graph to trained model."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import compare_methods, min_feasible_budget, plan
from repro.core.graph import chain
from repro.data import DataConfig, SyntheticLM
from repro.launch.plan import plan_with_microbatching
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serving import Engine
from repro.train import TrainConfig, Trainer


def test_paper_pipeline_on_abstract_graph():
    """graph → min budget → plans → Table-1-style comparison row."""
    g = chain(24, time=10.0, memory=4.0)
    reports = compare_methods(g, include_exact=True)
    by = {(r.method, r.objective): r for r in reports}
    vanilla = by[("vanilla", "-")]
    # every recomputation method beats vanilla's simulated peak
    for key, r in by.items():
        if key[0] == "vanilla":
            continue
        assert r.feasible
        assert r.peak_with_liveness <= vanilla.peak_with_liveness + 1e-9
    # MC(min-budget) peak ≤ TC(min-budget) peak under liveness (§4.4)
    mc = by[("exact_dp", "memory_centric")]
    tc = by[("exact_dp", "time_centric")]
    assert mc.peak_with_liveness <= tc.peak_with_liveness + 1e-9
    # and TC overhead ≤ MC overhead
    assert tc.result.overhead <= mc.result.overhead + 1e-9


def test_train_full_stack_with_plan():
    """DP plan → sharded-capable loss → trainer → loss ↓ (the framework's
    one-sentence story, executed)."""
    cfg = reduced(get_config("phi4-mini-3.8b"), n_layers=4)
    model = build_model(cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    sp, res = plan_with_microbatching(cfg, shape, dp_shards=1, model_shards=1,
                                      max_micro=1)
    loss_fn = lambda p, b: model.loss(p, b, segment_sizes=sp.sizes,
                                      segment_remat=sp.remat)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    tr = Trainer(loss_fn, params, TrainConfig(
        total_steps=25, log_every=0,
        optimizer=AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=25)))
    out = tr.run(iter(data))
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


def test_train_then_serve():
    cfg = reduced(get_config("stablelm-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    tr = Trainer(model.loss, params, TrainConfig(
        total_steps=5, log_every=0,
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=5)))
    tr.run(iter(data))
    eng = Engine(model, tr.params, max_slots=2, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4
