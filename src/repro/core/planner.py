"""High-level planning API: solve the general recomputation problem for a
graph (or a traced JAX function) under a memory budget.

The paper's §5.1 protocol: "for the memory budget B … we chose the minimal
value B for which the solution … exists.  This value was determined using
binary search."  ``min_feasible_budget`` implements that search;
``plan`` is the one-call front door used by the framework.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, List, Optional, Sequence, Tuple

from . import dp as dp_mod
from .chen import chen_sqrt_n
from .dp import DPResult, approx_dp, exact_dp, solve
from .graph import Graph, NodeSet
from .liveness import simulate, vanilla_peak
from .lower_sets import all_lower_sets, pruned_lower_sets
from .schedule import ExecutionPlan, make_plan


@dataclasses.dataclass
class PlanReport:
    """Everything the framework (and the benchmarks) need about one plan."""

    method: str  # "exact_dp" | "approx_dp" | "chen" | "vanilla"
    objective: str  # "time_centric" | "memory_centric" | "-"
    budget: float
    result: DPResult
    plan: Optional[ExecutionPlan]
    peak_with_liveness: float
    peak_without_liveness: float
    plan_seconds: float

    @property
    def feasible(self) -> bool:
        return self.result.feasible


def _family(g: Graph, method: str) -> Sequence[NodeSet]:
    if method == "exact_dp":
        return all_lower_sets(g)
    if method == "approx_dp":
        return pruned_lower_sets(g)
    raise ValueError(method)


def min_feasible_budget(
    g: Graph,
    method: str = "approx_dp",
    tol: float = 1e-3,
    family: Optional[Sequence[NodeSet]] = None,
) -> float:
    """Binary search the minimal B with a feasible canonical strategy (§5.1).

    Bounds: any strategy needs at least max_i 2·M_v-ish memory; the
    single-segment strategy needs ≤ vanilla 2·M(V).  We search in
    [max_v M_v, 2·M(V)] to relative tolerance ``tol``, using the fast
    feasibility-only DP (core.dp.feasible) per probe.
    """
    from .dp import _prepare, feasible

    fam = list(family) if family is not None else list(_family(g, method))
    infos = _prepare(g, fam)
    lo = max(g.mem_v)
    hi = 2.0 * g.total_memory + max(g.mem_v)
    # verify hi feasible
    if not feasible(g, hi, fam, infos):
        raise RuntimeError("even the maximal budget is infeasible — bug")
    while hi - lo > tol * max(1.0, hi):
        mid = 0.5 * (lo + hi)
        if feasible(g, mid, fam, infos):
            hi = mid
        else:
            lo = mid
    return hi


def plan(
    g: Graph,
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
) -> PlanReport:
    """Solve and lower to an ExecutionPlan.

    budget=None reproduces the paper's protocol: minimal feasible B.
    method ∈ {"exact_dp", "approx_dp", "chen", "vanilla"}.
    """
    t0 = _time.perf_counter()
    full = frozenset(range(g.n))

    if method == "vanilla":
        res = DPResult(
            sequence=[full],
            overhead=0.0,
            peak_memory=dp_mod.peak_memory(g, [full]),
            feasible=True,
        )
    elif method == "chen":
        res = chen_sqrt_n(g, budget=None)
    else:
        fam = list(_family(g, method))
        if budget is None:
            budget = min_feasible_budget(g, method, family=fam)
        res = solve(g, budget, fam, objective)
    dt = _time.perf_counter() - t0

    if not res.feasible:
        return PlanReport(
            method=method,
            objective=objective if method.endswith("dp") else "-",
            budget=budget if budget is not None else float("nan"),
            result=res,
            plan=None,
            peak_with_liveness=float("inf"),
            peak_without_liveness=float("inf"),
            plan_seconds=dt,
        )

    ep = make_plan(g, res.sequence)
    sim_live = simulate(g, res.sequence, liveness=True)
    sim_nolive = simulate(g, res.sequence, liveness=False)
    return PlanReport(
        method=method,
        objective=objective if method.endswith("dp") else "-",
        budget=budget if budget is not None else res.peak_memory,
        result=res,
        plan=ep,
        peak_with_liveness=sim_live.peak_memory,
        peak_without_liveness=sim_nolive.peak_memory,
        plan_seconds=dt,
    )


def compare_methods(
    g: Graph, budget: Optional[float] = None, include_exact: bool = True
) -> List[PlanReport]:
    """The paper's Table-1 row for one network: all methods, one graph."""
    reports = [plan(g, method="vanilla")]
    reports.append(plan(g, method="chen"))
    for objective in ("memory_centric", "time_centric"):
        reports.append(plan(g, budget, "approx_dp", objective))
        if include_exact:
            reports.append(plan(g, budget, "exact_dp", objective))
    return reports
