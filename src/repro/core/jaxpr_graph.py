"""Extract the paper's graph ``G = (V, E)`` from a traced JAX function.

The paper builds G over the *intermediate* values of the network, excluding
inputs and parameters (§2).  In JAX the natural carrier is the jaxpr: every
equation output is an intermediate value node; an edge (v, w) exists when v's
output is an operand of w's equation.

Cost models (§3: "We can either directly measure T_v … or use some form of
approximation.  … we therefore set T_v = 10 for convolutional node, and
T_v = 1 for all other types of node."):

* ``cost_model="paper"`` — T_v = 10 for dot/conv-like primitives, 1 otherwise
  (the paper's model, the default);
* ``cost_model="flops"`` — beyond-paper: analytic FLOP counts per primitive
  (matmul 2·M·N·K, conv 2·spatial·Cin·Cout·k², elementwise = #elems), then
  quantized for the DP's integer t-axis by the caller.

``M_v`` is always the byte size of the equation's outputs — **per device**
when a mesh + input shardings are supplied (the paper's budget B is the
memory of one accelerator, §3): shardings are propagated through the jaxpr
(``repro.parallel.sharding.propagate_eqn_specs``, conservative replicated
fallback) and each node's bytes are the ceil-divided shard size.  Under the
``"flops"`` cost model the same shard count divides ``T_v`` (per-shard
FLOPs for sharded matmuls/attention), so the measured cost model
(``core.cost_model``) prices sharded graphs in per-device seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

from .graph import Graph, Node
from .prims import (  # single source of truth (core.prims)
    HEAVY_PRIMS,
    HIGHER_ORDER_PRIMS as _HIGHER_ORDER_PRIMS,
    INNER_JAXPR_KEYS as _INNER_JAXPR_KEYS,
    MATMUL_PRIMS as _MATMUL_PRIMS,
)


def aval_bytes(aval: Any) -> int:
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 1
    try:
        itemsize = np.dtype(aval.dtype).itemsize
    except TypeError:
        # extended dtypes (e.g. PRNG key arrays, dtype "key<fry>") are not
        # numpy dtypes but still know their own itemsize
        itemsize = int(getattr(aval.dtype, "itemsize", 8))
    return int(np.prod(aval.shape, dtype=np.int64)) * itemsize


def _dot_flops(eqn: Any) -> float:
    """2·M·N·K for dot_general from operand avals."""
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = np.prod(
        [lhs.shape[i] for i in range(len(lhs.shape)) if i not in set(lc) | set(lb)],
        dtype=np.int64,
    )
    n = np.prod(
        [rhs.shape[i] for i in range(len(rhs.shape)) if i not in set(rc) | set(rb)],
        dtype=np.int64,
    )
    k = np.prod([lhs.shape[i] for i in lc], dtype=np.int64)
    b = np.prod([lhs.shape[i] for i in lb], dtype=np.int64)
    return float(2 * b * m * n * k)


def _conv_flops(eqn: Any) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    # 2 · out_elems · (k_spatial · Cin)
    k_elems = np.prod(rhs.shape, dtype=np.int64)  # includes Cin·Cout·spatial
    out_spatial = np.prod(out.shape, dtype=np.int64)
    cout = rhs.shape[-1] if len(rhs.shape) >= 2 else 1
    return float(2 * out_spatial * max(1, k_elems // max(1, cout)))


def _inner_jaxpr_flops(eqn: Any) -> float:
    total = 0.0
    for key in _INNER_JAXPR_KEYS:
        sub = eqn.params.get(key)
        if sub is None:
            continue
        subs = sub if isinstance(sub, (list, tuple)) else [sub]
        for s in subs:
            inner = s.jaxpr if hasattr(s, "jaxpr") else s
            for ie in inner.eqns:
                total += eqn_flops_for(ie)
    length = eqn.params.get("length", 1)
    if eqn.primitive.name == "scan":
        total *= max(1, length)
    return total


def eqn_flops_for(eqn: Any) -> float:
    name = eqn.primitive.name
    try:
        if name == "dot_general":
            return _dot_flops(eqn)
        if name == "conv_general_dilated":
            return _conv_flops(eqn)
        if name in _HIGHER_ORDER_PRIMS:
            return max(1.0, _inner_jaxpr_flops(eqn))
    except Exception:
        pass
    # elementwise default: one flop per output element
    out = 0.0
    for ov in eqn.outvars:
        if hasattr(ov, "aval") and hasattr(ov.aval, "shape"):
            out += float(np.prod(ov.aval.shape, dtype=np.int64))
    return max(1.0, out)


def _eqn_io_bytes(eqn: Any) -> float:
    total = 0.0
    for vs in (eqn.invars, eqn.outvars):
        for v in vs:
            if hasattr(v, "aval"):
                total += aval_bytes(v.aval)
    return total


def eqn_bytes_for(eqn: Any) -> float:
    """HBM-traffic estimate per eqn: input+output bytes, with scan/while/call
    bodies recursed and multiplied by trip count (the piece XLA's
    cost_analysis drops — it counts loop bodies once)."""
    name = eqn.primitive.name
    if name in _HIGHER_ORDER_PRIMS:
        total = 0.0
        for key in _INNER_JAXPR_KEYS:
            sub = eqn.params.get(key)
            if sub is None:
                continue
            subs = sub if isinstance(sub, (list, tuple)) else [sub]
            for s in subs:
                inner = s.jaxpr if hasattr(s, "jaxpr") else s
                total += sum(eqn_bytes_for(ie) for ie in inner.eqns)
        if name == "scan":
            total *= max(1, eqn.params.get("length", 1))
        return total
    return _eqn_io_bytes(eqn)


def jaxpr_totals(closed_jaxpr: Any) -> Dict[str, float]:
    """Global (pre-partition) FLOPs and byte-traffic totals of a jaxpr,
    scan-aware.  The dry-run divides by the mesh size for per-chip terms."""
    flops = 0.0
    nbytes = 0.0
    for eqn in closed_jaxpr.jaxpr.eqns:
        flops += eqn_flops_for(eqn)
        nbytes += eqn_bytes_for(eqn)
    return {"flops": flops, "bytes": nbytes}


def eqn_is_heavy(eqn: Any) -> bool:
    name = eqn.primitive.name
    if name in _MATMUL_PRIMS:
        return True
    if name in HEAVY_PRIMS:
        # heavy iff it contains a heavy eqn
        for key in ("jaxpr", "call_jaxpr", "body_jaxpr"):
            sub = eqn.params.get(key)
            if sub is None:
                continue
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            if any(eqn_is_heavy(ie) for ie in inner.eqns):
                return True
    return False


@dataclasses.dataclass
class JaxprGraph:
    """The extracted graph plus the mapping back to jaxpr equations."""

    graph: Graph
    eqns: List[Any]  # node idx → jaxpr eqn
    jaxpr: Any
    #: per-equation output PartitionSpecs when traced under a mesh (aligned
    #: with ``eqns``; None for an unsharded trace)
    eqn_specs: Optional[List[Tuple]] = None
    #: mesh axis name → size for a sharded trace ({} otherwise) — lets the
    #: static verifier (repro.analysis) re-derive per-device bytes
    axis_sizes: Dict[str, int] = dataclasses.field(default_factory=dict)


def from_jaxpr(
    closed_jaxpr: Any,
    cost_model: str = "paper",
    mesh: Any = None,
    in_shardings: Optional[Sequence[Any]] = None,
) -> JaxprGraph:
    """Build the paper's G=(V,E) from a ClosedJaxpr.

    With ``mesh`` (a ``jax.sharding.Mesh`` or a plain ``{axis: size}``
    dict — no devices needed for planning), ``in_shardings`` is a sequence
    of PartitionSpec/NamedSharding/None aligned with ``jaxpr.invars``;
    node ``M_v`` becomes **per-device** bytes and the ``"flops"`` cost
    model emits per-shard FLOPs.
    """
    jaxpr = closed_jaxpr.jaxpr
    producer: Dict[Any, int] = {}  # jaxpr Var -> node idx
    nodes: List[Node] = []
    eqns: List[Any] = []
    edges: List[Tuple[int, int]] = []

    eqn_specs = None
    axis_sizes: Dict[str, int] = {}
    if mesh is not None:
        from repro.parallel import sharding as _sh

        axis_sizes = _sh.axis_sizes_of(mesh)
        if in_shardings is None:
            in_shardings = [None] * len(jaxpr.invars)
        eqn_specs = _sh.propagate_eqn_specs(
            closed_jaxpr, [_sh.normalize_spec(s) for s in in_shardings],
            axis_sizes,
        )

    for eidx, eqn in enumerate(jaxpr.eqns):
        if eqn_specs is not None:
            from repro.parallel import sharding as _sh

            specs = eqn_specs[eidx]
            mem = 0
            shards = 1
            for ov, sp in zip(eqn.outvars, specs):
                if not hasattr(ov, "aval"):
                    continue
                mem += _sh.sharded_aval_bytes(ov.aval, sp, axis_sizes)
                if hasattr(ov.aval, "shape"):
                    shards = max(
                        shards,
                        _sh.num_shards(ov.aval.shape, sp, axis_sizes),
                    )
        else:
            shards = 1
            mem = sum(
                aval_bytes(ov.aval) for ov in eqn.outvars if hasattr(ov, "aval")
            )
        if mem <= 0:
            mem = 1
        if cost_model == "paper":
            t = 10.0 if eqn_is_heavy(eqn) else 1.0
        elif cost_model == "flops":
            # per-shard FLOPs: an output split k ways costs each device 1/k
            # of the global work (contracting dims are never sharded by the
            # conservative propagation, so no reduction terms appear)
            t = max(eqn_flops_for(eqn) / shards, 1.0)
        else:
            raise ValueError(f"unknown cost_model {cost_model!r}")
        idx = len(nodes)
        nodes.append(
            Node(
                idx=idx,
                name=f"{idx}:{eqn.primitive.name}",
                time=t,
                memory=float(mem),
                kind=eqn.primitive.name,
            )
        )
        eqns.append(eqn)
        for iv in eqn.invars:
            if isinstance(iv, jcore.Literal):
                continue
            src = producer.get(iv)
            if src is not None:
                edges.append((src, idx))
        for ov in eqn.outvars:
            producer[ov] = idx

    return JaxprGraph(
        graph=Graph(nodes, edges), eqns=eqns, jaxpr=closed_jaxpr,
        eqn_specs=eqn_specs, axis_sizes=axis_sizes,
    )


def trace(
    fn: Callable[..., Any],
    *example_args: Any,
    cost_model: str = "paper",
    mesh: Any = None,
    in_shardings: Optional[Sequence[Any]] = None,
) -> JaxprGraph:
    """Trace ``fn`` on example arguments (arrays or ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*example_args)
    return from_jaxpr(closed, cost_model=cost_model, mesh=mesh,
                      in_shardings=in_shardings)
