"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode).

Sweeps shapes, dtypes, causality, GQA ratios and block sizes; checks both
the forward and the recompute backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa_op
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import flash_attention
from repro.kernels.ref import attention_ref, attention_with_lse_ref


def _mk(B, H, KV, Sq, Sk, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, D), dtype)
    return q, k, v


SHAPES = [
    # B, H, KV, Sq,  Sk,  D
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),   # GQA 2:1
    (1, 8, 1, 128, 128, 32),   # MQA
    (1, 2, 2, 128, 256, 64),   # decode-style Sk > Sq
    (2, 2, 2, 64, 64, 128),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_forward_matches_oracle(shape, causal):
    B, H, KV, Sq, Sk, D = shape
    q, k, v = _mk(B, H, KV, Sq, Sk, D, jnp.float32)
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    oref, lref = attention_with_lse_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, oref, rtol=1e-5, atol=2e-5)
    np.testing.assert_allclose(lse, lref, rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    q, k, v = _mk(1, 4, 4, 128, 128, 64, dtype)
    out, _ = flash_attention_fwd(q, k, v, interpret=True)
    oref = attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), oref.astype(jnp.float32), rtol=tol, atol=tol
    )
    assert out.dtype == dtype


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    q, k, v = _mk(1, 2, 2, 128, 128, 64, jnp.float32)
    out, lse = flash_attention_fwd(
        q, k, v, block_q=bq, block_k=bk, interpret=True
    )
    ref, lref = attention_with_lse_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=2e-5)
    np.testing.assert_allclose(lse, lref, rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("KV", [1, 2, 4])
def test_backward_recompute_matches_autodiff(KV):
    B, H, Sq, D = 1, 4, 128, 32
    q, k, v = _mk(B, H, KV, Sq, Sq, D, jnp.float32, seed=3)
    do = jax.random.normal(jax.random.PRNGKey(9), (B, Sq, H, D))

    def loss_kernel(q_, k_, v_):
        out = flash_attention(
            q_.transpose(0, 2, 1, 3),
            k_.transpose(0, 2, 1, 3),
            v_.transpose(0, 2, 1, 3),
            interpret=True,
            block_q=64,
            block_k=64,
        )
        return jnp.sum(out.transpose(0, 2, 1, 3) * do.transpose(0, 2, 1, 3))

    def loss_ref(q_, k_, v_):
        return jnp.sum(attention_ref(q_, k_, v_) * do.transpose(0, 2, 1, 3))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5, err_msg=name)


def test_no_score_matrix_in_residuals():
    """The whole point: residuals must be O(S), not O(S²) — inspect the VJP
    jaxpr for any (Sq, Sk) f32 intermediate crossing the fwd/bwd boundary."""
    S = 256
    q, k, v = _mk(1, 2, 2, S, S, 32, jnp.float32)

    def f(q_, k_, v_):
        return jnp.sum(
            flash_attention(
                q_.transpose(0, 2, 1, 3),
                k_.transpose(0, 2, 1, 3),
                v_.transpose(0, 2, 1, 3),
                interpret=True,
            )
        )

    # residuals of the custom_vjp: q, k, v, out, lse — all O(S·D) or O(S)
    out, vjp = jax.vjp(f, q, k, v)
    # vjp closure leaves: no (S, S)-shaped arrays
    leaves = jax.tree_util.tree_leaves(vjp)
    for leaf in leaves:
        if hasattr(leaf, "shape") and len(leaf.shape) >= 2:
            assert not (
                leaf.shape[-1] == S and leaf.shape[-2] == S
            ), f"O(S²) residual cached: {leaf.shape}"


def test_fully_masked_rows_are_zero():
    """Non-square causal with Sq > Sk never occurs, but padded/masked rows
    (first rows with off<0 alignment) must not produce NaNs."""
    q, k, v = _mk(1, 2, 2, 128, 128, 64, jnp.float32)
    out, _ = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    assert not bool(jnp.any(jnp.isnan(out)))
