"""Sharding rules: logical resolution, divisibility guard, FSDP extension."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_RULES,
    drop_indivisible,
    fsdp_extend,
    param_spec,
    resolve,
    set_rules,
    tree_param_specs,
)

SIZES = {"pod": 2, "data": 16, "model": 16}


def setup_function(_):
    set_rules(DEFAULT_RULES)


def test_drop_indivisible_keeps_divisible():
    spec = P(("pod", "data"), None, "model")
    out = drop_indivisible(spec, (64, 7, 32), SIZES)
    assert out == P(("pod", "data"), None, "model")


def test_drop_indivisible_replicates_odd_dims():
    # kv_heads = 8 on a 16-way model axis → replicate
    out = drop_indivisible(P(None, None, "model", None), (2, 128, 8, 64), SIZES)
    assert out == P(None, None, None, None)
    # odd vocab on model
    out2 = drop_indivisible(P("model", None), (49155, 1536), SIZES)
    assert out2 == P(None, None)


def test_fsdp_extend_shards_largest_free_dim():
    spec = P(None, "model")
    out = fsdp_extend(spec, (4096, 11008), SIZES)
    assert out == P("data", "model")
    # small tensors untouched
    assert fsdp_extend(P(), (2560,), SIZES) == P()


def test_param_spec_conventions():
    assert param_spec("layers/attn/wq", (1024, 2048)) == P(None, "model")
    assert param_spec("layers/attn/wo", (2048, 1024)) == P("model", None)
    assert param_spec("layers/mlp/w_gate", (1024, 8192)) == P(None, "model")
    assert param_spec("layers/mlp/w_down", (8192, 1024)) == P("model", None)
    assert param_spec("embedding/embed", (50304, 1024)) == P("model", None)
    assert param_spec("ln/scale", (1024,)) == P(None)
    e = param_spec("moe/experts/w_gate", (64, 1024, 768))
    assert e == P("model", None, None)


def test_tree_param_specs_stacked_layers():
    params = {
        "layers": {"attn": {"wq": jnp.zeros((4, 64, 128))}},
        "embedding": {"embed": jnp.zeros((256, 64))},
    }
    specs = tree_param_specs(params)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["embedding"]["embed"] == P("model", None)


def test_resolve_respects_missing_axes():
    # without a mesh, resolution falls back to None axes
    spec = resolve(["batch", None, "heads"])
    assert spec == P(None, None, None)


def test_rules_swap():
    set_rules({**DEFAULT_RULES, "heads": None})
    assert param_spec("x/wq", (16, 16)) == P(None, None)
    set_rules(DEFAULT_RULES)


def test_shard_noop_without_mesh():
    from repro.parallel.sharding import shard

    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)
    assert (y == x).all()
