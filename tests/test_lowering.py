"""Backend equivalence: every registered lowering of a plan computes the
same function.

The paper's core guarantee — "any canonical strategy … never alters the
network output" — asserted at the bit level across the whole lowering
registry: on random small nets, the interpreter, the checkpoint-policy
lowering, the per-segment lowering, and the jaxpr-level lowering must all
return loss and gradients **bit-identical** to vanilla
``jax.value_and_grad``.

The nets are built from ``lax`` primitives: bit-identity is a statement
about replaying the same compilation units, and ``jnp`` wrappers (e.g.
``jnp.tanh``) run as separate jit units in eager mode, which can shift a
recomputed value by an ulp.  The loss wrapper is shared by both sides, so
it does not break the comparison.

The interpreter additionally audits the memory claim: its live-byte trace
must stay within the plan's analytic peak (eq. 2) and within the
no-liveness event simulation (``core.liveness``).
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax import lax

from repro.core import PlanCache, Planner, simulate
from repro.core.blockgraph import Block, BlockGraph
from repro.core.jaxpr_graph import trace
from repro.core.lowering import (
    available_backends,
    get_lowering,
    plan_function,
    vanilla_value_and_grad,
)
from repro.core.lowering.carriers import BlockGraphCarrier, TracedCarrier

DN = (((1,), (0,)), ((), ()))  # 2-D matmul dimension_numbers
D = 8


def _leaves(t):
    return jax.tree_util.tree_leaves(t)


def _assert_bits(got, ref, what=""):
    for a, b in zip(_leaves(got), _leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)


# ---------------------------------------------------------------------------
# Random small nets (lax primitives, chain + random skip connections)
# ---------------------------------------------------------------------------


def _lin_init(rng, *in_shapes):
    return {"w": jax.random.normal(rng, (D, D)) * 0.3}


def _lin(p, *xs):
    h = xs[0]
    for x in xs[1:]:
        h = lax.add(h, x)  # skip merge
    return lax.tanh(lax.dot_general(h, p["w"], DN))


def _rand_blockgraph(seed: int, n_blocks: int) -> BlockGraph:
    r = random.Random(seed)
    blocks = [Block("b0", _lin, ("x",), _lin_init)]
    for i in range(1, n_blocks):
        ins = [f"b{i-1}"]
        if i >= 2 and r.random() < 0.5:
            ins.append(f"b{r.randrange(i - 1)}")  # skip connection
        blocks.append(Block(f"b{i}", _lin, tuple(ins), _lin_init))
    return BlockGraph(blocks, ["x"], [f"b{n_blocks-1}"])


def _rand_traced(seed: int, depth: int):
    r = random.Random(seed)
    skip_at = r.randrange(depth) if depth > 2 and r.random() < 0.7 else None

    def fn(params, x):
        h = x
        skip = x
        for i, w in enumerate(params):
            h = lax.tanh(lax.dot_general(h, w, DN))
            if i == skip_at:
                skip = h
        if skip_at is not None:
            h = lax.add(h, skip)
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(seed)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (D, D)) * 0.3
        for i in range(depth)
    ]
    x = jax.random.normal(jax.random.fold_in(key, 999), (4, D))
    return fn, (params, x)


# ---------------------------------------------------------------------------
# Property: all backends == vanilla, bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 7))
def test_blockgraph_backends_bit_identical(seed, n_blocks):
    bg = _rand_blockgraph(seed, n_blocks)
    params = bg.init(jax.random.PRNGKey(seed), {"x": (4, D)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(seed + 1), (4, D))}
    loss_fn = lambda out: jnp.sum(out * out)
    ref = vanilla_value_and_grad(bg, loss_fn)(params, inputs)

    planner = Planner(cache=PlanCache())
    g = bg.to_graph(params, inputs)
    budget = planner.min_feasible_budget(g, "approx_dp") * 1.2  # forces remat
    for backend in ("interpreter", "policy", "segment"):
        pf = plan_function(bg, budget, backend=backend, loss_fn=loss_fn,
                           planner=planner)
        loss, grads = pf(params, inputs)
        _assert_bits(loss, ref[0], f"{backend}: loss")
        _assert_bits(grads, ref[1], f"{backend}: grads")


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 9))
def test_traced_backends_bit_identical(seed, depth):
    fn, args = _rand_traced(seed, depth)
    ref = jax.value_and_grad(fn)(*args)
    planner = Planner(cache=PlanCache())
    g = trace(fn, *args).graph
    budget = planner.min_feasible_budget(g, "approx_dp") * 1.2
    for backend in ("jaxpr", "interpreter"):
        pf = plan_function(fn, budget, backend=backend, planner=planner)
        loss, grads = pf(*args)
        _assert_bits(loss, ref[0], f"{backend}: loss")
        _assert_bits(grads, ref[1], f"{backend}: grads")


# ---------------------------------------------------------------------------
# Interpreter live-byte audit vs the plan's analytic peak + core.liveness
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 8))
def test_interpreter_live_trace_within_plan_peak(seed, depth):
    fn, args = _rand_traced(seed, depth)
    pf = plan_function(fn, backend="interpreter", track_live=True,
                       planner=Planner(cache=PlanCache()))
    _, _, live = pf(*args)  # budget=None: exact minimal feasible budget
    lowered = pf.lowered_for(*args)
    peak_live = max(b for _, b in live)
    assert peak_live <= lowered.plan.peak_memory
    # audit against the event-level liveness simulator: the measured trace
    # counts forward intermediates only, so it is bounded by the
    # no-liveness simulation (which also carries gradient buffers)
    g = lowered.carrier.to_graph()
    seq = lowered.report.result.sequence
    assert peak_live <= simulate(g, seq, liveness=False).peak_memory


def test_blockgraph_interpreter_live_trace_within_plan_peak():
    bg = _rand_blockgraph(7, 6)
    params = bg.init(jax.random.PRNGKey(7), {"x": (4, D)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(8), (4, D))}
    loss_fn = lambda out: jnp.sum(out * out)
    pf = plan_function(bg, backend="interpreter", loss_fn=loss_fn,
                       track_live=True, planner=Planner(cache=PlanCache()))
    _, _, live = pf(params, inputs)
    lowered = pf.lowered_for(params, inputs)
    peak_live = max(b for _, b in live)
    assert peak_live <= lowered.plan.peak_memory


# ---------------------------------------------------------------------------
# Registry behavior
# ---------------------------------------------------------------------------


def test_registry_and_auto_dispatch():
    assert set(available_backends()) >= {
        "interpreter", "policy", "segment", "jaxpr"
    }
    fn, args = _rand_traced(3, 4)
    carrier = TracedCarrier.trace(fn, args)
    assert available_backends(carrier) == ["interpreter", "jaxpr"]
    assert carrier.default_backend == "jaxpr"

    bg = _rand_blockgraph(3, 4)
    params = bg.init(jax.random.PRNGKey(0), {"x": (4, D)})
    inputs = {"x": jnp.ones((4, D))}
    bc = BlockGraphCarrier(bg, lambda o: jnp.sum(o), params, inputs)
    assert available_backends(bc) == ["interpreter", "policy", "segment"]
    assert bc.default_backend == "policy"

    with pytest.raises(ValueError, match="unknown lowering backend"):
        get_lowering("nope")
    # a backend that does not support the carrier is rejected
    pf = plan_function(fn, backend="policy")
    with pytest.raises(ValueError, match="does not support"):
        pf.lowered_for(*args)


def test_track_live_rejected_on_xla_backends():
    fn, args = _rand_traced(5, 4)
    pf = plan_function(fn, backend="jaxpr", track_live=True)
    with pytest.raises(ValueError, match="interpreter-only"):
        pf.lowered_for(*args)


def test_shims_reexport_the_moved_entry_points():
    """core.executor / core.remat stay importable (deprecation shims)."""
    from repro.core import executor, remat
    from repro.core.lowering import interpreter, policy, segment

    assert executor.planned_value_and_grad is interpreter.planned_value_and_grad
    assert executor.vanilla_value_and_grad is interpreter.vanilla_value_and_grad
    assert remat.apply_with_policy is policy.apply_with_policy
    assert remat.plan_policy is policy.plan_policy
    assert remat.segment_groups is segment.segment_groups
    assert remat.even_groups is segment.even_groups
