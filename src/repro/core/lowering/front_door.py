"""``plan_function`` — the one-call front door of the planning pipeline.

    planned = repro.plan_function(loss_fn, budget=2 * 2**30)
    loss, grads = planned(params, x)          # value_and_grad twin

Any JAX callable (or a ``BlockGraph``) goes through the same pipeline:

    carrier (trace / blocks) → core.Graph → Planner (plan cache + budget
    sweep) → a registered Lowering backend → runnable value_and_grad

Tracing and planning happen lazily on the first call (like ``jax.jit``)
and are memoized per argument structure/avals; re-creating the planned
function — a new process, a restarted job — re-plans through the
content-addressed plan cache instead of re-running the DP.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

from ..schedule import ExecutionPlan
from .base import InfeasibleBudgetError, Lowering, resolve_backend
from .carriers import BlockGraphCarrier, TracedCarrier, abstract_signature


@dataclasses.dataclass
class LoweredPlan:
    """One (argument-signature → plan → backend) lowering of a function."""

    carrier: Any
    report: Any  # core.planner.PlanReport
    plan: ExecutionPlan
    backend: str
    run: Callable[..., Any]

    def __call__(self, *args):
        return self.run(*args)


class PlannedFunction:
    """Lazy value_and_grad twin of a function under a memory budget.

    Calling it traces/plans on first use (memoized per argument signature)
    and then runs the lowered form.  ``lowered_for(*args)`` exposes the
    underlying :class:`LoweredPlan` (plan, PlanReport, backend) for
    inspection and tests.
    """

    def __init__(
        self,
        fn: Any,
        budget: Optional[float],
        backend: str,
        method: str,
        objective: str,
        cost_model: str,
        argnums: Union[int, Tuple[int, ...]],
        loss_fn: Optional[Callable[..., Any]],
        planner: Optional[Any],
        track_live: bool,
        mesh: Any = None,
        in_shardings: Any = None,
        analyze_effects: bool = False,
        verify: bool = False,
        verify_hlo: bool = False,
        donate: bool = False,
        strategies: Any = None,
    ):
        self.fn = fn
        self.budget = budget
        self.backend = backend
        self.method = method
        self.objective = objective
        self.cost_model = cost_model
        self.argnums = argnums
        self.loss_fn = loss_fn
        self.planner = planner
        self.track_live = track_live
        self.mesh = mesh
        self.in_shardings = in_shardings
        self.analyze_effects = analyze_effects
        self.verify = verify
        self.verify_hlo = verify_hlo
        self.donate = donate
        self.strategies = strategies
        self._memo: Dict[Tuple, LoweredPlan] = {}

    # ------------------------------------------------------------------ plan

    @property
    def _trace_cost_model(self) -> str:
        # "compiled" calibration is a re-pricing step *after* a pilot plan
        # exists (extract_segment_costs needs segments); the trace itself is
        # priced analytically by FLOPs and re-priced in lowered_for.
        return "flops" if self.cost_model == "compiled" else self.cost_model

    def _carrier_for(self, args) -> Any:
        fn = self.fn
        # BlockGraph carrier: duck-typed to avoid importing blockgraph here
        if hasattr(fn, "blocks") and hasattr(fn, "by_name"):
            if self.loss_fn is None:
                raise ValueError(
                    "plan_function over a BlockGraph needs loss_fn="
                )
            if len(args) != 2:
                raise TypeError(
                    "BlockGraph planned functions take (params, inputs)"
                )
            # only shapes matter for planning — don't pin the first call's
            # concrete weights in the memo for the function's lifetime
            import jax

            def abstract(t):
                return jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
                    if hasattr(x, "shape") and hasattr(x, "dtype")
                    else x,
                    t,
                )
            if self.backend == "jaxpr":
                # equation granularity for BlockGraphs: trace ``bg.apply``
                # whole (plus the loss) and plan it like any JAX function —
                # finer than blocks where XLA fusion allows
                bg, lf = fn, self.loss_fn

                def bg_loss(params, inputs):
                    out = bg.apply(params, inputs)
                    return lf(*out) if isinstance(out, tuple) else lf(out)

                return TracedCarrier.trace(
                    bg_loss, (abstract(args[0]), abstract(args[1])),
                    argnums=0, cost_model=self._trace_cost_model,
                    mesh=self.mesh, in_shardings=self.in_shardings,
                    analyze_effects=self.analyze_effects,
                )
            return BlockGraphCarrier(
                bg=fn, loss_fn=self.loss_fn, params=abstract(args[0]),
                inputs=abstract(args[1]), cost_model=self._trace_cost_model,
                mesh=self.mesh,
            )
        return TracedCarrier.trace(
            fn, args, argnums=self.argnums, cost_model=self._trace_cost_model,
            mesh=self.mesh, in_shardings=self.in_shardings,
            analyze_effects=self.analyze_effects,
        )

    def lowered_for(self, *args) -> LoweredPlan:
        """Trace + plan + lower for this argument signature (memoized)."""
        key = abstract_signature(args)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        from ..planner import get_default_planner

        carrier = self._carrier_for(args)
        g = carrier.to_graph()
        pl = self.planner or get_default_planner()
        if self.strategies is not None:
            # Joint memory-strategy planning: wrap the base planner in one
            # configured with the requested strategy set, sharing its plan
            # cache/profile so legacy and strategy plans coexist under
            # distinct content addresses.
            from ..planner import Planner

            pl = Planner(
                cache=pl.cache,
                profile=pl.profile,
                quantize_levels=pl.quantize_levels,
                sweep_max_states=pl.sweep_max_states,
                strategies=self.strategies,
            )
        report = pl.plan(g, self.budget, self.method, self.objective)
        if report.plan is None:
            hint = ""
            if self.method in ("exact_dp", "approx_dp"):
                needed = pl.min_feasible_budget(g, self.method)
                hint = f"; minimal feasible budget is {needed:g}"
            raise InfeasibleBudgetError(
                f"no feasible strategy for budget {self.budget!r} "
                f"({self.method}/{self.objective}){hint}"
            )
        if self.cost_model == "compiled" and getattr(carrier, "jg", None):
            # Two-phase compiled calibration: the pilot plan above (FLOP
            # priced) defines segments; XLA prices each segment's compiled
            # sub-jaxpr, the graph is re-priced from those numbers (with the
            # "compiled" source hashed into its digest) and the DP re-runs.
            import jax as _jax

            from repro.analysis.hlo import extract_segment_costs

            from ..cost_model import DEFAULT_PROFILE, compiled_calibrated_graph

            profile = dataclasses.replace(
                DEFAULT_PROFILE,
                backend=_jax.default_backend(),
                jax_version=_jax.__version__,
                source="compiled",
            )
            seg_costs = extract_segment_costs(carrier, report.plan)
            g = compiled_calibrated_graph(g, report.plan, seg_costs, profile)
            report = pl.plan(g, self.budget, self.method, self.objective)
            if report.plan is None:
                raise InfeasibleBudgetError(
                    f"budget {self.budget!r} became infeasible after "
                    "compiled-cost recalibration"
                )
        import os

        env_verify = os.environ.get("REPRO_VERIFY_PLANS", "")
        do_verify = self.verify or bool(env_verify)
        do_verify_hlo = (
            self.verify_hlo or env_verify.strip().lower() == "hlo"
        )
        if do_verify:
            from repro import analysis
            from repro.analysis.report import PlanVerificationError

            vrep = analysis.check_plan(
                g, report.plan, budget=self.budget,
                effects=getattr(carrier, "effects", None),
                jg=getattr(carrier, "jg", None),
                strategies=getattr(pl, "strategies", None),
            )
            if not vrep.ok:
                raise PlanVerificationError(str(vrep))
        if do_verify_hlo:
            from repro.analysis.hlo import check_hlo
            from repro.analysis.report import PlanVerificationError

            hrep = check_hlo(carrier, report.plan)
            if not hrep.ok:
                raise PlanVerificationError(str(hrep))
        backend = resolve_backend(self.backend, carrier)
        run = backend.lower(carrier, report.plan, track_live=self.track_live,
                            donate=self.donate)
        lowered = LoweredPlan(
            carrier=carrier, report=report, plan=report.plan,
            backend=backend.name, run=run,
        )
        self._memo[key] = lowered
        return lowered

    def __call__(self, *args):
        return self.lowered_for(*args).run(*args)


def plan_function(
    fn: Any,
    budget: Optional[float] = None,
    *,
    backend: str = "auto",
    method: str = "approx_dp",
    objective: str = "time_centric",
    cost_model: str = "paper",
    argnums: Union[int, Tuple[int, ...]] = 0,
    loss_fn: Optional[Callable[..., Any]] = None,
    planner: Optional[Any] = None,
    track_live: bool = False,
    mesh: Any = None,
    in_shardings: Any = None,
    analyze_effects: bool = False,
    verify: bool = False,
    verify_hlo: bool = False,
    donate: bool = False,
    strategies: Any = None,
) -> PlannedFunction:
    """Plan ``fn``'s recomputation under ``budget`` bytes; return its
    value_and_grad twin.

    Parameters
    ----------
    fn:
        Any scalar-output JAX callable — traced on first call via
        ``core.jaxpr_graph`` — or a ``core.blockgraph.BlockGraph`` (then
        ``loss_fn`` is required and calls take ``(params, inputs)``;
        ``backend="jaxpr"`` traces ``bg.apply`` whole and plans at
        equation granularity).
    budget:
        Memory budget in bytes for the analytic peak (the liveness-tight
        refinement of eq. 2: a strategy fits iff its last-use-liveness
        execution peak does) — **per-device activation bytes** when
        ``mesh`` is given (the paper's B is one accelerator's memory).
        ``None`` reproduces the paper's §5.1 protocol: the exact minimal
        feasible budget.
    mesh / in_shardings:
        Sharding-aware planning: ``mesh`` is a ``jax.sharding.Mesh`` (or a
        plain ``{axis: size}`` dict when only the accounting is needed);
        ``in_shardings`` aligns with the positional args — each entry is
        None, one PartitionSpec/NamedSharding for every leaf of that arg,
        or a matching pytree of specs.  Shardings are propagated through
        the trace (conservative replicated fallback), node ``M_v`` becomes
        per-device bytes (distinct shardings therefore hash to distinct
        plan-cache digests), and the lowered twin re-applies the caller's
        shardings so it stays pjit-composable.
    backend:
        ``"auto"`` (the carrier's production path: ``"jaxpr"`` for traced
        functions, ``"policy"`` for BlockGraphs), or any registered
        lowering: ``"interpreter"``, ``"policy"``, ``"segment"``,
        ``"jaxpr"``.
    method / objective:
        Planner knobs (§4): ``"approx_dp"``/``"exact_dp"`` ×
        ``"time_centric"``/``"memory_centric"``/``"wallclock"``.
        ``"wallclock"`` ranks every budget-feasible Pareto candidate by
        replayed step time (``core.replay``: recompute/backward overlap
        within the budget's liveness headroom, collectives priced from the
        mesh) instead of summed eq. (1) overhead; the chosen plan's
        replayed seconds land in ``PlanReport.replayed_seconds``.
    argnums:
        Which positional args to differentiate (``jax.value_and_grad``
        semantics; traced carrier only).
    planner:
        A ``core.planner.Planner``; defaults to the process-wide one, so
        repeated plans hit the content-addressed plan cache.
    track_live:
        Interpreter backend only: calls return ``(value, grads, trace)``
        where ``trace`` is the live-intermediate-bytes audit trail.
    analyze_effects:
        Run ``repro.analysis``'s effect/determinism pass on the trace:
        PRNG-consuming / side-effecting / opaque equations taint the graph
        and their storable frontier is pinned ``must_store`` — the planner
        then prices those nodes store-only (never recomputed), and pinned
        and unpinned variants hash to distinct plan-cache digests.
    verify:
        Statically re-verify every produced plan (``analysis.check_plan``:
        topology, replay soundness, simulated peak vs. budget, eq. (1)
        overhead, per-device ``M_v``) and raise
        :class:`~repro.analysis.report.PlanVerificationError` on any error
        finding before the plan is lowered.
    verify_hlo:
        Additionally run the compiler-truth checks (``analysis.check_hlo``)
        on the compiled planned twin: heavy-op multiplicity vs. the plan's
        eq. (1) recompute counts, materialization of every cached residual
        in the optimized HLO, and the memory-drift gate against
        ``compiled.memory_analysis()``.  Traced carriers only (BlockGraph
        carriers report ``not-applicable``).

    donate:
        Jit the lowered twin with donation hints (``jaxpr``/``segment``
        backends): non-differentiated positional args are marked
        ``donate_argnums`` so XLA's buffer assignment may alias them, and
        the per-segment dead-at-peak hints (``lowering.donation``) are
        attached to the returned callable.  Values and gradients are
        unchanged; callers must not reuse donated arrays after the call on
        backends that implement donation (CPU warns and ignores).

    strategies:
        Joint memory-strategy planning (§ strategy lattice): a
        ``core.strategies.StrategyConfig`` or a tuple of strategy names
        drawn from ``{"store", "recompute", "offload", "quantize"}``.
        The planner then picks a per-node storage strategy for every
        cached residual — offloaded nodes cost host-transfer time but
        zero device bytes; quantized nodes cost codec time and int8+scale
        bytes — and the lowered twin realizes the assignment (host
        placement / ``optim.compression`` round-trip).  ``None`` (or a
        set enabling nothing beyond store+recompute) is the paper's
        binary planning, bit-identical to previous releases.

    The ``REPRO_VERIFY_PLANS`` environment variable overrides both flags at
    the launch layer: any truthy value enables ``verify``; the value
    ``"hlo"`` enables ``verify`` *and* ``verify_hlo``.

    ``cost_model="compiled"`` selects two-phase planning: a FLOP-priced
    pilot plan defines segments, XLA's ``cost_analysis()`` prices each
    segment's compiled sub-jaxpr, and the DP re-runs on the re-priced graph
    (whose digest carries the ``compiled:`` cost source, so such plans
    never alias flops-priced cache entries).
    """
    if track_live and backend == "auto":
        backend = "interpreter"
    return PlannedFunction(
        fn=fn, budget=budget, backend=backend, method=method,
        objective=objective, cost_model=cost_model, argnums=argnums,
        loss_fn=loss_fn, planner=planner, track_live=track_live,
        mesh=mesh, in_shardings=in_shardings,
        analyze_effects=analyze_effects, verify=verify,
        verify_hlo=verify_hlo, donate=donate, strategies=strategies,
    )


def planned_value_and_grad_under_budget(
    bg,
    params: Dict[str, Any],
    inputs: Dict[str, Any],
    loss_fn: Callable[..., Any],
    budget: Optional[float] = None,
    method: str = "approx_dp",
    objective: str = "time_centric",
    cost_model: str = "paper",
    planner=None,
    track_live: bool = False,
):
    """Trace → plan (through the plan cache) → interpret, in one call.

    Compatibility wrapper over :func:`plan_function` with the interpreter
    backend; returns ``(run_fn, PlanReport)`` exactly as the old
    ``core.executor`` entry point did.
    """
    pf = plan_function(
        bg, budget, backend="interpreter", method=method,
        objective=objective, cost_model=cost_model, loss_fn=loss_fn,
        planner=planner, track_live=track_live,
    )
    lowered = pf.lowered_for(params, inputs)
    return lowered.run, lowered.report
