import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first executable statements — jax locks the
device count at first init, and the dry-run (and only the dry-run) needs 512
placeholder CPU devices to build the production meshes.

Per cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the step function + shardings (launch.steps) with the paper's
     DP remat plan applied,
  3. ``jax.jit(fn, in_shardings, out_shardings).lower(*specs).compile()``,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     parsed from the post-SPMD HLO into a JSON blob for
     benchmarks/roofline.py and EXPERIMENTS.md §Dry-run.

Budget math: the per-device activation budget and chain-node byte sizes in
each record come from the shared sharding-aware accounting
(``launch.plan.plan_inputs`` → ``repro.parallel.sharding``) under the same
rules table the step compiled with — the dry-run carries no byte arithmetic
of its own.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

# HLO text parsing lives in repro.analysis.hlo_text (shared with the
# compiler-truth checkers); the historical underscore names stay as aliases
# for existing callers of the dry-run module.
from repro.analysis.hlo_text import (
    COLLECTIVES as _COLLECTIVES,  # noqa: F401  (re-exported alias)
    DTYPE_BYTES as _DTYPE_BYTES,  # noqa: F401
    SHAPE_RE as _SHAPE_RE,  # noqa: F401
    collective_bytes,
    shape_bytes as _shape_bytes,  # noqa: F401
    split_computations as _split_computations,  # noqa: F401
)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             objective: Optional[str] = None,
             opts: tuple = (),
             keep_hlo: bool = False) -> Dict[str, Any]:
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh, mesh_num_devices
    from repro.launch.steps import build_step, segment_plan
    from repro.parallel.compat import set_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "full-attention arch at 500k ctx (DESIGN.md §Arch-applicability)"}
    if cfg.encoder_decoder and shape.kind == "decode" and shape.seq_len > 32_768:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": "enc-dec 500k decode inapplicable"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.perf_counter()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": mesh_num_devices(mesh),
    }
    if opts:
        rec["opts"] = list(opts)
    with set_mesh(mesh):
        fn, in_sh, out_sh, example = build_step(cfg, shape, mesh, opts=opts)
        sp, plan_res = (segment_plan(cfg, shape, mesh)
                        if shape.kind == "train" else (None, None))
        if sp is not None:
            rec["segment_sizes"] = list(sp.sizes)
            rec["segment_remat"] = [bool(r) for r in sp.remat]
            rec["n_micro"] = sp.n_micro
            rec["plan_feasible"] = bool(plan_res.feasible)
            rec["plan_overhead_T"] = plan_res.overhead if plan_res.feasible else None
            rec["plan_peak_M"] = plan_res.peak_memory if plan_res.feasible else None
            # per-device budget bookkeeping, straight from the shared
            # sharding-aware accounting (launch.plan.plan_inputs →
            # repro.parallel.sharding) — no separate byte math here
            from repro.launch.plan import plan_inputs
            from repro.launch.steps import _dp_shards, _model_shards, _seq_shards
            from repro.parallel.sharding import get_rules

            pi = plan_inputs(
                cfg, shape, _dp_shards(mesh), _seq_shards(mesh, shape),
                _model_shards(mesh), n_micro=sp.n_micro, rules=get_rules(),
            )
            rec["budget_per_device"] = pi.budget
            rec["bytes_interior_per_device"] = pi.bytes_interior
            rec["bytes_boundary_per_device"] = pi.bytes_boundary
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*example)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)

    # Global, scan-aware FLOP/byte totals from the jaxpr (XLA cost_analysis
    # counts while-loop bodies once, so it is unusable for scan-over-layers).
    try:
        from repro.core.jaxpr_graph import jaxpr_totals

        closed = jax.make_jaxpr(fn)(*example)
        tot = jaxpr_totals(closed)
        rec["jaxpr_flops_global"] = tot["flops"]
        rec["jaxpr_bytes_global"] = tot["bytes"]
    except Exception as e:  # pragma: no cover - diagnostics only
        rec["jaxpr_totals_error"] = str(e)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
    cost = compiled.cost_analysis()
    if cost:
        c = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["hlo_flops"] = float(c.get("flops", -1))
        rec["hlo_transcendentals"] = float(c.get("transcendentals", -1))
        rec["hlo_bytes_accessed"] = float(c.get("bytes accessed", -1))
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    if keep_hlo:
        rec["hlo"] = hlo
    rec["status"] = "ok"
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="all (arch × shape) cells")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    ap.add_argument("--objective", default=None,
                    choices=[None, "time_centric", "memory_centric"])
    ap.add_argument("--opts", default="",
                    help="comma-separated hillclimb knobs (mp, ws, …)")
    args = ap.parse_args(argv)
    opts = tuple(o for o in args.opts.split(",") if o)

    from repro.configs import ARCH_IDS, SHAPES

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                tag = f"{arch}|{shape}|{mk}"
                try:
                    rec = run_cell(arch, shape, mk, objective=args.objective,
                                   opts=opts)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {"arch": arch, "shape": shape, "mesh": mk,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                line = {k: v for k, v in rec.items() if k not in ("hlo", "traceback")}
                print(json.dumps(line), flush=True)
                if rec["status"] == "error":
                    print(rec["traceback"], file=sys.stderr, flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    suffix = ("__" + "_".join(opts)) if opts else ""
                    fname = f"{arch}__{shape}__{mk}{suffix}.json".replace("/", "_")
                    with open(os.path.join(args.out, fname), "w") as f:
                        json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
