"""Table 1 — peak memory per (network × method), with liveness analysis.

Reproduces the paper's protocol: per method, binary-search the minimal
feasible budget B (§5.1), solve, simulate the canonical strategy with
liveness analysis, and report the peak and its reduction vs the vanilla run.

Deviations (documented in EXPERIMENTS.md §Paper-claims):
* graphs are abstractions with M_v from activation shapes (no params), so
  *reductions* are the comparable quantity, not absolute GB;
* exact DP runs where #𝓛_G ≤ EXACT_LIMIT — pure-Python exact DP on
  GoogLeNet's 8.8k-set lattice exceeds our time budget, exactly as the paper
  reports ">80 secs" for its optimized implementation (§5.1).
"""

from __future__ import annotations

import time
from typing import Dict, Optional

from repro.core import (
    approx_dp,
    chen_sqrt_n,
    exact_dp,
    min_feasible_budget,
    simulate,
    vanilla_peak,
)
from repro.core.lower_sets import all_lower_sets, pruned_lower_sets

from .networks import NETWORKS

EXACT_LIMIT = 2_000  # max #lower sets for the pure-Python exact DP


def run_network(name: str, liveness: bool = True) -> Dict[str, Optional[float]]:
    g = NETWORKS[name]()
    out: Dict[str, Optional[float]] = {}
    t0 = time.perf_counter()
    out["vanilla"] = vanilla_peak(g, liveness=liveness)

    # Chen's algorithm (+liveness), Appendix B configuration
    chen = chen_sqrt_n(g)
    out["chen"] = simulate(g, chen.sequence, liveness=liveness).peak_memory

    # approximate DP — both objectives at the minimal feasible budget
    fam_p = pruned_lower_sets(g)
    B_p = min_feasible_budget(g, family=fam_p, tol=1e-2)
    for obj, key in (("memory_centric", "approx_mc"), ("time_centric", "approx_tc")):
        res = approx_dp(g, B_p, objective=obj)
        out[key] = (
            simulate(g, res.sequence, liveness=liveness).peak_memory
            if res.feasible
            else None
        )
        out[key + "_overhead"] = res.overhead if res.feasible else None

    # exact DP where tractable
    try:
        fam_e = all_lower_sets(g, limit=EXACT_LIMIT)
    except RuntimeError:
        fam_e = None
    if fam_e is not None:
        B_e = min_feasible_budget(g, family=fam_e, tol=1e-2)
        for obj, key in (("memory_centric", "exact_mc"), ("time_centric", "exact_tc")):
            res = exact_dp(g, B_e, objective=obj)
            out[key] = (
                simulate(g, res.sequence, liveness=liveness).peak_memory
                if res.feasible
                else None
            )
            out[key + "_overhead"] = res.overhead if res.feasible else None
    else:
        out["exact_mc"] = out["exact_tc"] = None
    out["seconds"] = time.perf_counter() - t0
    return out


COLUMNS = ["approx_mc", "approx_tc", "exact_mc", "exact_tc", "chen", "vanilla"]
LABELS = {
    "approx_mc": "ApproxDP+MC", "approx_tc": "ApproxDP+TC",
    "exact_mc": "ExactDP+MC", "exact_tc": "ExactDP+TC",
    "chen": "Chen's", "vanilla": "Vanilla",
}


def main(liveness: bool = True, nets=None) -> Dict[str, Dict]:
    rows = {}
    title = "Table 1 (with liveness)" if liveness else "Table 2 (no liveness)"
    print(f"\n== {title} — peak activation memory, GB (reduction vs vanilla) ==")
    hdr = f"{'Network':12s} " + " ".join(f"{LABELS[c]:>20s}" for c in COLUMNS)
    print(hdr)
    for name in (nets or NETWORKS):
        r = run_network(name, liveness=liveness)
        rows[name] = r
        van = r["vanilla"]
        cells = []
        for c in COLUMNS:
            v = r.get(c)
            if v is None:
                cells.append(f"{'n/a':>20s}")
            elif c == "vanilla":
                cells.append(f"{v/1e9:17.2f} GB")
            else:
                cells.append(f"{v/1e9:11.2f} ({100*(v-van)/van:+3.0f}%)")
        print(f"{name:12s} " + " ".join(cells))
    return rows


if __name__ == "__main__":
    main()
