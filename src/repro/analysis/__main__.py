"""``python -m repro.analysis`` → the plan_lint CLI."""

import sys

from .cli import main

sys.exit(main())
