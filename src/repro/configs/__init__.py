"""Architecture registry: ``get_config("<arch-id>")`` for the 10 assigned
architectures, plus the paper's own benchmark-network graph topologies
(``paper_networks``) used by the Table-1/2 benchmarks."""

from __future__ import annotations

from typing import Dict, List

from . import (
    granite_moe_3b_a800m,
    mistral_large_123b,
    phi4_mini_3_8b,
    phi_3_vision_4_2b,
    qwen2_5_14b,
    qwen3_moe_30b_a3b,
    stablelm_3b,
    whisper_small,
    xlstm_1_3b,
    zamba2_2_7b,
)
from .base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    reduced,
    shape_applicable,
)

_MODULES = [
    xlstm_1_3b,
    stablelm_3b,
    qwen2_5_14b,
    phi4_mini_3_8b,
    mistral_large_123b,
    phi_3_vision_4_2b,
    qwen3_moe_30b_a3b,
    granite_moe_3b_a800m,
    zamba2_2_7b,
    whisper_small,
]

REGISTRY: Dict[str, ModelConfig] = {m.ARCH_ID: m.config() for m in _MODULES}
ARCH_IDS: List[str] = list(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return REGISTRY[arch_id]


__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "SHAPES",
    "REGISTRY",
    "ARCH_IDS",
    "get_config",
    "reduced",
    "shape_applicable",
]
