"""Liveness analysis [Appel & Palsberg] + an event-level execution simulator
for the canonical strategy (§3, §4.4, Appendix C).

The paper scores strategies three ways:

* the analytic model, eq. (2)            → ``core.dp.peak_memory``
* measured execution *with liveness analysis*, where every buffer is freed at
  its last use                           → ``simulate(..., liveness=True)``
* measured execution *without* liveness (Appendix C ablation), where buffers
  are freed only at the canonical strategy's own segment-boundary rules
                                          → ``simulate(..., liveness=False)``

The simulator expands the canonical strategy into a linear event list:

  forward  : for each segment i, compute f(v) for v ∈ V_i in topo order;
             at segment end, discard f(V_i \\ ∂(L_i)) (canonical rule).
  backward : for each segment i = k…1:
               recompute f(v) for uncached v ∈ V_i from the live caches;
               for w ∈ V_i in reverse topo order, run VJP(w): reads
               {f(p) : p ∈ pred(w)} ∪ {f(w), g(w)}, writes {g(p)};
             at segment end discard f/g buffers of V_i, keeping gradient
             contributions flowing to earlier segments
             (the δ⁺(L_{i-1}) ∩ V_i backward-cache rule of §3).

Because a discarded value is *recomputed* later, the same logical buffer has
several **versions** (live intervals).  The canonical strategy's explicit
discards delimit versions; liveness analysis can only shorten a version (free
at its last use inside the interval), never extend it.

Buffer sizes: both f(v) and g(v) occupy M_v (a gradient has the shape of its
value).  Parameters and inputs are excluded, as in §2.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Set, Tuple

from .graph import EMPTY, Graph, NodeSet

Buffer = Tuple[str, int]  # ("f"|"g", node)


@dataclasses.dataclass
class SimResult:
    peak_memory: float
    total_compute: float  # forward + recompute T (backward T excluded, §2)
    recompute_overhead: float  # T of recomputed nodes only
    num_events: int


@dataclasses.dataclass
class _Event:
    reads: List[Buffer]
    writes: List[Buffer]
    cost: float  # T_v for fwd/recompute events, 0 for VJP events (§2)
    frees_after: List[Buffer]  # explicit canonical-strategy discards


def _topo_within(g: Graph, nodes: NodeSet) -> List[int]:
    order = g.topological_order()
    return [v for v in order if v in nodes]


def build_events(g: Graph, sequence: Sequence[NodeSet]) -> List[_Event]:
    """Expand a lower-set sequence into the canonical-strategy event list."""
    g.check_increasing_sequence(sequence)
    events: List[_Event] = []
    k = len(sequence)
    prev: NodeSet = EMPTY
    segs: List[NodeSet] = []
    bounds: List[NodeSet] = []
    for L in sequence:
        segs.append(L - prev)
        bounds.append(g.boundary(L))
        prev = L
    # U_i = ∪_{j≤i} ∂(L_j)
    Us: List[NodeSet] = []
    acc: Set[int] = set()
    for b in bounds:
        acc |= b
        Us.append(frozenset(acc))
    U_k = Us[-1]

    # ---------------- forward ----------------
    for i, Vi in enumerate(segs):
        for v in _topo_within(g, Vi):
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # canonical rule: cache U_k ∩ V_i (its boundary nodes), discard rest
        drop = Vi - U_k
        if drop and events:
            events[-1].frees_after.extend(("f", v) for v in drop)

    # ---------------- backward ----------------
    for i in range(k - 1, -1, -1):
        Vi = segs[i]
        # recompute uncached forward values of V_i
        for v in _topo_within(g, Vi):
            if v in U_k:
                continue  # cached since the forward pass
            events.append(
                _Event(
                    reads=[("f", p) for p in g.pred[v]],
                    writes=[("f", v)],
                    cost=g.time_v[v],
                    frees_after=[],
                )
            )
        # VJP sweep in reverse topological order
        for w in reversed(_topo_within(g, Vi)):
            reads: List[Buffer] = [("f", p) for p in g.pred[w]]
            reads.append(("f", w))
            if g.succ[w]:
                reads.append(("g", w))
            events.append(
                _Event(
                    reads=reads,
                    writes=[("g", p) for p in g.pred[w]] or [("g", w)],
                    cost=0.0,
                    frees_after=[],
                )
            )
        # segment-end frees: drop f/g of V_i; gradient contributions to
        # earlier segments are ("g", p) with p ∉ V_i and thus survive.
        frees = [("f", v) for v in Vi] + [("g", v) for v in Vi]
        if events:
            events[-1].frees_after.extend(frees)
    return events


def build_vanilla_events(g: Graph) -> List[_Event]:
    """No-recomputation baseline: cache every forward value, then backprop."""
    events: List[_Event] = []
    order = g.topological_order()
    for v in order:
        events.append(
            _Event([("f", p) for p in g.pred[v]], [("f", v)], g.time_v[v], [])
        )
    for w in reversed(order):
        reads: List[Buffer] = [("f", p) for p in g.pred[w]] + [("f", w)]
        if g.succ[w]:
            reads.append(("g", w))
        events.append(
            _Event(reads, [("g", p) for p in g.pred[w]] or [("g", w)], 0.0, [])
        )
    if events:
        events[-1].frees_after = [("f", v) for v in order] + [
            ("g", v) for v in order
        ]
    return events


def simulate_events(
    g: Graph, events: List[_Event], liveness: bool
) -> SimResult:
    """Peak live bytes over an event list, with versioned buffer intervals.

    A buffer *version* opens at its first write (or lazy-read for gradient
    seeds) and closes at the strategy's explicit discard.  liveness=True
    shrinks each version to end at its last use instead.
    """

    def size(buf: Buffer) -> float:
        return g.mem_v[buf[1]]

    # Pass 1: version intervals.
    open_ver: Dict[Buffer, int] = {}
    nver: Dict[Buffer, int] = defaultdict(int)
    start: Dict[Tuple[Buffer, int], int] = {}
    last_touch: Dict[Tuple[Buffer, int], int] = {}
    end: Dict[Tuple[Buffer, int], int] = {}

    def touch(b: Buffer, idx: int) -> None:
        if b not in open_ver:
            v = nver[b]
            nver[b] += 1
            open_ver[b] = v
            start[(b, v)] = idx
        last_touch[(b, open_ver[b])] = idx

    n_events = len(events)
    for idx, ev in enumerate(events):
        for b in ev.reads:
            touch(b, idx)
        for b in ev.writes:
            touch(b, idx)
        for b in ev.frees_after:
            if b in open_ver:
                end[(b, open_ver[b])] = idx
                del open_ver[b]
    for b, v in open_ver.items():
        end[(b, v)] = n_events - 1

    # Pass 2: sweep with a difference array.
    delta = [0.0] * (n_events + 1)
    for key, s_idx in start.items():
        e_idx = last_touch[key] if liveness else end[key]
        e_idx = min(e_idx, end.get(key, e_idx))
        delta[s_idx] += size(key[0])
        delta[e_idx + 1] -= size(key[0])
    peak = 0.0
    cur = 0.0
    for idx in range(n_events):
        cur += delta[idx]
        peak = max(peak, cur)

    total_T = sum(ev.cost for ev in events)
    return SimResult(
        peak_memory=peak,
        total_compute=total_T,
        recompute_overhead=total_T - g.total_time,
        num_events=n_events,
    )


def simulate(
    g: Graph, sequence: Sequence[NodeSet], liveness: bool = True
) -> SimResult:
    """Simulate the canonical strategy for a lower-set sequence."""
    return simulate_events(g, build_events(g, sequence), liveness)


def vanilla_peak(g: Graph, liveness: bool = True) -> float:
    """Peak of the no-recomputation baseline (cache everything)."""
    return simulate_events(g, build_vanilla_events(g), liveness).peak_memory
