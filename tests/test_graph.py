"""§2 graph language: δ±, lower sets, boundaries — unit + property tests."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import EMPTY, Graph, Node, chain, from_cost_lists

from conftest import random_dag
from helpers import brute_lower_sets


def test_three_layer_perceptron_example():
    # Figure 1: a small chain — boundary of a prefix is its last node
    g = chain(5)
    L = frozenset({0, 1, 2})
    assert g.is_lower_set(L)
    assert g.boundary(L) == {2}
    assert g.delta_plus(L) == {1, 2, 3}
    assert g.delta_minus({3}) == {2}


def test_delta_definitions():
    #     0 → 1 → 3
    #      ↘ 2 ↗
    g = from_cost_lists([1] * 4, [1] * 4, [(0, 1), (0, 2), (1, 3), (2, 3)])
    assert g.delta_plus({0}) == {1, 2}
    assert g.delta_minus({3}) == {1, 2}
    assert g.is_lower_set({0, 1})
    assert not g.is_lower_set({1})
    assert g.boundary({0, 1, 2}) == {1, 2}
    # ∂({0,1,2,3}) = ∅: nothing outside needs anything
    assert g.boundary({0, 1, 2, 3}) == EMPTY


def test_lower_set_iff_closed_under_predecessors(rng):
    for trial in range(50):
        g = random_dag(rng, rng.randint(1, 7), topo_ids=(trial % 2 == 0))
        for L in brute_lower_sets(g):
            assert g.delta_minus(L) <= L


def test_boundary_subset_and_completeness(rng):
    for _ in range(50):
        g = random_dag(rng, rng.randint(1, 7))
        for L in brute_lower_sets(g):
            b = g.boundary(L)
            assert b <= L
            # nodes of L \ ∂(L) have no successors outside L
            for v in L - b:
                assert set(g.succ[v]) <= L


def test_lower_closure_is_minimal_lower_set(rng):
    for _ in range(30):
        g = random_dag(rng, 7)
        s = set(rng.sample(range(7), 3))
        L = g.lower_closure(s)
        assert g.is_lower_set(L) and s <= L
        # minimality: removing any element not in s breaks closure or coverage
        for v in L - s:
            if g.is_lower_set(L - {v}):
                assert not s <= (L - {v}) or any(
                    v in g.ancestors_of(w) for w in s
                )


def test_cycle_rejected():
    with pytest.raises(ValueError):
        Graph([Node(0, "a", 1, 1), Node(1, "b", 1, 1)], [(0, 1), (1, 0)])


def test_nonpositive_costs_rejected():
    with pytest.raises(ValueError):
        Graph([Node(0, "a", 0.0, 1)], [])
    with pytest.raises(ValueError):
        Graph([Node(0, "a", 1, -1.0)], [])


def test_check_increasing_sequence():
    g = chain(4)
    full = frozenset(range(4))
    g.check_increasing_sequence([frozenset({0}), frozenset({0, 1}), full])
    with pytest.raises(ValueError):
        g.check_increasing_sequence([frozenset({0, 1}), frozenset({0})])
    with pytest.raises(ValueError):
        g.check_increasing_sequence([frozenset({1})])  # not a lower set
    with pytest.raises(ValueError):
        g.check_increasing_sequence([frozenset({0})])  # does not end at V


@given(st.integers(1, 16))
def test_chain_count_paper_bounds(n):
    # paper: #V ≤ #𝓛_G ≤ 2^#V; chains achieve the minimum + 1 (∅ included)
    from repro.core.lower_sets import count_lower_sets

    g = chain(n)
    assert count_lower_sets(g) == n + 1


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_T_M_additivity(data):
    r = random.Random(data.draw(st.integers(0, 10_000)))
    g = random_dag(r, data.draw(st.integers(1, 8)))
    picks = data.draw(
        st.lists(st.integers(0, g.n - 1), max_size=g.n, unique=True)
    )
    s = frozenset(picks)
    assert g.T(s) == pytest.approx(sum(g.time_v[v] for v in s))
    assert g.M(s) == pytest.approx(sum(g.mem_v[v] for v in s))
